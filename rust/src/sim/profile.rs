//! Profile reports: the bottleneck-classified counter snapshot the
//! platform attaches to every submission (DESIGN.md §11).
//!
//! The paper's scientist conditions its designer on *timing data only*;
//! GEAK-agent-style loops classify the bottleneck from profiler
//! counters and steer avenue choice with it. The sim backend already
//! computes every ingredient — [`KernelTiming`] carries the mechanistic
//! compute/memory/LDS/occupancy/launch breakdown — but discarded it
//! after producing a scalar time. A [`ProfileReport`] is that breakdown
//! kept: per-component microseconds summed over the feedback suite,
//! plus a deterministic [`Bottleneck`] classification with a ranked
//! secondary.
//!
//! Purity contract: a report is a **pure function of the noiseless
//! [`KernelTiming`]s** — no RNG draw is ever consumed deriving one, so
//! attaching reports cannot perturb any measurement stream or
//! trajectory. That is what lets the platform compute them
//! unconditionally (journals always carry profiles) while the
//! `[profile] guided` knob only gates what *reads* them.

use super::KernelTiming;
use crate::util::json::{push_num_value, push_str_value, req_f64, req_str, Json};

/// The classified dominant cost component of a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Bottleneck {
    /// HBM / L2-fabric traffic (global loads + writeback) dominates.
    Memory,
    /// The compute pipe itself dominates.
    Compute,
    /// LDS bank-conflict stalls on the compute pipe dominate.
    Lds,
    /// Grid-utilization serialization (partial last wave of
    /// workgroups) dominates.
    Occupancy,
    /// Kernel launch + dispatch overhead dominates (tiny problems).
    Launch,
}

impl Bottleneck {
    /// Classification order — also the tie-break order when two
    /// components cost exactly the same (first listed wins).
    pub const ALL: [Bottleneck; 5] = [
        Bottleneck::Memory,
        Bottleneck::Compute,
        Bottleneck::Lds,
        Bottleneck::Occupancy,
        Bottleneck::Launch,
    ];

    /// Stable wire tag (journal / checkpoint / report).
    pub fn tag(&self) -> &'static str {
        match self {
            Bottleneck::Memory => "memory",
            Bottleneck::Compute => "compute",
            Bottleneck::Lds => "lds",
            Bottleneck::Occupancy => "occupancy",
            Bottleneck::Launch => "launch",
        }
    }

    /// Decode a [`Bottleneck::tag`].
    pub fn from_tag(s: &str) -> Result<Bottleneck, String> {
        match s {
            "memory" => Ok(Bottleneck::Memory),
            "compute" => Ok(Bottleneck::Compute),
            "lds" => Ok(Bottleneck::Lds),
            "occupancy" => Ok(Bottleneck::Occupancy),
            "launch" => Ok(Bottleneck::Launch),
            other => Err(format!("unknown bottleneck '{other}'")),
        }
    }

    /// Position in [`Bottleneck::ALL`] (the [`ProfileMix`] index).
    pub fn index(&self) -> usize {
        match self {
            Bottleneck::Memory => 0,
            Bottleneck::Compute => 1,
            Bottleneck::Lds => 2,
            Bottleneck::Occupancy => 3,
            Bottleneck::Launch => 4,
        }
    }
}

/// A secondary bottleneck is only reported when it carries at least
/// this share of the total attributed cost — below it the ranking is
/// noise, not signal.
pub const SECONDARY_SHARE: f64 = 0.15;

/// Per-submission profile: component costs (microseconds, summed over
/// the feedback suite) plus the classification they imply.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileReport {
    pub compute_us: f64,
    pub lds_us: f64,
    pub mem_us: f64,
    pub occupancy_us: f64,
    pub launch_us: f64,
    pub bottleneck: Bottleneck,
    /// Second-ranked component, if it carries ≥ [`SECONDARY_SHARE`] of
    /// the total attributed cost.
    pub secondary: Option<Bottleneck>,
}

/// Attribute one timing to the five cost components, in
/// [`Bottleneck::ALL`] order. The attribution reconstructs the cost
/// model's own terms from the fields [`KernelTiming`] exposes:
/// `t_exec = compute x (1 + lds_pressure)` splits into pipe time and
/// LDS stall time; grid serialization is the extra time the
/// `1/grid_utilization` divisor adds over the busy components.
pub fn components(t: &KernelTiming) -> [f64; 5] {
    let mem = t.mem_us + t.writeback_us;
    let compute = t.compute_us;
    let lds = t.compute_us * t.lds_pressure;
    let busy = compute + lds + mem;
    let occupancy = if t.grid_utilization > 0.0 {
        busy * (1.0 / t.grid_utilization - 1.0)
    } else {
        0.0
    };
    [mem, compute, lds, occupancy, t.launch_us]
}

/// Rank component costs and classify. Deterministic: ties broken by
/// [`Bottleneck::ALL`] order (stable sort), `total_cmp` so even
/// degenerate non-finite costs order reproducibly.
pub fn classify(costs: &[f64; 5]) -> (Bottleneck, Option<Bottleneck>) {
    let mut ranked: Vec<(Bottleneck, f64)> = Bottleneck::ALL
        .iter()
        .copied()
        .zip(costs.iter().copied())
        .collect();
    ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
    let total: f64 = costs.iter().sum();
    let secondary = if total > 0.0 && ranked[1].1 >= SECONDARY_SHARE * total {
        Some(ranked[1].0)
    } else {
        None
    };
    (ranked[0].0, secondary)
}

impl ProfileReport {
    /// Profile one noiseless timing.
    pub fn from_timing(t: &KernelTiming) -> ProfileReport {
        ProfileReport::from_timings(std::slice::from_ref(t))
    }

    /// Profile a submission: sum component costs over the feedback
    /// suite's noiseless timings, then classify the sums.
    pub fn from_timings(timings: &[KernelTiming]) -> ProfileReport {
        let mut sums = [0.0f64; 5];
        for t in timings {
            let c = components(t);
            for (s, v) in sums.iter_mut().zip(c.iter()) {
                *s += v;
            }
        }
        let (bottleneck, secondary) = classify(&sums);
        ProfileReport {
            mem_us: sums[0],
            compute_us: sums[1],
            lds_us: sums[2],
            occupancy_us: sums[3],
            launch_us: sums[4],
            bottleneck,
            secondary,
        }
    }

    /// One-line rendering for reports / `inspect`.
    pub fn render(&self) -> String {
        let mut s = format!(
            "bottleneck {} (mem {:.1} us, compute {:.1} us, lds {:.1} us, \
             occupancy {:.1} us, launch {:.1} us)",
            self.bottleneck.tag(),
            self.mem_us,
            self.compute_us,
            self.lds_us,
            self.occupancy_us,
            self.launch_us
        );
        if let Some(b) = self.secondary {
            s.push_str(&format!(", secondary {}", b.tag()));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bottleneck", Json::Str(self.bottleneck.tag().to_string())),
            ("compute_us", Json::Num(self.compute_us)),
            ("launch_us", Json::Num(self.launch_us)),
            ("lds_us", Json::Num(self.lds_us)),
            ("mem_us", Json::Num(self.mem_us)),
            ("occupancy_us", Json::Num(self.occupancy_us)),
            (
                "secondary",
                self.secondary
                    .map(|b| Json::Str(b.tag().to_string()))
                    .unwrap_or(Json::Null),
            ),
        ])
    }

    /// Streamed emission, byte-identical to `to_json().to_string()`
    /// (keys in alphabetical order) — the journal's zero-alloc path.
    pub fn write_json(&self, out: &mut String) {
        out.push_str("{\"bottleneck\":");
        push_str_value(out, self.bottleneck.tag());
        out.push_str(",\"compute_us\":");
        push_num_value(out, self.compute_us);
        out.push_str(",\"launch_us\":");
        push_num_value(out, self.launch_us);
        out.push_str(",\"lds_us\":");
        push_num_value(out, self.lds_us);
        out.push_str(",\"mem_us\":");
        push_num_value(out, self.mem_us);
        out.push_str(",\"occupancy_us\":");
        push_num_value(out, self.occupancy_us);
        out.push_str(",\"secondary\":");
        match self.secondary {
            Some(b) => push_str_value(out, b.tag()),
            None => out.push_str("null"),
        }
        out.push('}');
    }

    pub fn from_json(v: &Json) -> Result<ProfileReport, String> {
        Ok(ProfileReport {
            compute_us: req_f64(v, "compute_us")?,
            lds_us: req_f64(v, "lds_us")?,
            mem_us: req_f64(v, "mem_us")?,
            occupancy_us: req_f64(v, "occupancy_us")?,
            launch_us: req_f64(v, "launch_us")?,
            bottleneck: Bottleneck::from_tag(req_str(v, "bottleneck")?)?,
            secondary: match v.get("secondary") {
                None | Some(Json::Null) => None,
                Some(s) => Some(Bottleneck::from_tag(
                    s.as_str().ok_or("profile: bad secondary")?,
                )?),
            },
        })
    }
}

/// Bottleneck counts across a run's submissions (the campaign table's
/// mix column). Indexed by [`Bottleneck::index`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ProfileMix {
    pub counts: [u64; 5],
}

impl ProfileMix {
    pub fn add(&mut self, b: Bottleneck) {
        self.counts[b.index()] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// `"memory 12, compute 3"` — nonzero counts in [`Bottleneck::ALL`]
    /// order; `"-"` when empty.
    pub fn render(&self) -> String {
        let mut parts = Vec::new();
        for b in Bottleneck::ALL {
            let n = self.counts[b.index()];
            if n > 0 {
                parts.push(format!("{} {n}", b.tag()));
            }
        }
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join(", ")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::gpu::MI300;
    use crate::workload::{GemmConfig, FEEDBACK_CONFIGS};

    fn timing(g: &crate::genome::KernelGenome, cfg: &GemmConfig) -> KernelTiming {
        super::super::estimate(&MI300, g, cfg).unwrap()
    }

    #[test]
    fn naive_kernel_is_memory_bound() {
        // no LDS staging, narrow loads: fabric traffic dominates
        let timings: Vec<KernelTiming> = FEEDBACK_CONFIGS
            .iter()
            .map(|c| timing(&seeds::naive_hip(), c))
            .collect();
        let p = ProfileReport::from_timings(&timings);
        assert_eq!(p.bottleneck, Bottleneck::Memory);
        assert!(p.mem_us > p.compute_us);
    }

    #[test]
    fn classification_matches_the_largest_component() {
        for (_, g) in seeds::all_seeds() {
            for cfg in FEEDBACK_CONFIGS {
                let t = timing(&g, &cfg);
                let p = ProfileReport::from_timing(&t);
                let costs = [p.mem_us, p.compute_us, p.lds_us, p.occupancy_us, p.launch_us];
                let max = costs.iter().cloned().fold(f64::MIN, f64::max);
                assert_eq!(
                    costs[p.bottleneck.index()], max,
                    "{g:?} {cfg}: primary is not the max component"
                );
                if let Some(s) = p.secondary {
                    assert_ne!(s, p.bottleneck);
                    let total: f64 = costs.iter().sum();
                    assert!(costs[s.index()] >= SECONDARY_SHARE * total);
                }
            }
        }
    }

    #[test]
    fn tiny_problem_is_launch_bound() {
        // a synthetic timing where only launch matters
        let t = KernelTiming {
            compute_us: 0.01,
            lds_pressure: 0.0,
            mem_us: 0.01,
            writeback_us: 0.0,
            launch_us: 5.0,
            total_us: 5.02,
            compute_efficiency: 0.01,
            occupancy_waves: 1,
            grid_utilization: 1.0,
        };
        let p = ProfileReport::from_timing(&t);
        assert_eq!(p.bottleneck, Bottleneck::Launch);
        assert_eq!(p.secondary, None, "nothing else is within the share floor");
    }

    #[test]
    fn ties_break_in_declaration_order() {
        let (b, _) = classify(&[1.0, 1.0, 1.0, 1.0, 1.0]);
        assert_eq!(b, Bottleneck::Memory, "first of ALL wins exact ties");
        let (b, s) = classify(&[0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(b, Bottleneck::Memory);
        assert_eq!(s, None, "zero total reports no secondary");
    }

    #[test]
    fn json_roundtrip_is_lossless_and_streaming_matches() {
        for (_, g) in seeds::all_seeds() {
            let timings: Vec<KernelTiming> =
                FEEDBACK_CONFIGS.iter().map(|c| timing(&g, c)).collect();
            let p = ProfileReport::from_timings(&timings);
            let emitted = p.to_json().to_string();
            let mut streamed = String::new();
            p.write_json(&mut streamed);
            assert_eq!(streamed, emitted, "streamed == tree emitter");
            let back =
                ProfileReport::from_json(&crate::util::json::parse(&emitted).unwrap()).unwrap();
            assert_eq!(back, p, "{g:?}");
        }
    }

    #[test]
    fn tag_roundtrip() {
        for b in Bottleneck::ALL {
            assert_eq!(Bottleneck::from_tag(b.tag()).unwrap(), b);
            assert_eq!(Bottleneck::ALL[b.index()], b);
        }
        assert!(Bottleneck::from_tag("register").is_err());
    }

    #[test]
    fn profile_mix_renders_nonzero_counts_in_order() {
        let mut mix = ProfileMix::default();
        assert_eq!(mix.render(), "-");
        mix.add(Bottleneck::Compute);
        mix.add(Bottleneck::Memory);
        mix.add(Bottleneck::Memory);
        assert_eq!(mix.total(), 3);
        assert_eq!(mix.render(), "memory 2, compute 1");
    }
}
