//! Calibration of the simulator against Table 1 of the paper.
//!
//! The paper reports geometric-mean execution times over the 18
//! leaderboard sizes:
//!
//! | implementation      | us     |
//! |---------------------|--------|
//! | PyTorch reference   | ~850   |
//! | Human 1st place     | 105    |
//! | Naive HIP           | ~5000  |
//! | This work (LLM-only)| ~450   |
//!
//! We pin the canonical genomes to these magnitudes within a tolerance
//! band (the authors themselves write "~"). The *ratios* are what the
//! reproduction must preserve: naive/pytorch ~ 5.9x, pytorch/evolved
//! ~ 1.9x, evolved/oracle ~ 4.3x.

use crate::genome::{seeds, KernelGenome};
use crate::gpu::GpuArch;
use crate::metrics::geomean;
use crate::sim::estimate;
use crate::workload::LEADERBOARD_SIZES;

/// Noiseless leaderboard geomean for a genome (microseconds).
pub fn leaderboard_geomean(arch: &GpuArch, g: &KernelGenome) -> f64 {
    let times: Vec<f64> = LEADERBOARD_SIZES
        .iter()
        .map(|cfg| estimate(arch, g, cfg).expect("canonical genome must be valid").total_us)
        .collect();
    geomean(&times)
}

/// The four Table-1 rows as (label, paper_us, simulated_us).
pub fn table1_rows(arch: &GpuArch) -> Vec<(&'static str, f64, f64)> {
    vec![
        (
            "PyTorch reference",
            850.0,
            leaderboard_geomean(arch, &seeds::pytorch_reference()),
        ),
        (
            "Human 1st place",
            105.0,
            leaderboard_geomean(arch, &seeds::human_oracle()),
        ),
        (
            "Naive HIP",
            5000.0,
            leaderboard_geomean(arch, &seeds::naive_hip()),
        ),
        (
            "This work (representative evolved)",
            450.0,
            leaderboard_geomean(arch, &seeds::paper_evolved()),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::MI300;

    fn ratio_close(actual: f64, target: f64, tol: f64) -> bool {
        (actual / target).ln().abs() < tol.ln()
    }

    #[test]
    fn table1_magnitudes() {
        // Within 2x band of the paper's (approximate) absolute numbers.
        for (label, paper, sim) in table1_rows(&MI300) {
            assert!(
                ratio_close(sim, paper, 2.0),
                "{label}: simulated {sim:.0} us vs paper {paper:.0} us"
            );
        }
    }

    #[test]
    fn table1_ratios() {
        let rows = table1_rows(&MI300);
        let get = |label: &str| rows.iter().find(|(l, _, _)| *l == label).unwrap().2;
        let lib = get("PyTorch reference");
        let oracle = get("Human 1st place");
        let naive = get("Naive HIP");
        let evolved = get("This work (representative evolved)");
        // who-wins ordering
        assert!(naive > lib && lib > evolved && evolved > oracle);
        // rough factors (within ~1.7x of the paper's ratios)
        assert!(ratio_close(naive / lib, 5.9, 1.8), "naive/lib = {}", naive / lib);
        assert!(ratio_close(lib / evolved, 1.9, 1.8), "lib/evolved = {}", lib / evolved);
        assert!(
            ratio_close(evolved / oracle, 4.3, 1.8),
            "evolved/oracle = {}",
            evolved / oracle
        );
    }
}
