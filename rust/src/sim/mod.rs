//! The MI300-class timing simulator: the evaluation platform's
//! stand-in for real competition hardware.
//!
//! For a (genome, GEMM-config) pair it composes the `gpu/` models into
//! an end-to-end execution-time estimate with a mechanistic breakdown
//! (compute, memory, LDS, writeback, launch), then applies seeded
//! lognormal measurement noise — the scientist only ever sees the
//! noisy total, exactly like the paper's submission timings.
//!
//! Composition (per config):
//!
//! ```text
//! t_compute = flops / (peak x pipe_eff x issue_eff(occupancy))
//! t_exec    = t_compute x (1 + lds_pressure)          (LDS contends)
//! t_mem     = max(HBM-miss traffic / HBM bw,
//!                 total operand reads / L2 fabric bw) / coalesce / hide
//! t_main    = overlap(t_exec, t_mem)    (double buffer => max;
//!                                        staged single buffer => sum;
//!                                        unstaged => max)
//! total     = (t_main + t_writeback) / grid_util + launch + dispatch
//! ```

pub mod calibration;
pub mod profile;

pub use profile::{Bottleneck, ProfileMix, ProfileReport};

use std::sync::Arc;

use crate::genome::{Invalid, KernelGenome};
use crate::gpu::{lds, memory, mfma, occupancy, GpuArch, MI300};
use crate::rng::Rng;
use crate::workload::{GemmConfig, Workload};

/// Mechanistic per-run breakdown (microseconds unless noted). The
/// *scientist never sees this* — only `total_us` leaves the platform —
/// but benches and EXPERIMENTS.md use it for roofline accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelTiming {
    pub compute_us: f64,
    pub lds_pressure: f64,
    pub mem_us: f64,
    pub writeback_us: f64,
    pub launch_us: f64,
    pub total_us: f64,
    /// Fraction of the peak pipe the kernel achieved (for §Perf).
    pub compute_efficiency: f64,
    pub occupancy_waves: u32,
    pub grid_utilization: f64,
}

/// Deterministic noiseless estimate for a genome on a config — the
/// paper's fp8 block-scaled GEMM (per-row/col dequant scales included).
pub fn estimate(arch: &GpuArch, g: &KernelGenome, cfg: &GemmConfig) -> Result<KernelTiming, Invalid> {
    estimate_gemm(arch, g, cfg, true)
}

/// The tiled-GEMM cost model shared by the GEMM workload families.
/// `block_scales` switches the fp8 task's per-row/col dequant-scale
/// traffic; plain bf16/fp16 GEMMs have none.
pub fn estimate_gemm(
    arch: &GpuArch,
    g: &KernelGenome,
    cfg: &GemmConfig,
    block_scales: bool,
) -> Result<KernelTiming, Invalid> {
    g.validate()?;
    let occ = occupancy::occupancy(arch, g);
    let issue = occupancy::compute_issue_efficiency(&occ);
    let hide = occupancy::memory_latency_efficiency(&occ);

    // --- compute pipe ---
    let pipe_eff = mfma::pipe_efficiency(g);
    let peak = arch.peak_tflops(g) * pipe_eff * issue; // TFLOP/s
    let t_compute = cfg.flops() / (peak * 1e6); // us
    let lds_pressure = lds::pressure(g);
    let t_exec = t_compute * (1.0 + lds_pressure);

    // --- memory system ---
    let elt = GpuArch::operand_elt_bytes(g) as f64;
    let tiles_m = (cfg.m / g.block_m).max(1) as f64;
    let tiles_n = (cfg.n / g.block_n).max(1) as f64;
    let redundancy = if g.lds_staging { 1.0 } else { 2.0 };
    let scale_reads = if block_scales {
        memory::scale_traffic(g, cfg)
    } else {
        0.0
    };
    let total_reads = (cfg.m as f64 * cfg.k as f64 * elt * tiles_n
        + cfg.k as f64 * cfg.n as f64 * elt * tiles_m)
        * redundancy
        + scale_reads;
    let hbm_traffic = memory::hbm_operand_traffic(g, cfg, arch);
    let coal = memory::coalescing_efficiency(g.vector_width);
    let t_hbm = hbm_traffic / (arch.hbm_tbps * 1e6);
    let t_fabric = total_reads / (arch.l2_tbps * 1e6);
    let t_mem = t_hbm.max(t_fabric) / (coal * hide);

    // --- overlap ---
    let t_main = if g.double_buffer {
        // ping-pong: loads hide behind compute (plus pipeline fill)
        t_exec.max(t_mem) + 0.02 * t_exec.min(t_mem)
    } else if g.lds_staging {
        // load tile -> barrier -> compute tile: serialized phases
        t_exec + 0.85 * t_mem
    } else {
        // unstaged: wave scheduler overlaps inline loads with math
        t_exec.max(t_mem)
    };

    let t_write = memory::writeback_us(g, cfg, arch);

    // --- grid ---
    let wgs = (cfg.m as u64 / g.block_m as u64).max(1)
        * (cfg.n as u64 / g.block_n as u64).max(1);
    let util = occupancy::grid_utilization(arch, &occ, wgs);
    let t_launch = arch.launch_overhead_us + wgs as f64 / arch.dispatch_rate_per_us / 1e3;

    let total = (t_main + t_write) / util + t_launch;
    let ideal = cfg.flops() / (arch.peak_tflops(g) * 1e6);
    Ok(KernelTiming {
        compute_us: t_compute,
        lds_pressure,
        mem_us: t_mem,
        writeback_us: t_write,
        launch_us: t_launch,
        total_us: total,
        compute_efficiency: (ideal / total).min(1.0),
        occupancy_waves: occ.waves_per_cu,
        grid_utilization: util,
    })
}

/// The simulator backend: noiseless model + seeded lognormal jitter.
///
/// Each measurement perturbs the estimate by `exp(sigma * N(0,1))`
/// with an RNG stream derived from the backend seed and a submission
/// counter — two submissions of the *same* genome get different
/// timings, as on the real platform.
///
/// The backend is workload-generic: the cost model it times genomes
/// with is the [`Workload::estimate`] hook of whichever registered
/// workload it carries (the paper's fp8 GEMM by default, which keeps
/// the pre-registry timings bit-identical).
#[derive(Debug, Clone)]
pub struct SimBackend {
    pub arch: GpuArch,
    pub noise_sigma: f64,
    rng: Rng,
    measurements: u64,
    /// The construction seed, kept so parallel lane backends can derive
    /// decorrelated-but-deterministic noise streams (`lane_clone`).
    seed: u64,
    /// The workload whose cost model this backend times.
    workload: Arc<dyn Workload>,
}

impl SimBackend {
    pub fn new(seed: u64) -> Self {
        SimBackend {
            arch: MI300.clone(),
            noise_sigma: 0.02,
            rng: Rng::seed_from_u64(seed ^ 0x51b7_ca11),
            measurements: 0,
            seed,
            workload: crate::workload::default_workload(),
        }
    }

    /// Time genomes with a different registered workload's cost model.
    pub fn with_workload(mut self, workload: Arc<dyn Workload>) -> Self {
        self.workload = workload;
        self
    }

    /// The workload this backend evaluates.
    pub fn workload(&self) -> &Arc<dyn Workload> {
        &self.workload
    }

    /// An independent submission-lane backend: same architecture and
    /// noise model, with a noise stream forked deterministically from
    /// this backend's stream and the lane id. Models one of several
    /// identical competition servers, each with its own measurement
    /// jitter. Forking consumes one draw of the parent stream, so
    /// successive batches get fresh (yet seed-reproducible) lane
    /// noise; the sequential parallelism=1 path never forks, keeping
    /// it bit-identical to plain sequential submission.
    pub fn lane_clone(&mut self, lane: u64) -> SimBackend {
        let lane_seed = self
            .seed
            .wrapping_add((lane + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        SimBackend {
            arch: self.arch.clone(),
            noise_sigma: self.noise_sigma,
            rng: self.rng.fork(lane),
            measurements: 0,
            seed: lane_seed,
            workload: self.workload.clone(),
        }
    }

    pub fn with_noise(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// One noisy timing measurement (microseconds).
    pub fn measure(&mut self, g: &KernelGenome, cfg: &GemmConfig) -> Result<f64, Invalid> {
        let t = self.workload.estimate(&self.arch, g, cfg)?;
        self.measurements += 1;
        let noise = self.rng.lognormal_factor(self.noise_sigma);
        Ok(t.total_us * noise)
    }

    /// Noiseless breakdown (used by reports, never by agents).
    pub fn breakdown(&self, g: &KernelGenome, cfg: &GemmConfig) -> Result<KernelTiming, Invalid> {
        self.workload.estimate(&self.arch, g, cfg)
    }

    /// Profile a genome over the workload's feedback suite: noiseless
    /// breakdowns only — **no RNG draw, no measurement counted** — so
    /// profiling never perturbs the backend's noise stream. `None` when
    /// the genome is invalid for the cost model (such submissions carry
    /// no timings either).
    pub fn profile(&self, g: &KernelGenome) -> Option<ProfileReport> {
        let suite = self.workload.feedback_suite();
        let mut timings = Vec::with_capacity(suite.configs.len());
        for cfg in &suite.configs {
            timings.push(self.workload.estimate(&self.arch, g, cfg).ok()?);
        }
        Some(ProfileReport::from_timings(&timings))
    }

    pub fn measurements_taken(&self) -> u64 {
        self.measurements
    }

    /// Serialize the mutable backend state (noise stream + measurement
    /// counter) for a run-store checkpoint. Everything else — arch,
    /// sigma, seed, workload — is rebuilt from the run config.
    pub fn state_json(&self) -> crate::util::json::Json {
        use crate::util::json::{u64_hex, Json};
        Json::obj(vec![
            (
                "rng",
                Json::Arr(self.rng.state().iter().map(|&w| u64_hex(w)).collect()),
            ),
            ("measurements", u64_hex(self.measurements)),
        ])
    }

    /// Restore state captured by [`SimBackend::state_json`]; the
    /// resumed noise stream continues bit-identically.
    pub fn restore_state_json(&mut self, v: &crate::util::json::Json) -> Result<(), String> {
        use crate::util::json::parse_u64_hex;
        let words = v
            .get("rng")
            .and_then(|x| x.as_arr())
            .ok_or("sim state: missing rng")?;
        if words.len() != 4 {
            return Err(format!("sim state: expected 4 rng words, got {}", words.len()));
        }
        let mut s = [0u64; 4];
        for (i, w) in words.iter().enumerate() {
            s[i] = parse_u64_hex(w).map_err(|e| format!("sim state rng[{i}]: {e}"))?;
        }
        self.rng = Rng::from_state(s);
        self.measurements = parse_u64_hex(
            v.get("measurements").ok_or("sim state: missing measurements")?,
        )
        .map_err(|e| format!("sim state measurements: {e}"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome, Writeback};
    use crate::workload::FEEDBACK_CONFIGS;

    const CFG: GemmConfig = GemmConfig::new(4096, 1024, 4096);

    #[test]
    fn estimate_is_deterministic() {
        let g = seeds::human_oracle();
        assert_eq!(estimate(&MI300, &g, &CFG), estimate(&MI300, &g, &CFG));
    }

    #[test]
    fn invalid_genome_errors() {
        let g = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        assert!(estimate(&MI300, &g, &CFG).is_err());
    }

    #[test]
    fn seed_ordering_matches_paper() {
        // naive >> pytorch > evolved > oracle on every feedback config
        for cfg in FEEDBACK_CONFIGS {
            let t = |g: &KernelGenome| estimate(&MI300, g, &cfg).unwrap().total_us;
            let naive = t(&seeds::naive_hip());
            let lib = t(&seeds::pytorch_reference());
            let evolved = t(&seeds::paper_evolved());
            let oracle = t(&seeds::human_oracle());
            assert!(naive > lib, "{cfg}: naive {naive} <= lib {lib}");
            assert!(lib > evolved, "{cfg}: lib {lib} <= evolved {evolved}");
            assert!(evolved > oracle, "{cfg}: evolved {evolved} <= oracle {oracle}");
        }
    }

    #[test]
    fn bigger_problem_takes_longer() {
        let g = seeds::human_oracle();
        let small = estimate(&MI300, &g, &GemmConfig::new(4096, 512, 4096)).unwrap();
        let big = estimate(&MI300, &g, &GemmConfig::new(8192, 4096, 8192)).unwrap();
        assert!(big.total_us > small.total_us);
    }

    #[test]
    fn single_wave_writeback_costs() {
        let coop = seeds::human_oracle();
        let single = KernelGenome {
            writeback: Writeback::SingleWave,
            ..coop.clone()
        };
        let t_coop = estimate(&MI300, &coop, &CFG).unwrap().total_us;
        let t_single = estimate(&MI300, &single, &CFG).unwrap().total_us;
        assert!(t_single > t_coop);
    }

    #[test]
    fn double_buffer_helps_staged_kernels() {
        let single = KernelGenome {
            double_buffer: false,
            scale_cache: crate::genome::ScaleCache::Lds,
            ..seeds::human_oracle()
        };
        let double = KernelGenome {
            double_buffer: true,
            ..single.clone()
        };
        let t_single = estimate(&MI300, &single, &CFG).unwrap().total_us;
        let t_double = estimate(&MI300, &double, &CFG).unwrap().total_us;
        assert!(t_double < t_single);
    }

    #[test]
    fn noise_is_small_and_seeded() {
        let mut b1 = SimBackend::new(7);
        let mut b2 = SimBackend::new(7);
        let g = seeds::mfma_seed();
        let m1 = b1.measure(&g, &CFG).unwrap();
        let m2 = b2.measure(&g, &CFG).unwrap();
        assert_eq!(m1, m2, "same seed, same measurement");
        let m3 = b1.measure(&g, &CFG).unwrap();
        assert_ne!(m1, m3, "repeat measurements jitter");
        let clean = estimate(&MI300, &g, &CFG).unwrap().total_us;
        assert!((m1 / clean - 1.0).abs() < 0.15);
    }

    #[test]
    fn state_json_resumes_noise_stream_mid_run() {
        let g = seeds::mfma_seed();
        let mut live = SimBackend::new(21);
        for _ in 0..7 {
            live.measure(&g, &CFG).unwrap();
        }
        let snap = live.state_json().to_string();
        let tail: Vec<f64> = (0..10).map(|_| live.measure(&g, &CFG).unwrap()).collect();
        // a freshly constructed backend + restored state replays the
        // exact tail (the resume path's core property)
        let mut resumed = SimBackend::new(21);
        resumed
            .restore_state_json(&crate::util::json::parse(&snap).unwrap())
            .unwrap();
        assert_eq!(resumed.measurements_taken(), 7);
        let replay: Vec<f64> = (0..10).map(|_| resumed.measure(&g, &CFG).unwrap()).collect();
        assert_eq!(tail, replay);
        // lane forks after restore also agree
        let mut live2 = SimBackend::new(22);
        let mut resumed2 = SimBackend::new(22);
        live2.measure(&g, &CFG).unwrap();
        let s = live2.state_json();
        resumed2.restore_state_json(&s).unwrap();
        assert_eq!(
            live2.lane_clone(1).measure(&g, &CFG).unwrap(),
            resumed2.lane_clone(1).measure(&g, &CFG).unwrap()
        );
    }

    #[test]
    fn lane_clones_are_deterministic_and_decorrelated() {
        let g = seeds::mfma_seed();
        // identical parent state => identical forks, per lane
        let mut p1 = SimBackend::new(7);
        let mut p2 = SimBackend::new(7);
        let ma1 = p1.lane_clone(0).measure(&g, &CFG).unwrap();
        let ma2 = p2.lane_clone(0).measure(&g, &CFG).unwrap();
        assert_eq!(ma1, ma2, "same parent state + lane => same stream");
        // different lanes jitter independently
        let mut p3 = SimBackend::new(7);
        let mut lane0 = p3.lane_clone(0);
        let mut lane1 = p3.lane_clone(1);
        assert_ne!(
            lane0.measure(&g, &CFG).unwrap(),
            lane1.measure(&g, &CFG).unwrap(),
            "lanes are decorrelated"
        );
        // forking consumes the parent stream, so a second batch's
        // forks get fresh noise
        let mut p4 = SimBackend::new(7);
        let first = p4.lane_clone(0).measure(&g, &CFG).unwrap();
        let second = p4.lane_clone(0).measure(&g, &CFG).unwrap();
        assert_ne!(first, second, "successive forks advance the parent");
    }

    #[test]
    fn default_backend_times_the_paper_workload() {
        // SimBackend::new must stay bit-identical to the pre-registry
        // behaviour: fp8-gemm cost model, scales included
        let b = SimBackend::new(3);
        assert_eq!(b.workload().name(), "fp8-gemm");
        let g = seeds::human_oracle();
        assert_eq!(b.breakdown(&g, &CFG), estimate(&MI300, &g, &CFG));
    }

    #[test]
    fn estimate_gemm_scale_switch_only_drops_scale_traffic() {
        // scales-off is never slower, and differs exactly where the
        // scale vectors would have added fabric traffic
        let g = seeds::human_oracle();
        let with = estimate_gemm(&MI300, &g, &CFG, true).unwrap();
        let without = estimate_gemm(&MI300, &g, &CFG, false).unwrap();
        assert!(without.total_us <= with.total_us);
        assert_eq!(estimate(&MI300, &g, &CFG).unwrap(), with, "estimate == scales-on");
    }

    #[test]
    fn backend_with_workload_uses_that_cost_model() {
        use crate::workload::{lookup, GemmConfig};
        let w = lookup("row-softmax").expect("registered");
        let b = SimBackend::new(1).with_workload(w.clone());
        let g = crate::workload::softmax::fused_seed();
        let cfg = GemmConfig::new(8192, 8192, 8192);
        assert_eq!(b.breakdown(&g, &cfg), w.estimate(&MI300, &g, &cfg));
        // lane clones keep the workload
        let mut parent = b.clone();
        assert_eq!(parent.lane_clone(0).workload().name(), "row-softmax");
    }

    #[test]
    fn efficiency_fields_sane() {
        for (_, g) in seeds::all_seeds() {
            let t = estimate(&MI300, &g, &CFG).unwrap();
            assert!(t.compute_efficiency > 0.0 && t.compute_efficiency <= 1.0);
            assert!(t.grid_utilization > 0.0 && t.grid_utilization <= 1.0);
            assert!(t.occupancy_waves >= 1);
        }
    }
}
