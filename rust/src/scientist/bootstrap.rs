//! The bootstrap hardware-probing phase (paper §3 seed-kernel story,
//! §4.1, §4.3 and footnote 2).
//!
//! Before the evolutionary loop can use Matrix Cores, the paper's LLM
//! had to *discover* the MFMA intrinsic semantics "by actively probing
//! for compilation/execution errors until the actual behaviour was
//! revealed", distilling the results into the findings document. This
//! module reproduces that phase mechanically: a sequence of probe
//! kernels is submitted to the (black-box) evaluation platform; each
//! response — compile failure, wrong results, or a clean timing —
//! yields a distilled [`Finding`] entry.
//!
//! The probes are themselves genomes, so the bootstrap burns real
//! submissions from the same quota, exactly as in the paper (the
//! "extended deep-dive ... even human/AI co-creation of a working
//! kernel was very challenging").

use crate::agents::knowledge::{Finding, FindingsDoc};
use crate::eval::EvalBackend;
use crate::eval::EvalPlatform;
use crate::genome::{seeds, KernelGenome, ScaleCache, Swizzle, Writeback};
use crate::population::EvalOutcome;

/// One probing experiment: a kernel built to reveal one hardware fact.
#[derive(Debug, Clone)]
pub struct Probe {
    pub name: &'static str,
    pub genome: KernelGenome,
    /// The finding confirmed when the probe's outcome matches
    /// expectation.
    pub reveals: Finding,
    /// What outcome the hypothesis predicts ("works" vs "breaks").
    pub expect_success: bool,
    /// The digest line recorded when the hypothesis is confirmed.
    pub digest: &'static str,
}

/// The probe sequence the bootstrap runs, in order. Mirrors the
/// paper's narrative: first make MFMA work at all, then establish the
/// safety conditions of the advanced LDS tricks.
pub fn probe_sequence() -> Vec<Probe> {
    let mfma = seeds::mfma_seed();
    vec![
        Probe {
            name: "mfma-compiles-and-computes",
            genome: mfma.clone(),
            reveals: Finding::MfmaSemantics,
            expect_success: true,
            digest: "MFMA 32x32x16 fp8 intrinsics probed: fragment rows spread \
                     across wave quarters; accumulate in f32, cast bf16 on store.",
        },
        Probe {
            name: "swizzle-layout-accepted",
            genome: KernelGenome {
                swizzle: Swizzle::Xor,
                lds_pad: 0,
                ..mfma.clone()
            },
            reveals: Finding::SwizzleLayouts,
            expect_success: true,
            digest: "XOR-swizzled LDS columns match rocwmma::load_matrix_sync \
                     expectations; do not combine with row padding.",
        },
        Probe {
            name: "scale-repurpose-unsafe-without-pingpong",
            // hypothesis test by *negative* probe: re-purposing the live
            // LDS buffer without double buffering must corrupt results
            genome: KernelGenome {
                scale_cache: ScaleCache::LdsRepurposed,
                double_buffer: false,
                ..mfma.clone()
            },
            reveals: Finding::LdsRepurposeTrick,
            expect_success: false,
            digest: "Consumed A/B LDS buffers may be overlaid with f32 scales \
                     once the pipeline stage has retired (requires ping-pong).",
        },
    ]
}

/// Outcome of the bootstrap phase.
#[derive(Debug, Clone)]
pub struct BootstrapReport {
    pub findings: FindingsDoc,
    pub submissions_used: u64,
    /// (probe name, confirmed?) per probe.
    pub transcript: Vec<(&'static str, bool)>,
}

/// Run the probing phase against a platform. Every probe costs a real
/// submission; confirmed hypotheses become findings.
pub fn run_bootstrap<B: EvalBackend>(platform: &mut EvalPlatform<B>) -> BootstrapReport {
    let mut findings = FindingsDoc::default();
    let mut transcript = Vec::new();
    let before = platform.submissions();
    for probe in probe_sequence() {
        let outcome = platform.submit(&probe.genome);
        let succeeded = matches!(outcome, EvalOutcome::Timings(_));
        let confirmed = succeeded == probe.expect_success;
        if confirmed {
            findings.record(probe.reveals, probe.digest);
        }
        transcript.push((probe.name, confirmed));
    }
    BootstrapReport {
        findings,
        submissions_used: platform.submissions() - before,
        transcript,
    }
}

/// Extra "probe" kernels the negative experiments leave behind — the
/// paper notes even failed submissions inform the system. These are
/// returned so the caller may (or may not) keep them in the ledger.
pub fn probe_genomes() -> Vec<(String, KernelGenome)> {
    probe_sequence()
        .into_iter()
        .map(|p| (format!("bootstrap probe: {}", p.name), p.genome))
        .collect()
}

/// A correctness-hazard showcase probe used in docs/tests: the
/// multi-wave accumulation race the single-wave writeback avoids.
pub fn race_probe() -> KernelGenome {
    KernelGenome {
        waves_per_block: 4,
        acc_in_regs: false,
        writeback: Writeback::Cooperative,
        ..seeds::mfma_seed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::PlatformConfig;
    use crate::sim::SimBackend;

    fn platform() -> EvalPlatform<SimBackend> {
        EvalPlatform::new(SimBackend::new(5), PlatformConfig::default())
    }

    #[test]
    fn bootstrap_confirms_all_findings_on_sim() {
        let mut p = platform();
        let report = run_bootstrap(&mut p);
        assert!(report.findings.has(Finding::MfmaSemantics));
        assert!(report.findings.has(Finding::SwizzleLayouts));
        assert!(report.findings.has(Finding::LdsRepurposeTrick));
        assert_eq!(report.submissions_used, 3);
        assert!(report.transcript.iter().all(|(_, ok)| *ok));
    }

    #[test]
    fn negative_probe_actually_fails_on_platform() {
        // the scale-repurpose-without-pingpong probe must come back as
        // an incorrect result, not a timing
        let mut p = platform();
        let probe = &probe_sequence()[2];
        assert!(!probe.expect_success);
        let outcome = p.submit(&probe.genome);
        assert!(matches!(outcome, EvalOutcome::IncorrectResult(_)));
    }

    #[test]
    fn bootstrap_consumes_quota() {
        let mut p = EvalPlatform::new(
            SimBackend::new(5),
            PlatformConfig {
                submission_quota: Some(10),
                ..Default::default()
            },
        );
        let report = run_bootstrap(&mut p);
        assert_eq!(p.submissions(), report.submissions_used);
    }

    #[test]
    fn race_probe_is_hazardous() {
        assert!(race_probe().correctness_hazard().is_some());
        assert!(race_probe().validate().is_ok());
    }

    #[test]
    fn probe_genomes_labelled() {
        let probes = probe_genomes();
        assert_eq!(probes.len(), 3);
        assert!(probes[0].0.contains("bootstrap probe"));
    }
}
