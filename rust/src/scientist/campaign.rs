//! The campaign driver: several workloads optimized concurrently.
//!
//! A campaign runs one full [`ScientistRun`] per requested workload,
//! each on its own OS thread with its own evaluation platform — its own
//! submission quota, simulated wall clock, and **per-workload eval
//! cache** (genome fingerprints are only meaningful within one
//! workload's cost model, so caches are never shared). Within each run,
//! the configured scheduler drives the executor lanes — lockstep
//! barrier batches by default, or the steady-state pipeline
//! (`base.pipeline = true`, DESIGN.md §8) whose per-lane worker
//! threads then stack under the campaign's per-workload threads — so a
//! campaign composes both parallelism levels: across workloads
//! (threads here) and across submissions (executor lanes, `DESIGN.md`
//! §3).
//!
//! Campaigns are deterministic: every run is seeded independently from
//! its own `RunConfig`, so results are bit-identical to running each
//! workload standalone, regardless of thread interleaving (locked in by
//! the tests below).
//!
//! With a `[store] dir` configured, each workload journals to its own
//! per-workload ledger under `<dir>/<workload>/` (eval caches and RNG
//! streams are per-workload, so ledgers must be too) and the campaign
//! writes a `campaign.json` manifest naming the members in request
//! order — [`resume_campaign`] continues every member after a crash.

use std::path::Path;
use std::sync::Arc;

use super::{RunOutcome, ScientistRun};
use crate::config::RunConfig;
use crate::store::FederationSnapshot;
use crate::workload::{self, Workload};

/// Configuration of a multi-workload campaign.
#[derive(Debug, Clone)]
pub struct CampaignConfig {
    /// Registry keys of the workloads to run (order is preserved in
    /// the results).
    pub workloads: Vec<String>,
    /// Per-run configuration template; `base.workload` is overridden
    /// per entry.
    pub base: RunConfig,
}

impl CampaignConfig {
    /// A campaign over every registered workload.
    pub fn all_workloads(base: RunConfig) -> Self {
        CampaignConfig {
            workloads: workload::registry().iter().map(|w| w.name().to_string()).collect(),
            base,
        }
    }
}

/// One workload's completed run inside a campaign.
#[derive(Debug, Clone)]
pub struct WorkloadRunResult {
    pub workload: String,
    pub outcome: RunOutcome,
    /// (hits, misses) of this run's private eval cache.
    pub cache_stats: (u64, u64),
}

/// All campaign results, in the requested workload order.
#[derive(Debug, Clone)]
pub struct CampaignOutcome {
    pub results: Vec<WorkloadRunResult>,
}

impl CampaignOutcome {
    /// Total submissions spent across every workload.
    pub fn total_submissions(&self) -> u64 {
        self.results.iter().map(|r| r.outcome.submissions).sum()
    }

    /// Campaign wall clock: the slowest workload's simulated platform
    /// time (runs execute concurrently).
    pub fn wall_clock_s(&self) -> f64 {
        self.results
            .iter()
            .map(|r| r.outcome.wall_clock_s)
            .fold(0.0, f64::max)
    }
}

/// Run every requested workload's scientist loop concurrently (one OS
/// thread per workload, each over its own multi-lane platform) and
/// collect the outcomes in request order.
pub fn run_campaign(config: &CampaignConfig) -> Result<CampaignOutcome, String> {
    if config.workloads.is_empty() {
        return Err("campaign has no workloads".into());
    }
    for name in &config.workloads {
        if workload::lookup(name).is_none() {
            return Err(format!("unknown workload '{name}'"));
        }
    }
    if let Some(dir) = &config.base.store_dir {
        // manifest first: a crash during the very first iteration must
        // still leave a resumable campaign directory
        crate::store::write_campaign_manifest(Path::new(dir), &config.workloads)?;
    }
    // Load the federated archive ONCE, before any member thread spawns,
    // and Arc-share the snapshot: members that finish early publish new
    // run files into the store directory, and a member that self-loaded
    // mid-campaign would see a different archive depending on thread
    // timing — breaking campaign determinism (DESIGN.md §12).
    let fed_snapshot: Option<Arc<FederationSnapshot>> = match &config.base.federation_dir {
        Some(dir) => Some(Arc::new(FederationSnapshot::load(Path::new(dir))?)),
        None => None,
    };
    let runs: Vec<Result<WorkloadRunResult, String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = config
            .workloads
            .iter()
            .map(|name| {
                let cfg = RunConfig {
                    workload: name.clone(),
                    // per-workload ledger: caches and RNG streams are
                    // workload-private, so persistence is too
                    store_dir: config
                        .base
                        .store_dir
                        .as_ref()
                        .map(|d| crate::store::campaign_member_dir(d, name)),
                    ..config.base.clone()
                };
                let snapshot = fed_snapshot.clone();
                scope.spawn(move || -> Result<WorkloadRunResult, String> {
                    let mut run = ScientistRun::new_with_snapshot(cfg, snapshot)?;
                    let outcome = run.run_to_completion()?;
                    Ok(WorkloadRunResult {
                        workload: name.clone(),
                        cache_stats: run.platform.cache_stats(),
                        outcome,
                    })
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("campaign worker panicked"))
            .collect()
    });
    let mut results = Vec::with_capacity(runs.len());
    for r in runs {
        results.push(r?);
    }
    Ok(CampaignOutcome { results })
}

/// Resume every member of a crashed campaign from `<dir>` (one
/// [`ScientistRun::resume`] per manifest entry, concurrently — the
/// same thread-per-workload shape as [`run_campaign`]) and run each to
/// completion. Members that already finished simply recompute their
/// outcome from the final checkpoint. `halt_after` re-arms the
/// simulated-crash knob on every member (it is never persisted), so
/// repeated crash-recovery is testable for campaigns too.
pub fn resume_campaign(dir: &Path, halt_after: Option<u64>) -> Result<CampaignOutcome, String> {
    let workloads = crate::store::read_campaign_manifest(dir)?
        .ok_or_else(|| format!("{}: no campaign manifest", dir.display()))?;
    if workloads.is_empty() {
        return Err("campaign manifest has no workloads".into());
    }
    let runs: Vec<Result<WorkloadRunResult, String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = workloads
            .iter()
            .map(|name| {
                let member = dir.join(name);
                scope.spawn(move || -> Result<WorkloadRunResult, String> {
                    // each member re-attaches the federated archive
                    // itself inside `resume`; files published by sibling
                    // members cannot perturb it (the eval-cache merge is
                    // workload-filtered and warm-start seeding never
                    // re-runs on resume), so no shared snapshot is needed
                    let mut run = ScientistRun::resume(&member)?;
                    run.config.halt_after = halt_after;
                    let outcome = run.run_to_completion()?;
                    Ok(WorkloadRunResult {
                        workload: name.clone(),
                        cache_stats: run.platform.cache_stats(),
                        outcome,
                    })
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("campaign worker panicked"))
            .collect()
    });
    let mut results = Vec::with_capacity(runs.len());
    for r in runs {
        results.push(r?);
    }
    Ok(CampaignOutcome { results })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(budget: u64) -> RunConfig {
        RunConfig {
            max_submissions: budget,
            ..RunConfig::default()
        }
    }

    #[test]
    fn campaign_runs_every_requested_workload_in_order() {
        let cfg = CampaignConfig {
            workloads: vec!["row-softmax".into(), "fp8-gemm".into()],
            base: base(10),
        };
        let out = run_campaign(&cfg).unwrap();
        assert_eq!(out.results.len(), 2);
        assert_eq!(out.results[0].workload, "row-softmax");
        assert_eq!(out.results[1].workload, "fp8-gemm");
        assert_eq!(out.results[0].outcome.workload, "row-softmax");
        assert!(out.total_submissions() > 0);
        assert!(out.wall_clock_s() > 0.0);
    }

    #[test]
    fn campaign_matches_standalone_runs_bit_for_bit() {
        // per-workload caches + independent seeding make the campaign
        // deterministic regardless of thread interleaving
        let cfg = CampaignConfig::all_workloads(base(14));
        let campaign = run_campaign(&cfg).unwrap();
        for r in &campaign.results {
            let solo_cfg = RunConfig {
                workload: r.workload.clone(),
                ..base(14)
            };
            let mut solo = ScientistRun::new(solo_cfg).unwrap();
            let solo_out = solo.run_to_completion().unwrap();
            assert_eq!(r.outcome.best_id, solo_out.best_id, "{}", r.workload);
            assert_eq!(
                r.outcome.best_geomean_us, solo_out.best_geomean_us,
                "{}",
                r.workload
            );
            assert_eq!(r.outcome.submissions, solo_out.submissions, "{}", r.workload);
            assert_eq!(r.cache_stats, solo.platform.cache_stats(), "{}", r.workload);
        }
    }

    #[test]
    fn pipelined_campaign_matches_standalone_pipeline_runs() {
        // the pipeline scheduler composes under the campaign's
        // per-workload threads without breaking the bit-identity
        // guarantee: stream worker threads are private to each run
        let base = RunConfig {
            eval_parallelism: 2,
            pipeline: true,
            ..base(16)
        };
        let cfg = CampaignConfig::all_workloads(base.clone());
        let campaign = run_campaign(&cfg).unwrap();
        for r in &campaign.results {
            let solo_cfg = RunConfig {
                workload: r.workload.clone(),
                ..base.clone()
            };
            let mut solo = ScientistRun::new(solo_cfg).unwrap();
            let solo_out = solo.run_to_completion().unwrap();
            assert_eq!(r.outcome.best_id, solo_out.best_id, "{}", r.workload);
            assert_eq!(
                r.outcome.best_geomean_us, solo_out.best_geomean_us,
                "{}",
                r.workload
            );
            assert_eq!(r.outcome.submissions, solo_out.submissions, "{}", r.workload);
            assert!(r.outcome.pipeline.pipelined, "{}", r.workload);
        }
    }

    #[test]
    fn campaign_rejects_unknown_and_empty() {
        let bad = CampaignConfig {
            workloads: vec!["fp8-gemm".into(), "nope".into()],
            base: base(10),
        };
        assert!(run_campaign(&bad).unwrap_err().contains("unknown workload"));
        let empty = CampaignConfig {
            workloads: vec![],
            base: base(10),
        };
        assert!(run_campaign(&empty).is_err());
    }
}
