//! The orchestrator: the Figure-1 loop.
//!
//! ```text
//! seed kernels -> population
//! repeat until submission budget:
//!   (1) Evolutionary Selector  -> Base + Reference (+ rationale)
//!   (2) Experiment Designer    -> 10 avenues -> 5 plans -> pick 3
//!   (3) Kernel Writer x3       -> children (+ self-reports)
//!   (4) submit the iteration's children AS A BATCH to the evaluation
//!       platform's multi-lane executor -> correctness + 6-config
//!       timings -> back into the population
//! ```
//!
//! With `eval_parallelism = 1` (the paper's good-citizen default) the
//! batch degenerates to exactly the sequential submission path: the
//! same writer-RNG and backend-RNG call sequences, hence the same
//! population trajectory bit-for-bit (see `tests/executor.rs`). Higher
//! lane counts run the children on real worker threads (paper §5.1's
//! counterfactual; DESIGN.md §3).
//!
//! Two schedulers can drive the loop (DESIGN.md §8):
//!
//! * **Lockstep** (default, the paper's shape): plan a whole
//!   iteration, submit its children as one barrier batch, wait for
//!   everything, plan again. With more lanes than children the spare
//!   lanes idle at the barrier — modeled by [`crate::eval::EvalPlatform::sync_lanes`].
//! * **Steady-state pipeline** (`platform.pipeline = true`,
//!   [`pipeline`]): a queue of planned experiments feeds the lanes
//!   through the platform's completion-driven stream API, and the
//!   selector/designer/writer stages run again the moment the queue
//!   can no longer fill a freed lane. At `eval_parallelism = 1` its
//!   trajectory is bit-identical to lockstep (`tests/pipeline.rs`).
//!
//! Everything the agents see flows through the population ledger —
//! they never touch the simulator's internals, matching the paper's
//! black-box constraint.

pub mod bootstrap;
pub mod campaign;
pub mod pipeline;

use std::collections::{HashSet, VecDeque};
use std::path::Path;
use std::sync::Arc;

pub use pipeline::PipelineStats;
use pipeline::SchedCounters;

use crate::agents::{AgentSuite, FindingsDoc, KernelWrite, Selection};
use crate::analysis::{self, Diagnostic, Severity};
use crate::config::RunConfig;
use crate::eval::{
    EvalBackend, EvalPlatform, FaultRecord, FaultSummary, FaultyBackend, PlatformConfig,
    ScreenConfig, ScreenTier,
};
use crate::gpu::MI300;
use crate::metrics::ConvergenceCurve;
use crate::population::{EvalOutcome, Individual, Population};
use crate::sim::SimBackend;
use crate::store::{
    config_digest, federation, journal, Checkpoint, ExperimentRecord, FedEntry,
    FederationSnapshot, FederationStats, JournalRecord, PendingPlan, PlanRecord, RunStore,
};
use crate::workload::{self, Workload};

/// One iteration's transcript (what the paper's appendices show).
#[derive(Debug, Clone)]
pub struct IterationLog {
    pub iteration: usize,
    pub selection: Selection,
    pub avenue_names: Vec<String>,
    pub chosen_experiments: Vec<String>,
    pub submitted_ids: Vec<String>,
}

/// Final result of a scientist run.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    /// Registry key of the workload this run optimized.
    pub workload: String,
    /// Best feedback geomean found (microseconds).
    pub best_geomean_us: f64,
    pub best_id: String,
    pub submissions: u64,
    pub wall_clock_s: f64,
    pub curve: ConvergenceCurve,
    /// Leaderboard-suite geomean of the best kernel, if computed.
    pub leaderboard_us: Option<f64>,
    /// Scheduler-level throughput stats: lane occupancy, pipeline
    /// depth, planning rounds (DESIGN.md §8).
    pub pipeline: PipelineStats,
    /// Bottleneck mix over every profiled submission (DESIGN.md §11).
    /// `None` unless `[profile] guided` is on — the mix is derived
    /// from always-journaled per-run profiles, but surfacing it in
    /// outcomes/reports is part of the knob's surface area so that
    /// guided-off output stays byte-identical to pre-profile builds.
    pub profile_mix: Option<crate::sim::ProfileMix>,
    /// Federated-archive counters (DESIGN.md §12): cross-run cache hits
    /// and warm-start elites injected. `None` when `[federation]` is
    /// off, keeping off-run reports byte-identical to pre-federation
    /// builds.
    pub federation: Option<FederationStats>,
    /// Fault-injection & recovery summary (DESIGN.md §14): the
    /// platform's committed fault counters plus the schedulers' retry
    /// decisions. `None` when `[faults]` is off, keeping off-run
    /// reports byte-identical to pre-faults builds.
    pub faults: Option<FaultSummary>,
}

/// A full scientist run: platform + population + agents + loop state.
pub struct ScientistRun<B: EvalBackend> {
    pub config: RunConfig,
    /// The workload being optimized (seed genomes, suites, leaderboard
    /// basis all come from here).
    pub workload: Arc<dyn Workload>,
    pub platform: EvalPlatform<B>,
    pub population: Population,
    pub agents: AgentSuite,
    pub curve: ConvergenceCurve,
    pub logs: Vec<IterationLog>,
    iteration: usize,
    /// Scheduler counters (planning rounds, duplicate replans, depth
    /// samples) shared by the lockstep and pipeline drivers.
    sched: SchedCounters,
    /// Durable run store (journal + checkpoints, DESIGN.md §9); `None`
    /// unless the config names a `[store] dir`.
    store: Option<RunStore>,
    /// Scheduler state reconstructed by [`ScientistRun::resume`],
    /// consumed by the first `run_to_completion` call.
    resume_state: Option<ResumeState>,
    /// Set when `config.halt_after` aborted the scheduler (simulated
    /// crash: no final checkpoint was written).
    halted: bool,
    /// Live federation context; `None` unless the config names a
    /// `[federation] dir` (DESIGN.md §12).
    federation: Option<FederationCtx>,
}

/// Live federation state: the loaded cross-run snapshot, this run's
/// (workload, config) digest, and the warm-start injection count.
struct FederationCtx {
    snapshot: Arc<FederationSnapshot>,
    digest: u64,
    warm_injected: u64,
}

/// Experiment-label prefix for warm-start elites. `resume` recovers
/// the injection count by scanning the rebuilt ledger for it, so the
/// label doubles as durable provenance — change it and old stores
/// under-count warm starts after resume.
const WARM_START_LABEL: &str = "federated warm-start elite";

/// Mid-run scheduler state carried across a resume: the stall streak,
/// whether planning had gone dead, and every planned-but-uncommitted
/// experiment (in dispatch order — the resumed pipeline re-feeds these
/// through the normal submission path before planning anything new).
pub(crate) struct ResumeState {
    pub stalls: u32,
    pub planning_dead: bool,
    pub pending: Vec<PendingResume>,
    /// How many `pending` entries were in flight at the checkpoint:
    /// their depth samples are already in the restored counters, so the
    /// resumed feed skips re-sampling exactly that many dispatches.
    pub skip_depth: usize,
    /// Candidates that sat in the screen tier's partial rung at the
    /// checkpoint, in submission order. The resumed pipeline re-scores
    /// them (the analytic model is pure, so scores recompute exactly)
    /// and refills the rung before planning anything new (DESIGN.md §10).
    pub screen_pending: Vec<(PlannedExperiment, usize)>,
}

/// One planned-but-uncommitted experiment carried across a resume,
/// with its recovery-layer retry metadata (DESIGN.md §14). On a
/// faults-off run `attempt`/`not_before_s` are always `0`/`0.0` and
/// `ticket` is always `None` — the checkpoint omits them entirely, so
/// off-store bytes stay identical to pre-faults output.
pub(crate) struct PendingResume {
    pub experiment: PlannedExperiment,
    pub log_pos: usize,
    /// Retry attempt the dispatch was (or will be) submitted as.
    pub attempt: u32,
    /// Earliest virtual start time (retry backoff), `0.0` = none.
    pub not_before_s: f64,
    /// Platform ticket, persisted only on faults-mode checkpoints for
    /// entries that were in flight: the platform checkpoint carries
    /// their pending evaluations as data, so a resume re-attaches by
    /// ticket instead of re-submitting (DESIGN.md §14).
    pub ticket: Option<u64>,
}

/// Borrowed checkpoint view of one pending experiment — what the
/// schedulers hand [`ScientistRun::write_checkpoint`] (see
/// [`PendingResume`] for the field semantics).
pub(crate) struct PendingRef<'a> {
    pub experiment: &'a PlannedExperiment,
    pub log_pos: usize,
    pub attempt: u32,
    pub not_before_s: f64,
    pub ticket: Option<u64>,
}

/// Evaluation provenance of one ledger entry, journaled alongside it
/// so the platform log and eval cache are reconstructible.
pub(crate) struct Provenance {
    /// 1-based submission count at which the result became available —
    /// explicit (rather than read from the platform) so batch
    /// submissions attribute each child to its own submission index on
    /// the convergence curve.
    pub submitted_at: u64,
    pub cached: bool,
    pub submission_index: Option<u64>,
    /// Producing planning round (`logs` position); `None` for seeds
    /// and bootstrap probes.
    pub plan: Option<usize>,
    /// Whether this entry passed through the analytic screen tier
    /// before submission (always false while `[screen]` is disabled).
    pub screened: bool,
    /// Error codes of the lint diagnostics that rejected this entry at
    /// the pre-submission gate (DESIGN.md §13); empty for everything
    /// that actually reached the platform — and always empty while
    /// `[lint] gate` is off.
    pub lint: Vec<String>,
}

impl Provenance {
    /// A sequential inline submission (seeds, bootstrap probes).
    fn seed(submitted_at: u64) -> Provenance {
        Provenance {
            submitted_at,
            cached: false,
            submission_index: Some(submitted_at - 1),
            plan: None,
            screened: false,
            lint: Vec::new(),
        }
    }
}

/// One writer child waiting for an evaluation lane: everything the
/// ledger needs once its result lands. Produced by
/// [`ScientistRun::plan_group`], consumed by both schedulers.
pub(crate) struct PlannedExperiment {
    pub base_id: String,
    pub reference_id: String,
    pub description: String,
    pub write: KernelWrite,
    /// Genome content hash ([`crate::genome::KernelGenome::fingerprint_hash`]),
    /// computed once at planning — the dedup keys everywhere downstream
    /// (queue reservations, in-flight sets, checkpoints) reuse it.
    pub fingerprint: u64,
}

/// One select → design → write planning round.
pub(crate) struct PlannedGroup {
    pub selection: Selection,
    pub avenue_names: Vec<String>,
    pub chosen_experiments: Vec<String>,
    pub experiments: Vec<PlannedExperiment>,
    /// Writer children discarded as duplicates during this round.
    pub duplicates_skipped: u64,
    /// Children the static lint gate diverted away from submission,
    /// with their `Error` diagnostics (DESIGN.md §13). Each scheduler
    /// ledgers these as compile failures — no lane, no quota. Always
    /// empty while `[lint] gate` is off.
    pub lint_rejected: Vec<(PlannedExperiment, Vec<Diagnostic>)>,
}

/// Checkpoint form of one planned-but-uncommitted experiment.
/// `attempt`/`not_before_s`/`ticket` are the recovery layer's retry
/// metadata (always `0`/`0.0`/`None` on a faults-off run — the store
/// omits the zero values, keeping off-checkpoint bytes identical).
fn pending_plan(
    e: &PlannedExperiment,
    log_pos: usize,
    attempt: u32,
    not_before_s: f64,
    ticket: Option<u64>,
) -> PendingPlan {
    PendingPlan {
        base_id: e.base_id.clone(),
        reference_id: e.reference_id.clone(),
        description: e.description.clone(),
        fingerprint: e.fingerprint,
        log_pos,
        genome: e.write.genome.clone(),
        applied: e.write.applied.clone(),
        skipped: e.write.skipped.clone(),
        repairs: e.write.repairs.clone(),
        report: e.write.report.clone(),
        diff: e.write.diff.clone(),
        attempt,
        not_before_s,
        ticket,
    }
}

/// Rebuild a planned experiment (with its planning-round position and
/// retry metadata) from its checkpointed form.
fn planned_from_pending(p: &PendingPlan) -> PendingResume {
    PendingResume {
        experiment: PlannedExperiment {
            base_id: p.base_id.clone(),
            reference_id: p.reference_id.clone(),
            description: p.description.clone(),
            write: KernelWrite {
                genome: p.genome.clone(),
                applied: p.applied.clone(),
                skipped: p.skipped.clone(),
                repairs: p.repairs.clone(),
                report: p.report.clone(),
                diff: p.diff.clone(),
            },
            fingerprint: p.fingerprint,
        },
        log_pos: p.log_pos,
        attempt: p.attempt,
        not_before_s: p.not_before_s,
        ticket: p.ticket,
    }
}

/// Build the simulator-backed evaluation backend for `config`: the
/// MI300 simulator wrapped in the deterministic fault decorator
/// (DESIGN.md §14). With `[faults]` off — the default — the wrapper is
/// pure delegation (zero RNG draws, zero state), so every off-run is
/// bit-identical to a build without the fault model.
fn sim_backend(
    config: &RunConfig,
    workload: Arc<dyn Workload>,
) -> FaultyBackend<SimBackend> {
    FaultyBackend::new(
        SimBackend::new(config.seed)
            .with_noise(config.noise_sigma)
            .with_workload(workload),
        config.faults.clone(),
        config.seed,
    )
}

impl ScientistRun<FaultyBackend<SimBackend>> {
    /// The paper's setup: simulated MI300 platform, surrogate agents,
    /// the configured workload's seed kernels (`config.workload`
    /// defaults to the paper's fp8 GEMM, reproducing §3 exactly).
    pub fn new(config: RunConfig) -> Result<Self, String> {
        Self::new_with_snapshot(config, None)
    }

    /// Like [`ScientistRun::new`], but share a pre-loaded federation
    /// snapshot. Campaigns load the federated store **once** before
    /// spawning members and `Arc`-share it so every member sees the
    /// same archive contents regardless of thread launch order
    /// (DESIGN.md §12). `None` falls back to self-loading from
    /// `config.federation_dir` (and to no federation when that is
    /// unset).
    pub fn new_with_snapshot(
        config: RunConfig,
        snapshot: Option<Arc<FederationSnapshot>>,
    ) -> Result<Self, String> {
        let workload = workload::lookup(&config.workload)
            .ok_or_else(|| format!("unknown workload '{}'", config.workload))?;
        let backend = sim_backend(&config, workload.clone());
        let platform = EvalPlatform::new(
            backend,
            PlatformConfig {
                reps_per_config: config.reps_per_config,
                parallelism: config.eval_parallelism,
                submission_quota: Some(config.max_submissions),
                cache_results: config.eval_cache,
            },
        )
        .with_feedback_suite(workload.feedback_suite());
        Self::with_platform_snapshot(config, platform, snapshot)
    }

    /// Reconstruct a crashed (or halted) run from its store directory
    /// and return it ready to continue **bit-identically** to a run
    /// that was never interrupted (DESIGN.md §9; `tests/resume.rs`).
    ///
    /// The journal is truncated to the last checkpoint's consistent
    /// prefix; the ledger, transcripts, convergence curve, platform
    /// log, and eval cache are rebuilt from it; RNG streams (surrogate
    /// LLM + simulator noise, including re-forked stream-lane workers)
    /// restore from the checkpoint. Bootstrap probing and seeding are
    /// **not** re-run — their results are already in the ledger.
    pub fn resume(dir: &Path) -> Result<Self, String> {
        let (mut store, cp, records) = RunStore::open_for_resume(dir)?;
        let mut config = cp.config.clone();
        config.store_dir = Some(dir.display().to_string());
        let workload = workload::lookup(&config.workload)
            .ok_or_else(|| format!("unknown workload '{}'", config.workload))?;
        let backend = sim_backend(&config, workload.clone());
        let mut platform = EvalPlatform::new(
            backend,
            PlatformConfig {
                reps_per_config: config.reps_per_config,
                parallelism: config.eval_parallelism,
                submission_quota: Some(config.max_submissions),
                cache_results: config.eval_cache,
            },
        )
        .with_feedback_suite(workload.feedback_suite());
        // the recovery layer must be live BEFORE restore_checkpoint:
        // a chaos checkpoint carries fault-model state the restore
        // refuses to drop silently (DESIGN.md §14)
        if config.faults.enabled {
            platform.enable_faults(config.faults.clone());
        }
        let agents = AgentSuite::paper(config.seed)
            .with_llm_config(config.llm.clone())
            .with_selection_policy(config.selection_policy)
            .with_experiment_rule(config.experiment_rule)
            .with_knowledge(config.knowledge);
        let ledger = journal::rebuild(
            &records,
            platform.feedback_suite.configs.clone(),
            true,
        )?;
        if ledger.population.len() != cp.ledger_len || ledger.logs.len() != cp.logs_len {
            return Err(format!(
                "journal rebuilt {} ledger entries / {} transcripts but the checkpoint \
                 recorded {} / {} — store corrupted",
                ledger.population.len(),
                ledger.logs.len(),
                cp.ledger_len,
                cp.logs_len
            ));
        }
        let mut run = ScientistRun {
            config,
            workload,
            platform,
            population: ledger.population,
            agents,
            curve: ledger.curve,
            logs: ledger.logs,
            iteration: cp.iteration,
            sched: SchedCounters::restore(&cp.sched),
            store: None,
            resume_state: Some(ResumeState {
                stalls: cp.stalls,
                planning_dead: cp.planning_dead,
                pending: cp.pending.iter().map(planned_from_pending).collect(),
                skip_depth: cp.skip_depth,
                screen_pending: cp
                    .screen_pending
                    .iter()
                    .map(|p| {
                        let r = planned_from_pending(p);
                        (r.experiment, r.log_pos)
                    })
                    .collect(),
            }),
            halted: false,
            federation: None,
        };
        // Re-attach the federated archive from the persisted config
        // BEFORE restoring the checkpoint: attachment requires a
        // platform with no submission history, and the restored run
        // must consult the same cross-run results the original did.
        // The warm-start count is recovered from the ledger (injected
        // elites journal with a recognizable experiment label).
        if let Some(fdir) = run.config.federation_dir.clone() {
            let snap = Arc::new(FederationSnapshot::load(Path::new(&fdir))?);
            let digest = config_digest(&run.config, run.workload.cost_model_version());
            run.platform
                .attach_federation(snap.results_for(run.workload.name(), digest));
            let warm_injected = run
                .population
                .members()
                .iter()
                .filter(|m| m.experiment.starts_with(WARM_START_LABEL))
                .count() as u64;
            run.federation = Some(FederationCtx {
                snapshot: snap,
                digest,
                warm_injected,
            });
        }
        run.agents.llm.restore_rng(cp.llm_rng);
        run.agents.knowledge.findings = FindingsDoc::from_json(&cp.findings)?;
        run.platform.restore_checkpoint(
            &cp.platform,
            ledger.log_entries,
            ledger.cache_entries,
            &ledger.committed_genomes,
        )?;
        // every validation passed — only now discard the stale journal
        // tail (a failed resume must leave the full history on disk)
        store.commit_truncation()?;
        run.store = Some(store);
        Ok(run)
    }
}

impl<B: EvalBackend + Send> ScientistRun<B> {
    /// Construct over an arbitrary backend (the PJRT example uses this).
    /// `Send` is required because step (4) submits each iteration's
    /// children as a batch through the multi-lane executor.
    pub fn with_platform(
        config: RunConfig,
        platform: EvalPlatform<B>,
    ) -> Result<Self, String> {
        Self::with_platform_snapshot(config, platform, None)
    }

    /// [`ScientistRun::with_platform`] with an optional pre-loaded
    /// federation snapshot (see [`ScientistRun::new_with_snapshot`]).
    pub fn with_platform_snapshot(
        config: RunConfig,
        mut platform: EvalPlatform<B>,
        snapshot: Option<Arc<FederationSnapshot>>,
    ) -> Result<Self, String> {
        // Switch on the recovery layer before ANY submission: per-lane
        // health, quarantine, and the fault-event outbox (DESIGN.md
        // §14). Injection itself only fires when the backend is an
        // enabled [`FaultyBackend`]; over any other backend the layer
        // just tracks health that never degrades.
        if config.faults.enabled {
            platform.enable_faults(config.faults.clone());
        }
        // the backend is the single source of truth for what is being
        // evaluated; a config naming a different workload would submit
        // one family's seeds to another family's cost model
        let workload = platform.workload();
        if workload.name() != config.workload {
            return Err(format!(
                "config workload '{}' does not match the platform backend's workload '{}'",
                config.workload,
                workload.name()
            ));
        }
        let agents = AgentSuite::paper(config.seed)
            .with_llm_config(config.llm.clone())
            .with_selection_policy(config.selection_policy)
            .with_experiment_rule(config.experiment_rule)
            .with_knowledge(config.knowledge);
        let population = Population::new(platform.feedback_suite.configs.clone());
        let mut run = ScientistRun {
            config,
            workload,
            platform,
            population,
            agents,
            curve: ConvergenceCurve::default(),
            logs: Vec::new(),
            iteration: 0,
            sched: SchedCounters::default(),
            store: None,
            resume_state: None,
            halted: false,
            federation: None,
        };
        // Attach the federated archive before ANY submission (seeds,
        // probes, warm-start) so every genome this run ever evaluates
        // can be served from cross-run history (DESIGN.md §12).
        let snapshot = match (&run.config.federation_dir, snapshot) {
            (Some(dir), None) => Some(Arc::new(FederationSnapshot::load(Path::new(dir))?)),
            (Some(_), pre @ Some(_)) => pre,
            // a snapshot with no [federation] dir configured is inert:
            // off must mean off
            (None, _) => None,
        };
        if let Some(snap) = snapshot {
            let digest = config_digest(&run.config, run.workload.cost_model_version());
            run.platform
                .attach_federation(snap.results_for(run.workload.name(), digest));
            run.federation = Some(FederationCtx {
                snapshot: snap,
                digest,
                warm_injected: 0,
            });
        }
        if let Some(dir) = run.config.store_dir.clone() {
            // checkpoints need backend-state snapshots at dispatch
            // points; store-less runs never pay for them
            run.platform.enable_state_capture();
            // fail fast before burning submissions: a store over a
            // backend that cannot snapshot its state would journal
            // ledgers no resume can ever continue
            run.platform.checkpoint_state().map_err(|e| {
                format!("[store] configured but the platform cannot checkpoint: {e}")
            })?;
            run.store = Some(RunStore::create(Path::new(&dir))?);
        }
        if run.config.bootstrap_probing {
            // The probe sequence is fp8-specific (mfma-seed variants
            // exercising the fp8 task's hazards); on another family the
            // compile gate would reject the positive probes and falsely
            // "confirm" the negative one, poisoning the findings doc.
            if run.workload.name() != workload::DEFAULT_WORKLOAD {
                return Err(format!(
                    "bootstrap probing is specific to the {} workload (its probe \
                     kernels are fp8 genomes); disable it for '{}'",
                    workload::DEFAULT_WORKLOAD,
                    run.workload.name()
                ));
            }
            // Re-derive the findings document by probing the platform
            // (paper §4.1/footnote 2) instead of assuming it. Probes
            // consume real submissions; their kernels join the ledger.
            let report = bootstrap::run_bootstrap(&mut run.platform);
            run.agents.knowledge.findings = report.findings;
            let labels = bootstrap::probe_genomes();
            for ((label, genome), (_, _confirmed)) in
                labels.into_iter().zip(report.transcript.iter())
            {
                let outcome = run
                    .platform
                    .log()
                    .get(run.population.len())
                    .map(|r| r.outcome.clone())
                    .unwrap_or(EvalOutcome::CompileFailure("missing log".into()));
                // probe i's result arrived with submission i+1 (the
                // log index it was fetched from, 1-based)
                let submitted_at = run.population.len() as u64 + 1;
                run.record_individual(
                    vec![],
                    genome,
                    label.clone(),
                    format!("hardware probe ({label})"),
                    outcome,
                    Provenance::seed(submitted_at),
                );
            }
        }
        run.submit_seeds()?;
        // the store's first checkpoint: a crash at any later point can
        // resume from at least the post-seed state
        run.write_checkpoint(0, false, &[], 0, &[])?;
        Ok(run)
    }

    /// Submit the workload's seed kernels (burns submissions, as in the
    /// paper's §3 for the fp8 task).
    fn submit_seeds(&mut self) -> Result<(), String> {
        let seeds = self.workload.starting_population();
        let bootstrap_idx = seeds.len().saturating_sub(1);
        let before = self.platform.submissions();
        for (i, (name, genome)) in seeds.into_iter().enumerate() {
            // no-bootstrap counterfactual: the deep-dive never happened,
            // so the family's fast-path bootstrap seed (listed last —
            // fp8's mfma-seed) is dropped along with the findings
            if i == bootstrap_idx && !self.config.include_mfma_seed {
                continue;
            }
            if self.platform.quota_exhausted() {
                return Err("quota exhausted while seeding".into());
            }
            let outcome = self.platform.submit(&genome);
            let submitted_at = self.platform.submissions();
            self.record_individual(
                vec![],
                genome,
                format!("seed kernel: {name}"),
                format!("provided seed ({name})"),
                outcome,
                Provenance::seed(submitted_at),
            );
        }
        // Warm-start seeding (DESIGN.md §12): inject prior-campaign
        // elites mined from the federated archive as extra seed
        // candidates. The mined list is already deterministic (geomean
        // asc, fingerprint tie-break); injection rides the same seed
        // provenance path, so downstream planning treats elites exactly
        // like provided seeds.
        let elites = match &self.federation {
            Some(ctx) if self.config.federation_warm_start_k > 0 => ctx.snapshot.mine_elites(
                self.workload.as_ref(),
                self.config.federation_warm_start_k as usize,
            ),
            _ => Vec::new(),
        };
        let mut injected = 0u64;
        for (_fp, genome, prior_geomean) in elites {
            // budget the elite like any other submission; an exhausted
            // quota is not an error here (unlike required seeds above)
            if self.platform.quota_exhausted() {
                break;
            }
            // a workload seed may already be someone's elite — skip
            // duplicates rather than burn a submission re-proving them
            if self.population.find_duplicate(&genome).is_some() {
                continue;
            }
            let outcome = self.platform.submit(&genome);
            let submitted_at = self.platform.submissions();
            self.record_individual(
                vec![],
                genome,
                format!("{WARM_START_LABEL} ({prior_geomean:.1} us prior geomean)"),
                "transferred from the federated archive".into(),
                outcome,
                Provenance::seed(submitted_at),
            );
            injected += 1;
        }
        if let Some(ctx) = &mut self.federation {
            ctx.warm_injected = injected;
        }
        // the loop cannot plan before every seed result is back, so
        // both schedulers start from a post-seed barrier
        let submitted = self.platform.submissions() - before;
        self.sched
            .sample_submissions(submitted, self.config.eval_parallelism);
        self.platform.sync_lanes();
        Ok(())
    }

    /// Add one evaluated kernel to the ledger (and, when a store is
    /// configured, journal it with its evaluation provenance).
    fn record_individual(
        &mut self,
        parents: Vec<String>,
        genome: crate::genome::KernelGenome,
        experiment: String,
        report: String,
        outcome: EvalOutcome,
        prov: Provenance,
    ) -> String {
        let id = self.population.next_id();
        if let Some(ts) = outcome.timings() {
            self.curve
                .record(prov.submitted_at as usize, crate::metrics::geomean(ts));
        } else if let Some(best) = self.curve.best() {
            self.curve.record(prov.submitted_at as usize, best);
        }
        self.population.add(Individual {
            id: id.clone(),
            parents,
            genome,
            experiment,
            report,
            outcome,
        });
        if self.store.is_some() {
            // journal the entry the moment it lands: a crash anywhere
            // after this line cannot lose it
            let (lane, completed_at_s) = match prov.submission_index {
                Some(i) => {
                    let rec = &self.platform.log()[i as usize];
                    (Some(rec.lane), Some(rec.completed_at_s))
                }
                None => (None, None),
            };
            let individual = self
                .population
                .members()
                .last()
                .expect("entry just added")
                .clone();
            // the profile is committed with the platform's log line;
            // cache-served results have no log line, so recompute from
            // the genome (pure — same classification either way)
            let profile = match prov.submission_index {
                Some(i) => self.platform.log()[i as usize].profile.clone(),
                None => self.platform.profile_of(&individual.genome),
            };
            // cross-run hit provenance travels with the entry so resume
            // knows which log lines must not be replayed against the
            // backend (the lane never actually evaluated them)
            let federated = match prov.submission_index {
                Some(i) => self.platform.log()[i as usize].federated,
                None => false,
            };
            let record = JournalRecord::Exp(ExperimentRecord {
                individual,
                submitted_at: prov.submitted_at,
                submission_index: prov.submission_index,
                cached: prov.cached,
                lane,
                completed_at_s,
                plan: prov.plan,
                screened: prov.screened,
                profile,
                federated,
                lint: prov.lint,
            });
            self.store.as_mut().expect("store checked above").append(&record);
        }
        id
    }

    /// Remaining submission budget.
    pub fn budget_left(&self) -> u64 {
        self.config
            .max_submissions
            .saturating_sub(self.platform.submissions())
    }

    /// Run one select → design → write planning round against the
    /// current ledger. `room` caps how many children may be planned
    /// (submission budget not yet spoken for); `reserved_fps` carries
    /// fingerprints of experiments already queued or in flight, so the
    /// pipeline never plans a duplicate of pending work (the lockstep
    /// path passes an empty set — its only reservations are the ledger
    /// and the group itself).
    ///
    /// Returns `None` when selection is impossible or the designer has
    /// no plans; a `Some` group may still be empty if every written
    /// child was a duplicate (counted in `duplicates_skipped` — the
    /// pipeline's replan signal).
    fn plan_group(
        &mut self,
        room: u64,
        reserved_fps: &HashSet<u64>,
    ) -> Option<PlannedGroup> {
        // Stage 1 — Evolutionary Selector
        let selection = self
            .agents
            .selector
            .select(&self.population, &mut self.agents.llm)?;
        // borrowed, not cloned: the agent stages only read the ledger,
        // so the round never copies full Individuals (§Perf)
        let base = self.population.by_id(&selection.base_id)?;
        let reference = self.population.by_id(&selection.reference_id)?;

        // Stage 2 — Experiment Designer. With `[profile] guided` on,
        // the base kernel's classified bottleneck conditions the
        // avenue priors (DESIGN.md §11); off, the designer sees `None`
        // and the round is bit-identical to the pre-profile path (the
        // profile itself is a pure recomputation — no RNG, no quota).
        let base_bottleneck = if self.config.profile_guided {
            self.platform.profile_of(&base.genome).map(|p| p.bottleneck)
        } else {
            None
        };
        // With `[lint] guided` on, the base's warning components and
        // its failed children's error components boost the avenues
        // that attack them (DESIGN.md §13). The set is a pure function
        // of the population — recomputed here every round, so resume
        // needs no extra state. Off, the slice is empty and
        // `design_guided` is bit-identical to the plain path.
        let lint_attacks = if self.config.lint_guided {
            analysis::guided_attacks(
                &base.genome,
                self.population
                    .members()
                    .iter()
                    .filter(|m| m.parents.first() == Some(&base.id))
                    .map(|m| &m.genome),
                &MI300,
                self.workload.as_ref(),
            )
        } else {
            Vec::new()
        };
        let design = self.agents.designer.design_guided(
            &base.id,
            &base.genome,
            &self.population,
            &self.agents.knowledge,
            &mut self.agents.llm,
            base_bottleneck,
            &lint_attacks,
        );
        if design.plans.is_empty() {
            return None;
        }
        let chosen = self.agents.designer.choose(&design.plans, &mut self.agents.llm);

        // Stage 3 — Kernel Writer x chosen. Writes happen while
        // (virtual) budget remains and each non-duplicate child
        // reserves one submission — the same call sequence as the
        // original sequential path, so parallelism=1 trajectories are
        // unchanged bit for bit. Duplicate kernels are pointless
        // submissions (the paper's population ids are unique code
        // versions): skip exact dups against the ledger, the caller's
        // reservations, and this group — via precomputed fingerprint
        // sets, never by re-rendering genomes (§Perf).
        let mut group = PlannedGroup {
            selection,
            avenue_names: design
                .avenues
                .iter()
                .map(|a| a.name().to_string())
                .collect(),
            chosen_experiments: Vec::new(),
            experiments: Vec::new(),
            duplicates_skipped: 0,
            lint_rejected: Vec::new(),
        };
        let mut group_fps: HashSet<u64> = HashSet::new();
        for idx in &chosen {
            if (group.experiments.len() as u64) >= room {
                break;
            }
            let plan = &design.plans[*idx];
            group.chosen_experiments.push(plan.description.clone());
            let write = self.agents.writer.write(
                &base.genome,
                &reference.genome,
                plan,
                &mut self.agents.llm,
            );
            let fp = write.genome.fingerprint_hash();
            if self.population.contains_genome(fp, &write.genome)
                || reserved_fps.contains(&fp)
                || group_fps.contains(&fp)
            {
                group.duplicates_skipped += 1;
                continue;
            }
            let experiment = PlannedExperiment {
                base_id: base.id.clone(),
                reference_id: reference.id.clone(),
                description: plan.description.clone(),
                write,
                fingerprint: fp,
            };
            // The static gate (DESIGN.md §13): an error-diagnosed
            // child can never run, so it is diverted to the reject
            // list instead of a lane. It still reserves its
            // fingerprint within the group (the writer cannot
            // re-propose it this round) but does not consume `room` —
            // like a screen reject, the budget flows back to planning.
            if self.config.lint_gate {
                self.sched.linted += 1;
                let mut diags = analysis::lint(
                    &experiment.write.genome,
                    &MI300,
                    self.workload.as_ref(),
                );
                if analysis::has_error(&diags) {
                    self.sched.lint_rejected += 1;
                    group_fps.insert(fp);
                    diags.retain(|d| d.severity == Severity::Error);
                    group.lint_rejected.push((experiment, diags));
                    continue;
                }
            }
            group_fps.insert(fp);
            group.experiments.push(experiment);
        }
        Some(group)
    }

    /// Add one completed experiment to the ledger and return its id.
    fn record_experiment(
        &mut self,
        experiment: PlannedExperiment,
        outcome: EvalOutcome,
        prov: Provenance,
    ) -> String {
        self.record_individual(
            vec![experiment.base_id, experiment.reference_id],
            experiment.write.genome,
            experiment.description,
            experiment.write.report,
            outcome,
            prov,
        )
    }

    /// Ledger one lint-gate reject (DESIGN.md §13): the child joins
    /// the population as a compile failure carrying its `Error`
    /// diagnostics, without ever occupying a lane or consuming quota —
    /// the designer sees the failed hypothesis, the budget does not
    /// pay for it. `submitted_at` is pinned to the current submission
    /// count so the curve and a journal replay stay aligned.
    fn record_lint_reject(
        &mut self,
        experiment: PlannedExperiment,
        errors: Vec<Diagnostic>,
        log_pos: usize,
    ) -> String {
        let message = errors
            .iter()
            .map(|d| d.render())
            .collect::<Vec<_>>()
            .join("; ");
        let prov = Provenance {
            submitted_at: self.platform.submissions(),
            cached: false,
            submission_index: None,
            plan: Some(log_pos),
            screened: false,
            lint: errors.into_iter().map(|d| d.code).collect(),
        };
        self.record_experiment(
            experiment,
            EvalOutcome::CompileFailure(format!("rejected by the lint gate: {message}")),
            prov,
        )
    }

    /// Journal one planning round's transcript (no-op without a store).
    /// `screened` is how many of the round's children entered the
    /// analytic screen tier (0 while `[screen]` is disabled); `linted`
    /// is how many the lint gate rejected (0 while `[lint] gate` is
    /// disabled — the field is then omitted from the record).
    fn journal_plan(&mut self, log_pos: usize, screened: u64, linted: u64) {
        let Some(store) = self.store.as_mut() else { return };
        let log = &self.logs[log_pos];
        store.append(&JournalRecord::Plan(PlanRecord {
            iteration: log.iteration,
            log_pos,
            base_id: log.selection.base_id.clone(),
            reference_id: log.selection.reference_id.clone(),
            policy: log.selection.policy,
            rationale: log.selection.rationale.clone(),
            avenues: log.avenue_names.clone(),
            chosen: log.chosen_experiments.clone(),
            screened,
            linted,
        }));
    }

    /// Drain the platform's typed fault/recovery events, journal each
    /// (`"t":"fault"` records, DESIGN.md §14), and hand them back so
    /// the scheduler can read the committed fault kind. Empty — and a
    /// no-op — while the fault model is off.
    fn drain_fault_events(&mut self) -> Vec<FaultRecord> {
        let events = self.platform.take_fault_events();
        if let Some(store) = self.store.as_mut() {
            for ev in &events {
                store.append(&JournalRecord::Fault(ev.clone()));
            }
        }
        events
    }

    /// Journal one scheduler-side fault record (retry/abandon).
    fn journal_fault_record(&mut self, rec: FaultRecord) {
        if let Some(store) = self.store.as_mut() {
            store.append(&JournalRecord::Fault(rec));
        }
    }

    /// Decide one fault-class completion's fate (DESIGN.md §14):
    /// `Some(backoff_s)` means retry — the caller requeues the
    /// experiment as `attempt + 1`, starting no earlier than the
    /// completion time plus the backoff; `None` means abandon.
    /// `committed` is the submission budget already spoken for
    /// (committed + in flight + queued) — a retry needs room.
    fn fault_retry_decision(
        &self,
        events: &[FaultRecord],
        done: &crate::eval::CompletedEval,
        attempt: u32,
        committed: u64,
    ) -> Option<f64> {
        let fcfg = &self.config.faults;
        if !fcfg.recovery || attempt >= fcfg.max_retries {
            return None;
        }
        if committed >= self.config.max_submissions {
            return None;
        }
        // transient service errors back off exponentially; straggler
        // timeouts, lane deaths, and suspect timings requeue with no
        // delay (the fault is not load-related, so waiting buys nothing)
        let kind = events
            .iter()
            .find(|ev| ev.submission_index == done.submission_index && ev.submission_index.is_some())
            .map(|ev| ev.kind.as_str());
        Some(match kind {
            Some("transient") => fcfg.backoff_s(attempt),
            _ => 0.0,
        })
    }

    /// Ledger one faulted-but-retried attempt: the fault outcome joins
    /// the population (designers see the failure, and the journal can
    /// rebuild the platform log line the attempt consumed) while the
    /// experiment itself stays alive for its retry.
    fn record_fault_attempt(
        &mut self,
        e: &PlannedExperiment,
        outcome: EvalOutcome,
        prov: Provenance,
    ) -> String {
        self.record_individual(
            vec![e.base_id.clone(), e.reference_id.clone()],
            e.write.genome.clone(),
            e.description.clone(),
            e.write.report.clone(),
            outcome,
            prov,
        )
    }

    /// Count + journal one retry decision. `next_attempt` is the
    /// attempt number the requeued dispatch will carry.
    fn note_fault_retry(&mut self, submission_index: Option<u64>, next_attempt: u32, at_s: f64) {
        self.sched.fault_retries += 1;
        self.journal_fault_record(FaultRecord {
            kind: "retry".into(),
            lane: None,
            submission_index,
            attempt: next_attempt,
            at_s,
        });
    }

    /// Count + journal one abandonment (policy, retry cap, or budget).
    fn note_fault_abandon(&mut self, submission_index: Option<u64>, attempt: u32, at_s: f64) {
        self.sched.fault_abandoned += 1;
        self.journal_fault_record(FaultRecord {
            kind: "abandon".into(),
            lane: None,
            submission_index,
            attempt,
            at_s,
        });
    }

    /// Snapshot everything a resume needs and write it to the store
    /// (no-op without one). `pending` lists planned-but-uncommitted
    /// experiments in dispatch order (with their retry metadata — all
    /// zero on a faults-off run); `skip_depth` of them were in flight;
    /// `screen_pending` lists the screen tier's partial rung in
    /// submission order (always empty in lockstep, whose rungs are
    /// batch-scoped). See DESIGN.md §9/§10/§14 for what goes where.
    fn write_checkpoint(
        &mut self,
        stalls: u32,
        planning_dead: bool,
        pending: &[PendingRef<'_>],
        skip_depth: usize,
        screen_pending: &[(&PlannedExperiment, usize)],
    ) -> Result<(), String> {
        if self.store.is_none() {
            return Ok(());
        }
        debug_assert!(
            self.platform.fault_state().map_or(true, |fs| fs.events.is_empty()),
            "fault events must be journaled before a checkpoint"
        );
        let platform = self.platform.checkpoint_state()?;
        let best = self.population.best();
        let cp = Checkpoint {
            config: self.config.clone(),
            journal_bytes: 0, // stamped by the store at write time
            ledger_len: self.population.len(),
            logs_len: self.logs.len(),
            iteration: self.iteration,
            stalls,
            planning_dead,
            sched: self.sched.snapshot(),
            llm_rng: self.agents.llm.rng_state(),
            findings: self.agents.knowledge.findings.to_json(),
            platform,
            pending: pending
                .iter()
                .map(|p| {
                    pending_plan(p.experiment, p.log_pos, p.attempt, p.not_before_s, p.ticket)
                })
                .collect(),
            skip_depth,
            screen_pending: screen_pending
                .iter()
                .map(|(e, log_pos)| pending_plan(e, *log_pos, 0, 0.0, None))
                .collect(),
            best_id: best.map(|b| b.id.clone()),
            best_geomean_us: self.population.best().and_then(|b| b.score()),
        };
        self.store
            .as_mut()
            .expect("store checked above")
            .write_checkpoint(cp);
        Ok(())
    }

    /// Current outcome snapshot.
    pub fn outcome(&mut self) -> Result<RunOutcome, String> {
        let best = self
            .population
            .best()
            .ok_or("no successful kernel in population")?
            .clone();
        let leaderboard_us = self
            .platform
            .leaderboard_score(&best.genome, &self.workload.leaderboard_suite())
            .ok();
        let profile_mix = if self.config.profile_guided {
            let mut mix = crate::sim::ProfileMix::default();
            for rec in self.platform.log() {
                if let Some(p) = &rec.profile {
                    mix.add(p.bottleneck);
                }
            }
            Some(mix)
        } else {
            None
        };
        Ok(RunOutcome {
            workload: self.workload.name().to_string(),
            best_geomean_us: best.score().unwrap(),
            best_id: best.id,
            submissions: self.platform.submissions(),
            wall_clock_s: self.platform.wall_clock_s(),
            curve: self.curve.clone(),
            leaderboard_us,
            pipeline: self.sched.stats(
                self.config.pipeline,
                self.config.eval_parallelism,
                self.platform.lane_occupancy(),
            ),
            profile_mix,
            federation: self.federation.as_ref().map(|ctx| FederationStats {
                hits: self.platform.federated_hits(),
                warm_start_injected: ctx.warm_injected,
            }),
            faults: self.platform.fault_state().map(|fs| FaultSummary {
                stats: fs.stats.clone(),
                retries: self.sched.fault_retries,
                abandoned: self.sched.fault_abandoned,
                retired_lanes: fs.lanes.iter().filter(|l| l.retired).count() as u64,
            }),
        })
    }

    /// Publish this run's distinct evaluated genomes to the federated
    /// store (DESIGN.md §12). Called only on a successful, non-halted
    /// completion: a partial run never writes a partial archive file.
    /// The per-run filename is a pure function of (workload, seed,
    /// digest), so re-running the identical config overwrites the file
    /// with identical contents — publication is idempotent.
    fn publish_federation(&self) -> Result<(), String> {
        let Some(ctx) = &self.federation else {
            return Ok(());
        };
        if self.config.federation_read_only {
            return Ok(());
        }
        let dir = self
            .config
            .federation_dir
            .as_ref()
            .expect("federation ctx implies a configured dir");
        // first occurrence per fingerprint wins, matching the reader's
        // merge order; failures are published too — a sibling run
        // learning "this genome does not compile" is as valuable as a
        // timing
        let mut seen = HashSet::new();
        let mut entries = Vec::new();
        for m in self.population.members() {
            // fault-class outcomes are this run's service weather, not
            // knowledge about the genome — a sibling run must never
            // inherit a transient as if it were a result (DESIGN.md §14)
            if m.outcome.is_fault() {
                continue;
            }
            let fp = m.genome.fingerprint_hash();
            if !seen.insert(fp) {
                continue;
            }
            entries.push(FedEntry {
                workload: self.workload.name().to_string(),
                digest: ctx.digest,
                fingerprint: fp,
                genome: m.genome.clone(),
                outcome: m.outcome.clone(),
            });
        }
        federation::write_run_results(
            Path::new(dir),
            self.workload.name(),
            self.config.seed,
            ctx.digest,
            &entries,
        )?;
        Ok(())
    }
}

impl<B: EvalBackend + Send + 'static> ScientistRun<B> {
    /// Run one full **lockstep** loop iteration (select -> design ->
    /// 3x write -> one batched submit through the multi-lane
    /// executor, then a barrier: the next iteration plans only after
    /// the whole batch completes). Returns `None` when out of budget
    /// or when selection is impossible. (`B: 'static` because the
    /// fault-model dispatch path streams the batch through per-lane
    /// worker threads; faults off, the batch path never spawns.)
    pub fn run_iteration(&mut self) -> Option<&IterationLog> {
        if self.budget_left() == 0 {
            return None;
        }
        self.iteration += 1;
        let no_reservations = HashSet::new();
        let mut group = self.plan_group(self.budget_left(), &no_reservations)?;
        self.sched.planning_rounds += 1;
        self.sched.replanned_duplicates += group.duplicates_skipped;

        // Lockstep screening is batch-scoped: the planned group is its
        // own rung (the `screen.rung` knob only shapes the pipeline
        // scheduler's rolling rung), so lockstep checkpoints still
        // never carry pending screen work and the barrier shape is
        // preserved (DESIGN.md §10). Rejected children are dropped —
        // lockstep holds no reservations to release.
        let planned = group.experiments.len() as u64;
        if self.config.screen_enabled && !group.experiments.is_empty() {
            let mut tier: ScreenTier<PlannedExperiment> = ScreenTier::new(
                ScreenConfig {
                    rung: group.experiments.len() as u32,
                    keep_fraction: self.config.screen_keep,
                },
                self.workload.clone(),
            );
            let mut outcome = None;
            for e in std::mem::take(&mut group.experiments) {
                let score = tier.score(&e.write.genome);
                if let Some(out) = tier.push_scored(score, e) {
                    outcome = Some(out);
                }
            }
            let out = outcome.expect("a rung sized to the group fills on its last push");
            self.sched.screened += planned;
            self.sched.screen_promoted += out.promoted.len() as u64;
            self.sched.screen_rejected += out.rejected.len() as u64;
            group.experiments = out.promoted;
        }

        // Lint-gate rejects join the ledger BEFORE the batch: their
        // journal records precede the batch's, so their ids lead
        // `submitted_ids` exactly as a journal-order resume would
        // reconstruct them. No-op (and no new code path) with the
        // gate off — the reject list is then always empty.
        let log_pos = self.logs.len();
        let mut submitted_ids = Vec::new();
        for (experiment, errors) in std::mem::take(&mut group.lint_rejected) {
            submitted_ids.push(self.record_lint_reject(experiment, errors, log_pos));
        }
        let lint_rejected_now = submitted_ids.len() as u64;

        if self.platform.fault_state().is_some() {
            // Fault-model lockstep (DESIGN.md §14): the round's batch
            // runs through the stream path one dispatch at a time so
            // each fault-class completion can be retried (or abandoned)
            // before the barrier. Completions still drain in virtual-
            // clock order, so the round stays a pure function of
            // (seed, config).
            let ids =
                self.pump_faulty_group(std::mem::take(&mut group.experiments), log_pos);
            submitted_ids.extend(ids);
        } else {
            let batch: Vec<crate::genome::KernelGenome> = group
                .experiments
                .iter()
                .map(|e| e.write.genome.clone())
                .collect();
            let results = self.platform.submit_batch(&batch);
            self.sched.sample_submissions(
                results.iter().filter(|r| !r.cached).count() as u64,
                self.config.eval_parallelism,
            );
            for (experiment, result) in group.experiments.into_iter().zip(results) {
                let prov = Provenance {
                    submitted_at: result
                        .submission_index
                        .map(|i| i + 1)
                        .unwrap_or_else(|| self.platform.submissions()),
                    cached: result.cached,
                    submission_index: result.submission_index,
                    plan: Some(log_pos),
                    screened: self.config.screen_enabled,
                    lint: Vec::new(),
                };
                submitted_ids.push(self.record_experiment(experiment, result.outcome, prov));
            }
        }
        // the lockstep barrier: every lane waits for the slowest
        // before the next planning round (a no-op at one lane)
        self.platform.sync_lanes();

        self.logs.push(IterationLog {
            iteration: self.iteration,
            selection: group.selection,
            avenue_names: group.avenue_names,
            chosen_experiments: group.chosen_experiments,
            submitted_ids,
        });
        let screened = if self.config.screen_enabled {
            planned
        } else {
            0
        };
        self.journal_plan(log_pos, screened, lint_rejected_now);
        self.logs.last()
    }

    /// Stream one lockstep batch through the recovery layer
    /// (DESIGN.md §14): feed dispatches while the quota has room,
    /// drain completions in virtual-clock order, and on a fault-class
    /// completion either requeue the experiment (backoff charged to
    /// the lane clock via `not_before_s`) or abandon it into the
    /// ledger. Every attempt is its own submission — quota charge,
    /// ledger entry and journal record included — so a journal
    /// rebuild reconstructs the platform log line for line.
    fn pump_faulty_group(
        &mut self,
        experiments: Vec<PlannedExperiment>,
        log_pos: usize,
    ) -> Vec<String> {
        let mut ids = Vec::new();
        let mut queue: VecDeque<(PlannedExperiment, u32, f64)> =
            experiments.into_iter().map(|e| (e, 0, 0.0)).collect();
        let mut in_flight: Vec<(u64, PlannedExperiment, u32)> = Vec::new();
        let mut counted = 0u64;
        loop {
            // feed while the quota can cover another counted miss
            // (in-flight misses count as already spent)
            while !queue.is_empty()
                && self.platform.submissions() + self.platform.in_flight() as u64
                    < self.config.max_submissions
            {
                let (e, attempt, not_before_s) = queue.pop_front().expect("checked non-empty");
                let ticket =
                    self.platform
                        .submit_stream_retry(&e.write.genome, not_before_s, attempt);
                in_flight.push((ticket, e, attempt));
            }
            let Some(done) = self.platform.poll_completed() else {
                break;
            };
            // journal the dispatch's fault events before anything can
            // checkpoint past them (also feeds the retry decision)
            let events = self.drain_fault_events();
            let pos = in_flight
                .iter()
                .position(|(t, _, _)| *t == done.ticket)
                .expect("completion matches an in-flight dispatch");
            let (_, experiment, attempt) = in_flight.remove(pos);
            if !done.cached {
                counted += 1;
            }
            let prov = Provenance {
                submitted_at: done
                    .submission_index
                    .map(|i| i + 1)
                    .unwrap_or_else(|| self.platform.submissions()),
                cached: done.cached,
                submission_index: done.submission_index,
                plan: Some(log_pos),
                screened: self.config.screen_enabled,
                lint: Vec::new(),
            };
            if done.outcome.is_fault() {
                let committed = self.platform.submissions()
                    + self.platform.in_flight() as u64
                    + queue.len() as u64;
                match self.fault_retry_decision(&events, &done, attempt, committed) {
                    Some(backoff) => {
                        // the failed attempt still joins the ledger:
                        // its journal record is what lets a rebuild
                        // replay this platform log line
                        ids.push(self.record_fault_attempt(
                            &experiment,
                            done.outcome.clone(),
                            prov,
                        ));
                        self.note_fault_retry(
                            done.submission_index,
                            attempt + 1,
                            done.completed_at_s,
                        );
                        queue.push_back((
                            experiment,
                            attempt + 1,
                            done.completed_at_s + backoff,
                        ));
                    }
                    None => {
                        self.note_fault_abandon(
                            done.submission_index,
                            attempt,
                            done.completed_at_s,
                        );
                        ids.push(self.record_experiment(experiment, done.outcome, prov));
                    }
                }
            } else {
                ids.push(self.record_experiment(experiment, done.outcome, prov));
            }
        }
        self.sched
            .sample_submissions(counted, self.config.eval_parallelism);
        // quota exhausted with work still queued: requeued retries were
        // already ledgered as their failed attempts — close them out
        // loudly; fresh entries fall to the same quota truncation the
        // batch path applies (planned > room never dispatches)
        let at_s = self.platform.wall_clock_s();
        for (_, attempt, _) in queue {
            if attempt > 0 {
                self.note_fault_abandon(None, attempt, at_s);
            }
        }
        ids
    }
}

impl<B: EvalBackend + Send + 'static> ScientistRun<B> {
    /// Run until the submission budget is exhausted (or the loop
    /// stalls), then compute the outcome. Dispatches on
    /// `config.pipeline`: the lockstep barrier loop by default, the
    /// steady-state pipeline scheduler ([`pipeline`], DESIGN.md §8)
    /// when enabled. (`B: 'static` because the pipeline's stream path
    /// keeps per-lane worker threads alive across iterations.)
    pub fn run_to_completion(&mut self) -> Result<RunOutcome, String> {
        if self.config.pipeline {
            self.pump_pipeline()?;
        } else {
            self.pump_lockstep()?;
        }
        let outcome = self.outcome()?;
        // a halted (simulated-crash) run must not publish: the resumed
        // continuation will, once it actually completes
        if !self.halted {
            self.publish_federation()?;
        }
        Ok(outcome)
    }

    /// The lockstep barrier loop, with store checkpoints at the
    /// iteration boundary (every `checkpoint_every` iterations + a
    /// final one — unless `halt_after` simulated a crash).
    fn pump_lockstep(&mut self) -> Result<(), String> {
        // lockstep checkpoints never carry pending work, so a resumed
        // run only needs the stall streak back
        let mut stalls = self.resume_state.take().map(|r| r.stalls).unwrap_or(0);
        let every = self.config.checkpoint_every.max(1);
        let mut steps = 0u64;
        while self.budget_left() > 0 && stalls < 8 {
            if self.halt_reached() {
                self.halted = true;
                return Ok(());
            }
            let before = self.platform.submissions();
            if self.run_iteration().is_none() {
                break;
            }
            if self.platform.submissions() == before {
                stalls += 1; // iteration produced only duplicates
            } else {
                stalls = 0;
            }
            steps += 1;
            if steps % every == 0 {
                self.write_checkpoint(stalls, false, &[], 0, &[])?;
            }
        }
        self.write_checkpoint(stalls, false, &[], 0, &[])
    }

    /// Whether the `halt_after` test knob says to abort now (simulated
    /// crash; see [`crate::config::RunConfig::halt_after`]).
    pub(crate) fn halt_reached(&self) -> bool {
        self.config
            .halt_after
            .map(|h| self.platform.submissions() >= h)
            .unwrap_or(false)
    }

    /// True when `halt_after` aborted the scheduler (the run's store —
    /// if any — ends at its last periodic checkpoint, like a crash).
    pub fn halted(&self) -> bool {
        self.halted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds as gseeds;
    use crate::gpu::MI300;
    use crate::sim::calibration::leaderboard_geomean;

    fn quick_config(max_submissions: u64) -> RunConfig {
        RunConfig {
            max_submissions,
            ..RunConfig::default()
        }
    }

    #[test]
    fn seeds_are_submitted_first() {
        let run = ScientistRun::new(quick_config(10)).unwrap();
        assert_eq!(run.population.len(), 3);
        assert_eq!(run.platform.submissions(), 3);
        assert!(run.population.by_id("00001").is_some());
    }

    #[test]
    fn unknown_workload_is_an_error() {
        let cfg = RunConfig {
            workload: "warp-drive".into(),
            ..quick_config(10)
        };
        let err = ScientistRun::new(cfg).unwrap_err();
        assert!(err.contains("unknown workload"), "{err}");
    }

    #[test]
    fn bootstrap_probing_is_rejected_off_the_fp8_family() {
        // the probe kernels are fp8 genomes; other families must fail
        // fast instead of poisoning their findings doc
        let cfg = RunConfig {
            workload: "bf16-gemm".into(),
            bootstrap_probing: true,
            ..quick_config(20)
        };
        let err = ScientistRun::new(cfg).unwrap_err();
        assert!(err.contains("bootstrap probing"), "{err}");
    }

    #[test]
    fn with_platform_rejects_workload_mismatch() {
        // the backend is the source of truth: a config naming a
        // different family must not silently cross-wire seeds & model
        use crate::eval::PlatformConfig;
        let platform = crate::eval::EvalPlatform::new(
            crate::sim::SimBackend::new(1), // carries the fp8 default
            PlatformConfig::default(),
        );
        let cfg = RunConfig {
            workload: "row-softmax".into(),
            ..quick_config(10)
        };
        let err = ScientistRun::with_platform(cfg, platform).unwrap_err();
        assert!(err.contains("does not match"), "{err}");
    }

    #[test]
    fn no_bootstrap_counterfactual_drops_the_fast_path_seed_per_family() {
        for w in workload::registry() {
            let cfg = RunConfig {
                workload: w.name().to_string(),
                include_mfma_seed: false,
                ..quick_config(10)
            };
            let run = ScientistRun::new(cfg).unwrap();
            let seeds = w.starting_population();
            assert_eq!(run.population.len(), seeds.len() - 1, "{}", w.name());
            let dropped = seeds.last().unwrap().0;
            assert!(
                !run.population
                    .members()
                    .iter()
                    .any(|m| m.experiment.contains(dropped)),
                "{}: bootstrap seed {dropped} should be dropped",
                w.name()
            );
        }
    }

    #[test]
    fn run_targets_the_configured_workload() {
        let cfg = RunConfig {
            workload: "row-softmax".into(),
            ..quick_config(10)
        };
        let run = ScientistRun::new(cfg).unwrap();
        assert_eq!(run.workload.name(), "row-softmax");
        // the platform times the workload's own feedback suite and the
        // ledger's seed rows are the workload's seeds
        assert_eq!(
            run.platform.feedback_suite.configs,
            run.workload.feedback_suite().configs
        );
        assert_eq!(
            run.population.len(),
            run.workload.starting_population().len()
        );
        assert!(run
            .population
            .by_id("00001")
            .unwrap()
            .experiment
            .contains("torch-softmax"));
    }

    #[test]
    fn outcome_is_stamped_with_the_workload() {
        let mut run = ScientistRun::new(quick_config(8)).unwrap();
        let outcome = run.run_to_completion().unwrap();
        assert_eq!(outcome.workload, "fp8-gemm");
    }

    #[test]
    fn iteration_grows_population() {
        let mut run = ScientistRun::new(quick_config(12)).unwrap();
        let log = run.run_iteration().expect("iteration should run");
        assert!(!log.submitted_ids.is_empty());
        assert!(!log.avenue_names.is_empty());
        assert!(run.population.len() > 3);
        // children carry base+reference parents
        let child = run
            .population
            .by_id(&run.logs[0].submitted_ids[0])
            .unwrap();
        assert_eq!(child.parents.len(), 2);
    }

    #[test]
    fn budget_is_respected() {
        let mut run = ScientistRun::new(quick_config(9)).unwrap();
        let outcome = run.run_to_completion().unwrap();
        assert!(outcome.submissions <= 9);
    }

    #[test]
    fn run_improves_over_best_seed() {
        let mut run = ScientistRun::new(quick_config(60)).unwrap();
        let best_seed_score = run.population.best().unwrap().score().unwrap();
        let outcome = run.run_to_completion().unwrap();
        assert!(
            outcome.best_geomean_us < best_seed_score,
            "no improvement: {} vs seed {}",
            outcome.best_geomean_us,
            best_seed_score
        );
    }

    #[test]
    fn long_run_beats_pytorch_reference() {
        // The paper's headline: the LLM-only loop ends well below the
        // PyTorch library baseline.
        let mut run = ScientistRun::new(quick_config(120)).unwrap();
        let outcome = run.run_to_completion().unwrap();
        let lib = leaderboard_geomean(&MI300, &gseeds::pytorch_reference());
        let lb = outcome.leaderboard_us.expect("leaderboard score");
        assert!(
            lb < lib,
            "evolved {lb:.0} us should beat library {lib:.0} us"
        );
    }

    #[test]
    fn runs_are_reproducible_per_seed() {
        let mut a = ScientistRun::new(quick_config(30)).unwrap();
        let mut b = ScientistRun::new(quick_config(30)).unwrap();
        let oa = a.run_to_completion().unwrap();
        let ob = b.run_to_completion().unwrap();
        assert_eq!(oa.best_id, ob.best_id);
        assert_eq!(oa.best_geomean_us, ob.best_geomean_us);
    }

    #[test]
    fn different_seeds_explore_differently() {
        let mut a = ScientistRun::new(RunConfig {
            seed: 1,
            max_submissions: 24,
            ..RunConfig::default()
        })
        .unwrap();
        let mut b = ScientistRun::new(RunConfig {
            seed: 2,
            max_submissions: 24,
            ..RunConfig::default()
        })
        .unwrap();
        let oa = a.run_to_completion().unwrap();
        let ob = b.run_to_completion().unwrap();
        // scores may coincide, but full transcripts should differ
        let ga: Vec<String> = a.population.members().iter().map(|m| m.genome.fingerprint()).collect();
        let gb: Vec<String> = b.population.members().iter().map(|m| m.genome.fingerprint()).collect();
        assert!(ga != gb || oa.best_geomean_us != ob.best_geomean_us);
    }

    #[test]
    fn curve_is_monotone() {
        let mut run = ScientistRun::new(quick_config(40)).unwrap();
        let outcome = run.run_to_completion().unwrap();
        let pts = &outcome.curve.points;
        assert!(!pts.is_empty());
        for w in pts.windows(2) {
            assert!(w[1].best_geomean_us <= w[0].best_geomean_us);
        }
    }

    #[test]
    fn logs_carry_rationales() {
        let mut run = ScientistRun::new(quick_config(15)).unwrap();
        run.run_iteration();
        let log = &run.logs[0];
        assert!(log.selection.rationale.contains("selected as the basis"));
        assert!(!log.chosen_experiments.is_empty());
    }
}
