//! The steady-state experiment pipeline (DESIGN.md §8).
//!
//! The paper's loop is throughput-bound by the evaluation platform,
//! and lockstep scheduling makes it worse than it needs to be: with
//! `parallelism = N` lanes, every iteration submits at most 3
//! children and then waits at a barrier, so N-3 lanes idle per round
//! and *all* lanes idle while the next round is planned. AutoKernel
//! and KernelFoundry (PAPERS.md) both frame agent-driven search as a
//! continuously fed evaluation queue; this module is that scheduler.
//!
//! Shape: a queue of planned experiments sits between the agent
//! stages and the platform's completion-driven stream API
//! ([`crate::eval::EvalPlatform::submit_stream`] /
//! [`crate::eval::EvalPlatform::poll_completed`]). The loop drains one
//! completion at a time — in **virtual-clock order**, which the
//! platform guarantees is a pure function of the submission sequence —
//! folds it into the ledger, and then refills:
//!
//! * **Queue refill rule** — whenever free lane capacity
//!   (`parallelism x inflight_per_lane` minus in-flight) outruns the
//!   queue, run another select → design → write round against the
//!   freshest ledger. Results still in flight are simply not there
//!   yet: planning trades a little staleness for never letting a lane
//!   wait on an agent stage.
//! * **Replanning** — a written child that duplicates the ledger, the
//!   queue, or an in-flight submission is discarded
//!   (`replanned_duplicates`) and planning continues, so duplicates
//!   never occupy a lane. Eight consecutive all-duplicate rounds
//!   **against an unchanged ledger** stop planning (the lockstep stall
//!   rule, same constant); any completion re-arms the streak, since a
//!   grown ledger can un-stick the writer.
//! * **Degenerate lockstep case** — at `parallelism = 1` with the
//!   default depth the cap is 1: the scheduler plans a full group,
//!   feeds its children one at a time through the same backend in the
//!   same order, and can only plan again once the group has drained —
//!   exactly the lockstep call sequence, so the trajectory is
//!   bit-identical (`tests/pipeline.rs` locks this in).
//!
//! Determinism: planning decisions depend only on the ledger and the
//! agents' seeded RNG; the ledger grows in virtual-clock completion
//! order; lane assignment is the platform's earliest-free rule. No OS
//! scheduling anywhere in that chain — pipeline runs replay from
//! (seed, config) at any lane count, re-verified across
//! `parallelism ∈ {1, 2, 4}` for every registered workload.

use std::collections::{HashSet, VecDeque};

use super::{IterationLog, PlannedExperiment, ScientistRun};
use crate::eval::{EvalBackend, ScreenConfig, ScreenOutcome, ScreenTier};

/// Scheduler-level throughput statistics, reported in
/// [`super::RunOutcome`] for both the lockstep and pipeline drivers.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PipelineStats {
    /// True when the steady-state pipeline scheduler drove the run.
    pub pipelined: bool,
    /// Evaluation lanes (platform parallelism).
    pub lanes: u32,
    /// Busy lane-seconds over `lanes x` simulated makespan; 1.0 means
    /// no lane ever idled.
    pub lane_occupancy: f64,
    /// Mean submissions simultaneously occupying lanes, sampled at
    /// each submission event.
    pub mean_in_flight: f64,
    /// Peak simultaneous lane occupancy observed.
    pub max_in_flight: u64,
    /// Select → design → write rounds run.
    pub planning_rounds: u64,
    /// Duplicate children discarded at planning time and replanned
    /// instead of submitted.
    pub replanned_duplicates: u64,
    /// Candidates scored by the analytic pre-screen tier (DESIGN.md
    /// §10); 0 when `[screen]` is disabled.
    pub screened: u64,
    /// Screened candidates promoted into the full platform.
    pub screen_promoted: u64,
    /// Screened candidates rejected at the screen tier — they never
    /// occupied a lane or consumed quota, like replanned duplicates.
    pub screen_rejected: u64,
    /// Candidates checked by the static lint gate (DESIGN.md §13); 0
    /// while `[lint] gate` is disabled.
    pub linted: u64,
    /// Lint-checked candidates carrying an `Error` diagnostic,
    /// rejected before submission — they joined the ledger as compile
    /// failures but never occupied a lane or consumed quota.
    pub lint_rejected: u64,
    /// Fault-class completions requeued by the recovery layer
    /// (DESIGN.md §14); 0 while `[faults]` is disabled.
    pub fault_retries: u64,
    /// Fault-class completions the recovery layer gave up on (retry
    /// budget, quota, or recovery disabled) — they joined the ledger
    /// with their fault outcome.
    pub fault_abandoned: u64,
}

/// Raw counters both schedulers accumulate on the run; snapshot into
/// [`PipelineStats`] by [`SchedCounters::stats`].
#[derive(Debug, Clone, Default)]
pub(crate) struct SchedCounters {
    pub planning_rounds: u64,
    pub replanned_duplicates: u64,
    pub screened: u64,
    pub screen_promoted: u64,
    pub screen_rejected: u64,
    pub linted: u64,
    pub lint_rejected: u64,
    pub fault_retries: u64,
    pub fault_abandoned: u64,
    depth_total: u64,
    depth_samples: u64,
    max_in_flight: u64,
}

impl SchedCounters {
    /// Record one in-flight depth observation (pipeline path: sampled
    /// right after each stream submission).
    pub fn sample_depth(&mut self, in_flight: u64) {
        self.depth_total += in_flight;
        self.depth_samples += 1;
        self.max_in_flight = self.max_in_flight.max(in_flight);
    }

    /// Record a barrier round of `n` submissions on `lanes` lanes
    /// (lockstep path): each submission sees `min(n, lanes)` of the
    /// batch occupying lanes at once.
    pub fn sample_submissions(&mut self, n: u64, lanes: u32) {
        let depth = n.min(lanes.max(1) as u64);
        for _ in 0..n {
            self.sample_depth(depth);
        }
    }

    /// Snapshot for a run-store checkpoint.
    pub fn snapshot(&self) -> crate::store::SchedSnapshot {
        crate::store::SchedSnapshot {
            planning_rounds: self.planning_rounds,
            replanned_duplicates: self.replanned_duplicates,
            screened: self.screened,
            screen_promoted: self.screen_promoted,
            screen_rejected: self.screen_rejected,
            linted: self.linted,
            lint_rejected: self.lint_rejected,
            fault_retries: self.fault_retries,
            fault_abandoned: self.fault_abandoned,
            depth_total: self.depth_total,
            depth_samples: self.depth_samples,
            max_in_flight: self.max_in_flight,
        }
    }

    /// Rebuild from a checkpoint snapshot.
    pub fn restore(s: &crate::store::SchedSnapshot) -> SchedCounters {
        SchedCounters {
            planning_rounds: s.planning_rounds,
            replanned_duplicates: s.replanned_duplicates,
            screened: s.screened,
            screen_promoted: s.screen_promoted,
            screen_rejected: s.screen_rejected,
            linted: s.linted,
            lint_rejected: s.lint_rejected,
            fault_retries: s.fault_retries,
            fault_abandoned: s.fault_abandoned,
            depth_total: s.depth_total,
            depth_samples: s.depth_samples,
            max_in_flight: s.max_in_flight,
        }
    }

    pub fn stats(&self, pipelined: bool, lanes: u32, lane_occupancy: f64) -> PipelineStats {
        PipelineStats {
            pipelined,
            lanes: lanes.max(1),
            lane_occupancy,
            mean_in_flight: if self.depth_samples > 0 {
                self.depth_total as f64 / self.depth_samples as f64
            } else {
                0.0
            },
            max_in_flight: self.max_in_flight,
            planning_rounds: self.planning_rounds,
            replanned_duplicates: self.replanned_duplicates,
            screened: self.screened,
            screen_promoted: self.screen_promoted,
            screen_rejected: self.screen_rejected,
            linted: self.linted,
            lint_rejected: self.lint_rejected,
            fault_retries: self.fault_retries,
            fault_abandoned: self.fault_abandoned,
        }
    }
}

/// Fold one screen-tier promotion decision into the scheduler state:
/// survivors join the submission queue (in submission order), culled
/// candidates release their fingerprint reservation — mirroring the
/// replanned-duplicate path, they never occupy a lane.
fn absorb_screen_outcome(
    out: ScreenOutcome<(PlannedExperiment, usize)>,
    queue: &mut VecDeque<QueuedChild>,
    reserved: &mut HashSet<u64>,
    sched: &mut SchedCounters,
) {
    sched.screen_promoted += out.promoted.len() as u64;
    sched.screen_rejected += out.rejected.len() as u64;
    for (experiment, log_pos) in out.promoted {
        queue.push_back((experiment, log_pos, 0, 0.0));
    }
    for (experiment, _) in out.rejected {
        reserved.remove(&experiment.fingerprint);
    }
}

/// A planned child waiting for a lane: `(experiment, log_pos,
/// attempt, not_before_s)`. The last two are the recovery layer's
/// retry metadata (DESIGN.md §14) — always `(0, 0.0)` on a faults-off
/// run, so the dispatch call sequence is unchanged.
type QueuedChild = (PlannedExperiment, usize, u32, f64);

/// One child occupying an evaluation lane.
struct InFlightChild {
    ticket: u64,
    experiment: PlannedExperiment,
    /// Position of the planning round's [`IterationLog`] in
    /// `run.logs`, so the id lands in the right transcript entry.
    log_pos: usize,
    /// Which dispatch attempt this is (0 = first); salts the fault
    /// model's per-dispatch stream on retries.
    attempt: u32,
}

impl<B: EvalBackend + Send + 'static> ScientistRun<B> {
    /// Drive the steady-state pipeline until the submission budget is
    /// spent or planning runs dry. See the module docs for the refill
    /// rule and the determinism argument.
    pub(super) fn pump_pipeline(&mut self) -> Result<(), String> {
        let lanes = self.config.eval_parallelism.max(1) as usize;
        let cap = lanes * self.config.inflight_per_lane.max(1) as usize;
        let faults_on = self.platform.fault_state().is_some();
        let mut queue: VecDeque<QueuedChild> = VecDeque::new();
        // content hashes of queued + in-flight children — the replan
        // path's reservation set (the ledger itself is checked inside
        // plan_group)
        let mut reserved: HashSet<u64> = HashSet::new();
        let mut in_flight: Vec<InFlightChild> = Vec::new();
        let mut stalls = 0u32;
        let mut planning_dead = false;
        // A resumed run re-feeds the checkpoint's planned-but-
        // uncommitted experiments (former in-flight first, in original
        // dispatch order) through the normal path below: the rolled-
        // back platform re-derives identical lanes, tickets, and
        // clocks. Their depth samples are already in the restored
        // counters, so the first `skip_depth` dispatches don't
        // re-sample (DESIGN.md §9).
        let mut skip_depth = 0usize;
        // The analytic pre-screen tier (DESIGN.md §10). `None` when
        // `[screen]` is disabled: an off run takes no code path through
        // the tier — no extra work, no reordering, no RNG draws — so
        // its trajectory is bit-identical to a build without it.
        let mut screen: Option<ScreenTier<(PlannedExperiment, usize)>> =
            self.config.screen_enabled.then(|| {
                ScreenTier::new(
                    ScreenConfig {
                        rung: self.config.screen_rung,
                        keep_fraction: self.config.screen_keep,
                    },
                    self.workload.clone(),
                )
            });
        if let Some(resume) = self.resume_state.take() {
            stalls = resume.stalls;
            planning_dead = resume.planning_dead;
            skip_depth = resume.skip_depth;
            for p in resume.pending {
                reserved.insert(p.experiment.fingerprint);
                match p.ticket {
                    // a faults-on checkpoint persisted its in-flight
                    // dispatches as live platform pending entries
                    // (DESIGN.md §14): reattach by ticket instead of
                    // re-dispatching — the completion will drain with
                    // its original clock, lane, and outcome
                    Some(ticket) if faults_on => in_flight.push(InFlightChild {
                        ticket,
                        experiment: p.experiment,
                        log_pos: p.log_pos,
                        attempt: p.attempt,
                    }),
                    _ => queue.push_back((
                        p.experiment,
                        p.log_pos,
                        p.attempt,
                        p.not_before_s,
                    )),
                }
            }
            // refill the partial screen rung exactly as checkpointed:
            // scores recompute identically (the cost model is pure) and
            // the restored counters already include these candidates
            for (experiment, log_pos) in resume.screen_pending {
                reserved.insert(experiment.fingerprint);
                match screen.as_mut() {
                    Some(tier) => {
                        let score = tier.score(&experiment.write.genome);
                        tier.restore(score, (experiment, log_pos));
                    }
                    // unreachable with a checkpoint-persisted config;
                    // promote unscreened rather than drop planned work
                    None => queue.push_back((experiment, log_pos)),
                }
            }
        }
        let every = self.config.checkpoint_every.max(1);
        let mut completions = 0u64;
        loop {
            if self.halt_reached() {
                self.halted = true;
                return Ok(());
            }
            // refill: plan whenever the queue cannot feed the free
            // lane capacity and budget remains
            while !planning_dead && stalls < 8 && queue.len() + in_flight.len() < cap {
                // candidates awaiting a screen decision are counted as
                // committed (conservative: a rejection frees the room
                // back to the planner on a later refill)
                let committed = self.platform.submissions()
                    + in_flight.len() as u64
                    + queue.len() as u64
                    + screen.as_ref().map_or(0, |t| t.pending() as u64);
                let room = self.config.max_submissions.saturating_sub(committed);
                if room == 0 {
                    break;
                }
                self.iteration += 1;
                let Some(group) = self.plan_group(room, &reserved) else {
                    planning_dead = true;
                    break;
                };
                self.sched.planning_rounds += 1;
                self.sched.replanned_duplicates += group.duplicates_skipped;
                if group.experiments.is_empty() {
                    stalls += 1;
                } else {
                    stalls = 0;
                }
                let log_pos = self.logs.len();
                self.logs.push(IterationLog {
                    iteration: self.iteration,
                    selection: group.selection,
                    avenue_names: group.avenue_names,
                    chosen_experiments: group.chosen_experiments,
                    submitted_ids: Vec::new(),
                });
                let screened_now = if screen.is_some() {
                    group.experiments.len() as u64
                } else {
                    0
                };
                self.journal_plan(log_pos, screened_now, group.lint_rejected.len() as u64);
                // Lint-gate rejects ledger immediately after their
                // plan record: they hold no reservation and take no
                // queue slot, so the journal order (plan, then its
                // rejects, then completions) matches the live
                // `submitted_ids` order a resume reconstructs. Empty
                // — and no new code path — while the gate is off.
                for (experiment, errors) in std::mem::take(&mut group.lint_rejected) {
                    let id = self.record_lint_reject(experiment, errors, log_pos);
                    self.logs[log_pos].submitted_ids.push(id);
                }
                for experiment in group.experiments {
                    reserved.insert(experiment.fingerprint);
                    match screen.as_mut() {
                        None => queue.push_back((experiment, log_pos, 0, 0.0)),
                        Some(tier) => {
                            self.sched.screened += 1;
                            let score = tier.score(&experiment.write.genome);
                            if let Some(out) = tier.push_scored(score, (experiment, log_pos)) {
                                absorb_screen_outcome(
                                    out,
                                    &mut queue,
                                    &mut reserved,
                                    &mut self.sched,
                                );
                            }
                        }
                    }
                }
            }
            // a partial rung strands candidates once planning can no
            // longer feed it (dead, stalled, or out of budget): when
            // nothing is queued or in flight to change that, decide it
            // now with the same keep rule
            if let Some(tier) = screen.as_mut() {
                if queue.is_empty() && in_flight.is_empty() && tier.pending() > 0 {
                    absorb_screen_outcome(tier.flush(), &mut queue, &mut reserved, &mut self.sched);
                }
            }
            // feed: move planned experiments onto lanes up to the cap
            while in_flight.len() < cap {
                let Some((experiment, log_pos, attempt, not_before_s)) = queue.pop_front()
                else {
                    break;
                };
                let ticket = self.platform.submit_stream_retry(
                    &experiment.write.genome,
                    not_before_s,
                    attempt,
                );
                in_flight.push(InFlightChild {
                    ticket,
                    experiment,
                    log_pos,
                    attempt,
                });
                if skip_depth > 0 {
                    skip_depth -= 1; // re-fed: sampled before the crash
                } else {
                    self.sched.sample_depth(in_flight.len() as u64);
                }
            }
            // drain: fold the earliest virtual completion into the
            // ledger; nothing in flight means nothing left to do
            let Some(done) = self.platform.poll_completed() else {
                break;
            };
            // journal this completion's fault events before anything
            // can checkpoint past them (empty — and no store write —
            // with the fault model off); they also carry the fault
            // kind the retry decision keys on
            let events = self.drain_fault_events();
            let pos = in_flight
                .iter()
                .position(|c| c.ticket == done.ticket)
                .expect("completion for an unknown ticket");
            let child = in_flight.remove(pos);
            let prov = super::Provenance {
                submitted_at: done
                    .submission_index
                    .map(|i| i + 1)
                    .unwrap_or_else(|| self.platform.submissions()),
                cached: done.cached,
                submission_index: done.submission_index,
                plan: Some(child.log_pos),
                screened: screen.is_some(),
                lint: Vec::new(),
            };
            if done.outcome.is_fault() {
                let committed = self.platform.submissions()
                    + in_flight.len() as u64
                    + queue.len() as u64
                    + screen.as_ref().map_or(0, |t| t.pending() as u64);
                match self.fault_retry_decision(&events, &done, child.attempt, committed) {
                    Some(backoff) => {
                        // the failed attempt joins the ledger (its
                        // journal record replays this platform log
                        // line on rebuild); the fingerprint stays
                        // reserved — the same child is going straight
                        // back into the queue
                        let id = self.record_fault_attempt(
                            &child.experiment,
                            done.outcome.clone(),
                            prov,
                        );
                        self.logs[child.log_pos].submitted_ids.push(id);
                        self.note_fault_retry(
                            done.submission_index,
                            child.attempt + 1,
                            done.completed_at_s,
                        );
                        queue.push_back((
                            child.experiment,
                            child.log_pos,
                            child.attempt + 1,
                            done.completed_at_s + backoff,
                        ));
                    }
                    None => {
                        self.note_fault_abandon(
                            done.submission_index,
                            child.attempt,
                            done.completed_at_s,
                        );
                        reserved.remove(&child.experiment.fingerprint);
                        let id = self.record_experiment(child.experiment, done.outcome, prov);
                        self.logs[child.log_pos].submitted_ids.push(id);
                    }
                }
            } else {
                reserved.remove(&child.experiment.fingerprint);
                let id = self.record_experiment(child.experiment, done.outcome, prov);
                self.logs[child.log_pos].submitted_ids.push(id);
            }
            // the ledger just changed, so a duplicate streak is no
            // longer evidence that planning is exhausted — re-arm it.
            // (At one lane nothing is ever in flight while a dud
            // streak runs, so this cannot fire there and lockstep
            // bit-identity is untouched.)
            stalls = 0;
            completions += 1;
            if completions % every == 0 {
                let pending: Vec<super::PendingRef<'_>> = in_flight
                    .iter()
                    .map(|c| super::PendingRef {
                        experiment: &c.experiment,
                        log_pos: c.log_pos,
                        attempt: c.attempt,
                        not_before_s: 0.0,
                        // faults-on checkpoints persist in-flight work
                        // as live platform entries keyed by ticket;
                        // faults-off ones roll the platform back and
                        // re-dispatch, so no ticket is recorded
                        ticket: if faults_on { Some(c.ticket) } else { None },
                    })
                    .chain(queue.iter().map(|(e, p, a, nb)| super::PendingRef {
                        experiment: e,
                        log_pos: *p,
                        attempt: *a,
                        not_before_s: *nb,
                        ticket: None,
                    }))
                    .collect();
                let screen_pending: Vec<(&PlannedExperiment, usize)> = screen
                    .as_ref()
                    .map(|t| t.pending_payloads().map(|(e, p)| (e, *p)).collect())
                    .unwrap_or_default();
                // reattached in-flight children never re-feed on a
                // faults-on resume, so no depth samples are skipped
                let skip = if faults_on { 0 } else { in_flight.len() };
                self.write_checkpoint(
                    stalls,
                    planning_dead,
                    &pending,
                    skip,
                    &screen_pending,
                )?;
            }
        }
        // the loop only breaks with the queue, lanes, and screen rung
        // all drained (the flush step decides any stranded rung before
        // the drain step can observe an empty pipeline)
        debug_assert!(screen.iter().all(|t| t.pending() == 0));
        self.write_checkpoint(stalls, planning_dead, &[], 0, &[])
    }
}

#[cfg(test)]
mod tests {
    use crate::config::RunConfig;
    use crate::scientist::ScientistRun;
    use crate::workload::Workload;

    fn pipeline_config(seed: u64, budget: u64, lanes: u32) -> RunConfig {
        RunConfig::default()
            .with_seed(seed)
            .with_budget(budget)
            .with_parallelism(lanes)
            .with_pipeline(true)
    }

    #[test]
    fn pipeline_run_completes_within_budget_and_dedups() {
        let mut run = ScientistRun::new(pipeline_config(9, 30, 3)).unwrap();
        let outcome = run.run_to_completion().unwrap();
        assert!(outcome.submissions <= 30);
        assert!(outcome.pipeline.pipelined);
        assert_eq!(outcome.pipeline.lanes, 3);
        // every ledger entry consumed a real submission (duplicates
        // were replanned, never submitted)
        assert_eq!(run.population.len() as u64, outcome.submissions);
        let fps: std::collections::HashSet<String> = run
            .population
            .members()
            .iter()
            .map(|m| m.genome.fingerprint())
            .collect();
        assert_eq!(fps.len(), run.population.len(), "no duplicate ever submitted");
    }

    #[test]
    fn pipeline_depth_respects_the_inflight_cap() {
        let mut cfg = pipeline_config(5, 24, 2);
        cfg.inflight_per_lane = 2;
        let mut run = ScientistRun::new(cfg).unwrap();
        let outcome = run.run_to_completion().unwrap();
        assert!(outcome.pipeline.max_in_flight <= 4, "cap = lanes x depth");
        assert!(outcome.pipeline.mean_in_flight > 0.0);
        assert!(outcome.pipeline.planning_rounds > 0);
    }

    #[test]
    fn pipeline_curve_stays_monotone() {
        let mut run = ScientistRun::new(pipeline_config(1, 36, 4)).unwrap();
        let outcome = run.run_to_completion().unwrap();
        let pts = &outcome.curve.points;
        assert!(!pts.is_empty());
        for w in pts.windows(2) {
            assert!(w[1].best_geomean_us <= w[0].best_geomean_us);
        }
    }

    #[test]
    fn pipeline_logs_attribute_children_to_their_planning_round() {
        let mut run = ScientistRun::new(pipeline_config(3, 28, 4)).unwrap();
        run.run_to_completion().unwrap();
        assert!(!run.logs.is_empty());
        let mut logged = 0usize;
        for log in &run.logs {
            assert!(log.submitted_ids.len() <= log.chosen_experiments.len());
            logged += log.submitted_ids.len();
        }
        let seeds = run.workload.starting_population().len();
        assert_eq!(logged + seeds, run.population.len());
    }
}
