//! Checkpoint serialization: the crash-safe snapshot a `resume`
//! restores from (DESIGN.md §9).
//!
//! A checkpoint carries exactly the state that is **not** a pure
//! function of the journal prefix it names:
//!
//! * the full [`RunConfig`] (resume is self-contained);
//! * the agents' surrogate-LLM RNG stream and the findings document;
//! * the platform's rolled-back accounting
//!   ([`crate::eval::PlatformCheckpoint`]): lane clocks, busy time,
//!   tickets, counted cache stats, and the backend RNG states (parent,
//!   and the pre-spawn state the stream lane workers re-fork from);
//! * scheduler position: iteration counter, stall streak, and — for
//!   the pipeline — every planned-but-uncommitted experiment, which
//!   the resumed scheduler re-feeds through the normal submission path;
//! * `journal_bytes`, the journal length this snapshot is consistent
//!   with — resume truncates the journal file to it, discarding any
//!   entries the crash left beyond the checkpoint.
//!
//! Full-width u64s (RNG words) travel as hex strings
//! ([`crate::util::json::u64_hex`]); everything else is plain JSON.
//! Writes are atomic (temp file + rename) so a crash mid-checkpoint
//! leaves the previous checkpoint intact.

use std::path::Path;

use crate::config::RunConfig;
use crate::eval::PlatformCheckpoint;
use crate::genome::KernelGenome;
use crate::util::json::{
    self, parse_str_arr, parse_u64_hex, req_bool, req_f64, req_str, req_u64, str_arr, u64_hex,
    Json,
};

pub const CHECKPOINT_FILE: &str = "checkpoint.json";
/// Bumped 1 → 2 when `PendingPlan.fingerprint` changed from the
/// rendered string fingerprint to the hex-encoded u64 content hash —
/// older stores fail with the explicit version error instead of an
/// opaque hex-parse error. Bumped 2 → 3 when the analytic screen tier
/// (DESIGN.md §10) added `screen_pending`, the screen counters in
/// `sched`, and the `[screen]` knobs in `config`. Bumped 3 → 4 when
/// the profile layer (DESIGN.md §11) added per-experiment
/// `ProfileReport`s to journal `exp` records and the `[profile]` knob
/// to `config` — a resume must not silently drop profile-era ledger
/// state onto a pre-profile replayer or vice versa. The federation
/// layer (DESIGN.md §12) added `platform.federated_hits` and the
/// journal `federated` flag *without* a bump: both parse tolerantly
/// (absent → 0 / false), so pre-federation checkpoints restore
/// unchanged and federation-off checkpoints are byte-identical to
/// version-4 ones. The lint layer (DESIGN.md §13) follows the same
/// no-bump pattern: `sched.linted`/`sched.lint_rejected`, the journal
/// `linted`/`lint` fields, and the `[lint]` config knobs all emit only
/// when set and parse tolerantly when absent. So does the fault model
/// (DESIGN.md §14): `sched.fault_retries`/`sched.fault_abandoned`, the
/// `platform.faults` state object, the pending entries' retry metadata
/// (`attempt`/`not_before_s`/`ticket`), and the `[faults]` config knobs
/// all emit only on enabled runs and parse tolerantly when absent.
const VERSION: u64 = 4;

/// Scheduler counters snapshot (mirrors the run's private
/// `SchedCounters` — see `scientist::pipeline`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedSnapshot {
    pub planning_rounds: u64,
    pub replanned_duplicates: u64,
    pub depth_total: u64,
    pub depth_samples: u64,
    pub max_in_flight: u64,
    pub screened: u64,
    pub screen_promoted: u64,
    pub screen_rejected: u64,
    /// Children checked by the lint gate (DESIGN.md §13); 0 while
    /// `[lint] gate` is off. Emitted only when nonzero.
    pub linted: u64,
    /// Children the gate rejected pre-submission. Emitted only when
    /// nonzero.
    pub lint_rejected: u64,
    /// Fault-class completions the recovery layer requeued (DESIGN.md
    /// §14); 0 while `[faults]` is off. Emitted only when nonzero.
    pub fault_retries: u64,
    /// Fault-class completions abandoned to the ledger. Emitted only
    /// when nonzero.
    pub fault_abandoned: u64,
}

/// One planned-but-uncommitted experiment (queued or in flight at
/// checkpoint time). The resumed pipeline re-submits these, in order,
/// before planning anything new.
#[derive(Debug, Clone)]
pub struct PendingPlan {
    pub base_id: String,
    pub reference_id: String,
    pub description: String,
    /// Genome content hash (the planner's dedup key); travels as a
    /// hex string like the RNG words — u64s don't fit [`Json::Num`].
    pub fingerprint: u64,
    pub log_pos: usize,
    pub genome: KernelGenome,
    pub applied: Vec<String>,
    pub skipped: Vec<String>,
    pub repairs: Vec<String>,
    pub report: String,
    pub diff: String,
    /// Recovery-layer retry metadata (DESIGN.md §14) — which dispatch
    /// attempt this is, and the earliest virtual time it may start.
    /// Always `(0, 0.0)` on faults-off runs and emitted only when set,
    /// so off-checkpoints stay byte-identical to pre-faults output.
    pub attempt: u32,
    pub not_before_s: f64,
    /// For a faults-on checkpoint's in-flight entries: the platform
    /// pending-entry ticket to reattach to on resume (the entry itself
    /// is persisted as data inside `platform.faults`). `None` for
    /// queued work and on every faults-off checkpoint.
    pub ticket: Option<u64>,
}

/// The full snapshot (see module docs).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub config: RunConfig,
    pub journal_bytes: u64,
    pub ledger_len: usize,
    pub logs_len: usize,
    pub iteration: usize,
    pub stalls: u32,
    pub planning_dead: bool,
    pub sched: SchedSnapshot,
    pub llm_rng: [u64; 4],
    pub findings: Json,
    pub platform: PlatformCheckpoint,
    pub pending: Vec<PendingPlan>,
    /// How many `pending` entries were already in flight (their depth
    /// samples are in `sched`; the resumed feed skips re-sampling them).
    pub skip_depth: usize,
    /// The screen tier's partial rung at checkpoint time, in submission
    /// order (DESIGN.md §10). The resumed pipeline re-scores and
    /// re-fills the rung from these; its counters already include them.
    /// Always empty in lockstep runs (batch-scoped rungs).
    pub screen_pending: Vec<PendingPlan>,
    /// Informational leaderboard summary (rendered by `replay`; never
    /// used for restore).
    pub best_id: Option<String>,
    pub best_geomean_us: Option<f64>,
}

fn rng_words(state: &[u64; 4]) -> Json {
    Json::Arr(state.iter().map(|&w| u64_hex(w)).collect())
}

fn parse_rng_words(v: Option<&Json>, what: &str) -> Result<[u64; 4], String> {
    let arr = v
        .and_then(|x| x.as_arr())
        .ok_or_else(|| format!("checkpoint: missing {what}"))?;
    if arr.len() != 4 {
        return Err(format!("checkpoint: {what} wants 4 words, got {}", arr.len()));
    }
    let mut out = [0u64; 4];
    for (i, w) in arr.iter().enumerate() {
        out[i] = parse_u64_hex(w).map_err(|e| format!("checkpoint {what}[{i}]: {e}"))?;
    }
    Ok(out)
}

impl PendingPlan {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("base", Json::Str(self.base_id.clone())),
            ("reference", Json::Str(self.reference_id.clone())),
            ("description", Json::Str(self.description.clone())),
            ("fingerprint", u64_hex(self.fingerprint)),
            ("log_pos", Json::Num(self.log_pos as f64)),
            ("genome", self.genome.to_json()),
            ("applied", str_arr(&self.applied)),
            ("skipped", str_arr(&self.skipped)),
            ("repairs", str_arr(&self.repairs)),
            ("report", Json::Str(self.report.clone())),
            ("diff", Json::Str(self.diff.clone())),
        ];
        // emitted only when set: faults-off checkpoints stay
        // byte-identical to pre-faults ones
        if self.attempt > 0 {
            pairs.push(("attempt", Json::Num(self.attempt as f64)));
        }
        if self.not_before_s > 0.0 {
            pairs.push(("not_before_s", Json::Num(self.not_before_s)));
        }
        if let Some(t) = self.ticket {
            pairs.push(("ticket", Json::Num(t as f64)));
        }
        Json::obj(pairs)
    }

    fn from_json(v: &Json) -> Result<PendingPlan, String> {
        Ok(PendingPlan {
            base_id: req_str(v, "base")?.to_string(),
            reference_id: req_str(v, "reference")?.to_string(),
            description: req_str(v, "description")?.to_string(),
            fingerprint: parse_u64_hex(
                v.get("fingerprint")
                    .ok_or("checkpoint: pending missing fingerprint")?,
            )
            .map_err(|e| format!("checkpoint pending fingerprint: {e}"))?,
            log_pos: req_u64(v, "log_pos")? as usize,
            genome: KernelGenome::from_json(
                v.get("genome").ok_or("checkpoint: pending missing genome")?,
            )?,
            applied: parse_str_arr(v.get("applied"), "applied")?,
            skipped: parse_str_arr(v.get("skipped"), "skipped")?,
            repairs: parse_str_arr(v.get("repairs"), "repairs")?,
            report: req_str(v, "report")?.to_string(),
            diff: req_str(v, "diff")?.to_string(),
            // tolerant: pre-faults and faults-off checkpoints carry none
            attempt: match v.get("attempt") {
                None | Some(Json::Null) => 0,
                Some(x) => x.as_u64().ok_or("checkpoint: bad pending attempt")? as u32,
            },
            not_before_s: match v.get("not_before_s") {
                None | Some(Json::Null) => 0.0,
                Some(x) => x.as_f64().ok_or("checkpoint: bad pending not_before_s")?,
            },
            ticket: match v.get("ticket") {
                None | Some(Json::Null) => None,
                Some(x) => Some(x.as_u64().ok_or("checkpoint: bad pending ticket")?),
            },
        })
    }
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        let p = &self.platform;
        Json::obj(vec![
            ("version", Json::Num(VERSION as f64)),
            ("config", self.config.to_json()),
            ("journal_bytes", Json::Num(self.journal_bytes as f64)),
            ("ledger_len", Json::Num(self.ledger_len as f64)),
            ("logs_len", Json::Num(self.logs_len as f64)),
            ("iteration", Json::Num(self.iteration as f64)),
            ("stalls", Json::Num(self.stalls as f64)),
            ("planning_dead", Json::Bool(self.planning_dead)),
            ("sched", {
                let mut pairs = vec![
                    ("planning_rounds", Json::Num(self.sched.planning_rounds as f64)),
                    (
                        "replanned_duplicates",
                        Json::Num(self.sched.replanned_duplicates as f64),
                    ),
                    ("depth_total", Json::Num(self.sched.depth_total as f64)),
                    ("depth_samples", Json::Num(self.sched.depth_samples as f64)),
                    ("max_in_flight", Json::Num(self.sched.max_in_flight as f64)),
                    ("screened", Json::Num(self.sched.screened as f64)),
                    (
                        "screen_promoted",
                        Json::Num(self.sched.screen_promoted as f64),
                    ),
                    (
                        "screen_rejected",
                        Json::Num(self.sched.screen_rejected as f64),
                    ),
                ];
                // emitted only when nonzero: lint-off checkpoints stay
                // byte-identical to pre-lint ones
                if self.sched.linted > 0 {
                    pairs.push(("linted", Json::Num(self.sched.linted as f64)));
                }
                if self.sched.lint_rejected > 0 {
                    pairs.push((
                        "lint_rejected",
                        Json::Num(self.sched.lint_rejected as f64),
                    ));
                }
                // same rule for the recovery layer (DESIGN.md §14)
                if self.sched.fault_retries > 0 {
                    pairs.push((
                        "fault_retries",
                        Json::Num(self.sched.fault_retries as f64),
                    ));
                }
                if self.sched.fault_abandoned > 0 {
                    pairs.push((
                        "fault_abandoned",
                        Json::Num(self.sched.fault_abandoned as f64),
                    ));
                }
                Json::obj(pairs)
            }),
            ("llm_rng", rng_words(&self.llm_rng)),
            ("findings", self.findings.clone()),
            ("platform", {
                let mut pairs = vec![
                    (
                        "lane_busy_until",
                        Json::Arr(p.lane_busy_until.iter().map(|&t| Json::Num(t)).collect()),
                    ),
                    ("busy_lane_s", Json::Num(p.busy_lane_s)),
                    ("next_ticket", Json::Num(p.next_ticket as f64)),
                    ("cache_hits", Json::Num(p.cache_hits as f64)),
                    ("cache_misses", Json::Num(p.cache_misses as f64)),
                    ("backend", p.backend.clone()),
                    (
                        "prespawn_backend",
                        p.prespawn_backend.clone().unwrap_or(Json::Null),
                    ),
                    ("stream_threaded", Json::Bool(p.stream_threaded)),
                    ("stream_log_start", Json::Num(p.stream_log_start as f64)),
                ];
                // emitted only when nonzero: federation-off checkpoints
                // stay byte-identical to pre-federation ones
                if p.federated_hits > 0 {
                    pairs.push(("federated_hits", Json::Num(p.federated_hits as f64)));
                }
                // only on faults-enabled runs: lane health, fault
                // counters, and in-flight pending persisted as data
                // (DESIGN.md §14)
                if let Some(f) = &p.faults {
                    pairs.push(("faults", f.clone()));
                }
                Json::obj(pairs)
            }),
            (
                "pending",
                Json::Arr(self.pending.iter().map(|p| p.to_json()).collect()),
            ),
            ("skip_depth", Json::Num(self.skip_depth as f64)),
            (
                "screen_pending",
                Json::Arr(self.screen_pending.iter().map(|p| p.to_json()).collect()),
            ),
            (
                "best_id",
                self.best_id
                    .clone()
                    .map(Json::Str)
                    .unwrap_or(Json::Null),
            ),
            (
                "best_geomean_us",
                self.best_geomean_us.map(Json::Num).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Checkpoint, String> {
        let version = req_u64(v, "version")?;
        if version != VERSION {
            return Err(format!(
                "checkpoint version {version} unsupported (this build reads {VERSION})"
            ));
        }
        let sched = v.get("sched").ok_or("checkpoint: missing sched")?;
        let p = v.get("platform").ok_or("checkpoint: missing platform")?;
        let lane_busy_until = p
            .get("lane_busy_until")
            .and_then(|x| x.as_arr())
            .ok_or("checkpoint: missing lane_busy_until")?
            .iter()
            .map(|t| t.as_f64().ok_or("checkpoint: bad lane clock".to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Checkpoint {
            config: RunConfig::from_json(
                v.get("config").ok_or("checkpoint: missing config")?,
            )?,
            journal_bytes: req_u64(v, "journal_bytes")?,
            ledger_len: req_u64(v, "ledger_len")? as usize,
            logs_len: req_u64(v, "logs_len")? as usize,
            iteration: req_u64(v, "iteration")? as usize,
            stalls: req_u64(v, "stalls")? as u32,
            planning_dead: req_bool(v, "planning_dead")?,
            sched: SchedSnapshot {
                planning_rounds: req_u64(sched, "planning_rounds")?,
                replanned_duplicates: req_u64(sched, "replanned_duplicates")?,
                depth_total: req_u64(sched, "depth_total")?,
                depth_samples: req_u64(sched, "depth_samples")?,
                max_in_flight: req_u64(sched, "max_in_flight")?,
                screened: req_u64(sched, "screened")?,
                screen_promoted: req_u64(sched, "screen_promoted")?,
                screen_rejected: req_u64(sched, "screen_rejected")?,
                // tolerant: pre-lint checkpoints carry neither counter
                linted: match sched.get("linted") {
                    None | Some(Json::Null) => 0,
                    Some(x) => x.as_u64().ok_or("checkpoint: bad linted")?,
                },
                lint_rejected: match sched.get("lint_rejected") {
                    None | Some(Json::Null) => 0,
                    Some(x) => x.as_u64().ok_or("checkpoint: bad lint_rejected")?,
                },
                // tolerant: pre-faults checkpoints carry neither counter
                fault_retries: match sched.get("fault_retries") {
                    None | Some(Json::Null) => 0,
                    Some(x) => x.as_u64().ok_or("checkpoint: bad fault_retries")?,
                },
                fault_abandoned: match sched.get("fault_abandoned") {
                    None | Some(Json::Null) => 0,
                    Some(x) => x.as_u64().ok_or("checkpoint: bad fault_abandoned")?,
                },
            },
            llm_rng: parse_rng_words(v.get("llm_rng"), "llm_rng")?,
            findings: v
                .get("findings")
                .ok_or("checkpoint: missing findings")?
                .clone(),
            platform: PlatformCheckpoint {
                lane_busy_until,
                busy_lane_s: req_f64(p, "busy_lane_s")?,
                next_ticket: req_u64(p, "next_ticket")?,
                cache_hits: req_u64(p, "cache_hits")?,
                cache_misses: req_u64(p, "cache_misses")?,
                backend: p
                    .get("backend")
                    .ok_or("checkpoint: missing backend state")?
                    .clone(),
                prespawn_backend: match p.get("prespawn_backend") {
                    None | Some(Json::Null) => None,
                    Some(s) => Some(s.clone()),
                },
                stream_threaded: req_bool(p, "stream_threaded")?,
                stream_log_start: req_u64(p, "stream_log_start")?,
                federated_hits: match p.get("federated_hits") {
                    None | Some(Json::Null) => 0,
                    Some(x) => x
                        .as_f64()
                        .ok_or("checkpoint: bad federated_hits")?
                        as u64,
                },
                // tolerant: absent on pre-faults and faults-off runs
                faults: match p.get("faults") {
                    None | Some(Json::Null) => None,
                    Some(f) => Some(f.clone()),
                },
            },
            pending: v
                .get("pending")
                .and_then(|x| x.as_arr())
                .ok_or("checkpoint: missing pending")?
                .iter()
                .map(PendingPlan::from_json)
                .collect::<Result<Vec<_>, _>>()?,
            skip_depth: req_u64(v, "skip_depth")? as usize,
            screen_pending: v
                .get("screen_pending")
                .and_then(|x| x.as_arr())
                .ok_or("checkpoint: missing screen_pending")?
                .iter()
                .map(PendingPlan::from_json)
                .collect::<Result<Vec<_>, _>>()?,
            best_id: match v.get("best_id") {
                None | Some(Json::Null) => None,
                Some(s) => Some(
                    s.as_str()
                        .ok_or("checkpoint: bad best_id")?
                        .to_string(),
                ),
            },
            best_geomean_us: match v.get("best_geomean_us") {
                None | Some(Json::Null) => None,
                Some(x) => Some(x.as_f64().ok_or("checkpoint: bad best_geomean_us")?),
            },
        })
    }

    /// Atomically persist to `<dir>/checkpoint.json`: write a temp
    /// file, fsync it, then rename over the previous checkpoint — a
    /// crash mid-write leaves the old snapshot intact.
    pub fn write_atomic(&self, dir: &Path) -> Result<(), String> {
        let tmp = dir.join(format!("{CHECKPOINT_FILE}.tmp"));
        let target = dir.join(CHECKPOINT_FILE);
        let text = self.to_json().to_string();
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .map_err(|e| format!("{}: {e}", tmp.display()))?;
            f.write_all(text.as_bytes())
                .and_then(|_| f.sync_all())
                .map_err(|e| format!("{}: {e}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &target).map_err(|e| format!("{}: {e}", target.display()))
    }

    /// Load `<dir>/checkpoint.json`.
    pub fn load(dir: &Path) -> Result<Checkpoint, String> {
        let path = dir.join(CHECKPOINT_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (was this run started with [store]?)", path.display()))?;
        Checkpoint::from_json(&json::parse(&text).map_err(|e| e.to_string())?)
    }
}
