//! Journal records: the append-only experiment ledger (DESIGN.md §9).
//!
//! One JSON object per line, tagged by `"t"`:
//!
//! * `"exp"` — one evaluated (or cache-served) kernel: the full
//!   [`Individual`] plus its evaluation provenance (submission index,
//!   lane, completion time, cache flag) and the planning round that
//!   produced it. The ledger's population, convergence curve, platform
//!   log, and eval-cache contents are all pure functions of the `exp`
//!   sequence — [`rebuild`] recomputes them.
//! * `"plan"` — one select → design → write round: the selection
//!   triple (base / reference / rationale, App. A.1), the avenue list,
//!   and the chosen experiment descriptions. Together with the `exp`
//!   records' `plan` back-references these reconstruct every
//!   [`IterationLog`] transcript.
//! * `"fault"` — one typed fault/recovery event from the fault model's
//!   recovery layer (DESIGN.md §14): injected faults, retries,
//!   abandons, lane quarantines/readmissions/retirements. Present only
//!   on `[faults]`-enabled runs, so faults-off journal bytes are
//!   identical to a build without the layer. Telemetry, not state:
//!   [`rebuild`] skips them (the `exp` sequence already replays the
//!   ledger) and `replay` renders them.
//!
//! Records are self-describing so `replay` can re-render a campaign
//! without evaluating anything, and strict enough that `resume` can
//! verify the rebuilt ledger against the checkpoint.

use crate::agents::{ReferencePolicy, Selection};
use crate::eval::{FaultRecord, SubmissionRecord};
use crate::genome::KernelGenome;
use crate::metrics::ConvergenceCurve;
use crate::population::{EvalOutcome, Individual, Population};
use crate::scientist::IterationLog;
use crate::sim::ProfileReport;
use crate::util::json::{self, parse_str_arr, req_bool, req_str, req_u64, str_arr, Json};
use crate::workload::GemmConfig;

/// One journal line.
#[derive(Debug, Clone)]
pub enum JournalRecord {
    Plan(PlanRecord),
    Exp(ExperimentRecord),
    Fault(FaultRecord),
}

/// One select → design → write round (`"t":"plan"`).
#[derive(Debug, Clone)]
pub struct PlanRecord {
    pub iteration: usize,
    /// Position of this round's [`IterationLog`] in the run's
    /// transcript (`exp` records reference it via `plan`).
    pub log_pos: usize,
    pub base_id: String,
    pub reference_id: String,
    pub policy: Option<ReferencePolicy>,
    pub rationale: String,
    pub avenues: Vec<String>,
    pub chosen: Vec<String>,
    /// How many of this round's children entered the analytic screen
    /// tier (DESIGN.md §10); 0 while `[screen]` is disabled. Absent in
    /// pre-screen journals (parsed as 0).
    pub screened: u64,
    /// How many of this round's children the static lint gate rejected
    /// before submission (DESIGN.md §13); 0 while `[lint] gate` is
    /// disabled. Emitted only when nonzero, so lint-off journals — and
    /// pre-lint journals, which parse as 0 — stay byte-identical.
    pub linted: u64,
}

/// One ledger entry (`"t":"exp"`).
#[derive(Debug, Clone)]
pub struct ExperimentRecord {
    pub individual: Individual,
    /// 1-based submission count at which the result became available
    /// (the convergence curve's x-axis).
    pub submitted_at: u64,
    /// Index in the platform submission log; `None` for cache hits.
    pub submission_index: Option<u64>,
    /// Served from the eval cache (no quota, no platform time).
    pub cached: bool,
    /// Virtual lane that evaluated the submission (`None` for cache
    /// hits) — restore replays each lane's committed FIFO prefix.
    pub lane: Option<u32>,
    /// Simulated completion time (`None` for cache hits).
    pub completed_at_s: Option<f64>,
    /// Back-reference to the producing plan's `log_pos` (`None` for
    /// seeds and bootstrap probes).
    pub plan: Option<usize>,
    /// Passed through the analytic screen tier before submission
    /// (DESIGN.md §10). Absent in pre-screen journals (parsed false).
    pub screened: bool,
    /// Bottleneck-classified counter profile (DESIGN.md §11). `None`
    /// when the backend has no counter model or the genome failed its
    /// gates. Absent in pre-profile journals (parsed `None`).
    pub profile: Option<ProfileReport>,
    /// Served from the federated cross-run store (DESIGN.md §12):
    /// quota and clock advanced, no backend evaluated it. Emitted only
    /// when true, so federation-off journals — and pre-federation
    /// journals, which parse as false — stay byte-identical.
    pub federated: bool,
    /// Error-severity lint codes that rejected this entry at the gate
    /// (DESIGN.md §13): no lane, no quota, no platform time. Emitted
    /// only when non-empty, so lint-off journals — and pre-lint
    /// journals, which parse as empty — stay byte-identical.
    pub lint: Vec<String>,
}

fn policy_token(p: ReferencePolicy) -> &'static str {
    match p {
        ReferencePolicy::DivergentPath => "divergent_path",
        ReferencePolicy::DirectParent => "direct_parent",
        ReferencePolicy::PerConfigSpecialist => "per_config_specialist",
    }
}

fn parse_policy(s: &str) -> Result<ReferencePolicy, String> {
    match s {
        "divergent_path" => Ok(ReferencePolicy::DivergentPath),
        "direct_parent" => Ok(ReferencePolicy::DirectParent),
        "per_config_specialist" => Ok(ReferencePolicy::PerConfigSpecialist),
        other => Err(format!("unknown reference policy '{other}'")),
    }
}

fn opt_num(v: Option<f64>) -> Json {
    v.map(Json::Num).unwrap_or(Json::Null)
}

/// Streaming JSON-object writer shared by the `plan`/`exp` emitters:
/// one comma/key/value grammar instead of the two hand-interleaved
/// `push_str` chains PR 6/7 grew. Callers emit fields in sorted key
/// order themselves — that ordering is the byte-identity contract with
/// the tree emitter ([`JournalRecord::to_json`]), refereed by
/// `streamed_record_matches_tree_emitter`. Keys must not need JSON
/// escaping (ours are ASCII identifiers).
struct FieldWriter<'a> {
    out: &'a mut String,
    first: bool,
}

impl<'a> FieldWriter<'a> {
    fn new(out: &'a mut String) -> Self {
        out.push('{');
        FieldWriter { out, first: true }
    }

    /// Emit the separator + `"key":` prefix and hand back the buffer
    /// for the value — the escape hatch nested `write_json` values
    /// (individual, profile) stream through.
    fn value_slot(&mut self, key: &str) -> &mut String {
        if self.first {
            self.first = false;
        } else {
            self.out.push(',');
        }
        self.out.push('"');
        self.out.push_str(key);
        self.out.push_str("\":");
        self.out
    }

    fn num(&mut self, key: &str, v: f64) {
        json::push_num_value(self.value_slot(key), v);
    }

    fn opt_num(&mut self, key: &str, v: Option<f64>) {
        match v {
            Some(v) => self.num(key, v),
            None => self.null(key),
        }
    }

    fn str(&mut self, key: &str, v: &str) {
        json::push_str_value(self.value_slot(key), v);
    }

    fn bool(&mut self, key: &str, v: bool) {
        self.value_slot(key).push_str(if v { "true" } else { "false" });
    }

    fn null(&mut self, key: &str) {
        self.value_slot(key).push_str("null");
    }

    fn str_arr(&mut self, key: &str, items: &[String]) {
        let out = self.value_slot(key);
        out.push('[');
        for (i, s) in items.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            json::push_str_value(out, s);
        }
        out.push(']');
    }

    fn finish(self) {
        self.out.push('}');
    }
}

impl JournalRecord {
    pub fn to_json(&self) -> Json {
        match self {
            JournalRecord::Plan(p) => {
                let mut pairs = vec![
                    ("t", Json::Str("plan".into())),
                    ("iteration", Json::Num(p.iteration as f64)),
                    ("log_pos", Json::Num(p.log_pos as f64)),
                    ("base", Json::Str(p.base_id.clone())),
                    ("reference", Json::Str(p.reference_id.clone())),
                    (
                        "policy",
                        p.policy
                            .map(|pol| Json::Str(policy_token(pol).into()))
                            .unwrap_or(Json::Null),
                    ),
                    ("rationale", Json::Str(p.rationale.clone())),
                    ("avenues", str_arr(&p.avenues)),
                    ("chosen", str_arr(&p.chosen)),
                    ("screened", Json::Num(p.screened as f64)),
                ];
                // only-when-nonzero: lint-off journal bytes are
                // identical to a build without the analyzer
                if p.linted > 0 {
                    pairs.push(("linted", Json::Num(p.linted as f64)));
                }
                Json::obj(pairs)
            }
            JournalRecord::Exp(e) => {
                let mut pairs = vec![
                    ("t", Json::Str("exp".into())),
                    ("ind", e.individual.to_json()),
                    ("submitted_at", Json::Num(e.submitted_at as f64)),
                    (
                        "submission_index",
                        opt_num(e.submission_index.map(|i| i as f64)),
                    ),
                    ("cached", Json::Bool(e.cached)),
                    ("lane", opt_num(e.lane.map(|l| l as f64))),
                    ("completed_at_s", opt_num(e.completed_at_s)),
                    ("plan", opt_num(e.plan.map(|p| p as f64))),
                    ("screened", Json::Bool(e.screened)),
                    (
                        "profile",
                        e.profile
                            .as_ref()
                            .map(|p| p.to_json())
                            .unwrap_or(Json::Null),
                    ),
                ];
                // only-when-true: federation-off journal bytes are
                // identical to a build without the federation layer
                if e.federated {
                    pairs.push(("federated", Json::Bool(true)));
                }
                // only-when-non-empty: same rule for the lint gate
                if !e.lint.is_empty() {
                    pairs.push(("lint", str_arr(&e.lint)));
                }
                Json::obj(pairs)
            }
            JournalRecord::Fault(f) => f.to_json(),
        }
    }

    /// Stream this record as one JSONL line (no trailing newline) into
    /// `out`, byte-identical to `self.to_json().to_string()` — same
    /// sorted key order, same escaping and number formatting — but
    /// with no intermediate [`Json`] tree or per-entry `String`
    /// (§Perf; the store's append path reuses one buffer). The tree
    /// form stays as the parse-side contract and golden reference
    /// (`streamed_record_matches_tree_emitter`).
    pub fn write_json(&self, out: &mut String) {
        match self {
            JournalRecord::Plan(p) => {
                let mut w = FieldWriter::new(out);
                w.str_arr("avenues", &p.avenues);
                w.str("base", &p.base_id);
                w.str_arr("chosen", &p.chosen);
                w.num("iteration", p.iteration as f64);
                if p.linted > 0 {
                    w.num("linted", p.linted as f64);
                }
                w.num("log_pos", p.log_pos as f64);
                match p.policy {
                    Some(pol) => w.str("policy", policy_token(pol)),
                    None => w.null("policy"),
                }
                w.str("rationale", &p.rationale);
                w.str("reference", &p.reference_id);
                w.num("screened", p.screened as f64);
                w.str("t", "plan");
                w.finish();
            }
            JournalRecord::Exp(e) => {
                let mut w = FieldWriter::new(out);
                w.bool("cached", e.cached);
                w.opt_num("completed_at_s", e.completed_at_s);
                if e.federated {
                    w.bool("federated", true);
                }
                e.individual.write_json(w.value_slot("ind"));
                w.opt_num("lane", e.lane.map(f64::from));
                if !e.lint.is_empty() {
                    w.str_arr("lint", &e.lint);
                }
                w.opt_num("plan", e.plan.map(|p| p as f64));
                match &e.profile {
                    Some(p) => p.write_json(w.value_slot("profile")),
                    None => w.null("profile"),
                }
                w.bool("screened", e.screened);
                w.opt_num("submission_index", e.submission_index.map(|i| i as f64));
                w.num("submitted_at", e.submitted_at as f64);
                w.str("t", "exp");
                w.finish();
            }
            JournalRecord::Fault(f) => f.write_json(out),
        }
    }

    pub fn from_json(v: &Json) -> Result<JournalRecord, String> {
        let tag = v
            .get("t")
            .and_then(|x| x.as_str())
            .ok_or("journal: record without tag")?;
        match tag {
            "plan" => Ok(JournalRecord::Plan(PlanRecord {
                iteration: req_u64(v, "iteration")? as usize,
                log_pos: req_u64(v, "log_pos")? as usize,
                base_id: req_str(v, "base")?.to_string(),
                reference_id: req_str(v, "reference")?.to_string(),
                policy: match v.get("policy") {
                    None | Some(Json::Null) => None,
                    Some(p) => Some(parse_policy(
                        p.as_str().ok_or("journal: non-string policy")?,
                    )?),
                },
                rationale: req_str(v, "rationale")?.to_string(),
                avenues: parse_str_arr(v.get("avenues"), "avenues")?,
                chosen: parse_str_arr(v.get("chosen"), "chosen")?,
                // tolerant: journals written before the screen tier
                // have no "screened" key — nothing was screened
                screened: match v.get("screened") {
                    None | Some(Json::Null) => 0,
                    Some(x) => x.as_u64().ok_or("journal: bad screened count")?,
                },
                // tolerant: the key exists only on gated rounds —
                // pre-lint and lint-off journals omit it
                linted: match v.get("linted") {
                    None | Some(Json::Null) => 0,
                    Some(x) => x.as_u64().ok_or("journal: bad linted count")?,
                },
            })),
            "exp" => Ok(JournalRecord::Exp(ExperimentRecord {
                individual: Individual::from_json(
                    v.get("ind").ok_or("journal: exp missing ind")?,
                )?,
                submitted_at: req_u64(v, "submitted_at")?,
                submission_index: match v.get("submission_index") {
                    None | Some(Json::Null) => None,
                    Some(x) => Some(
                        x.as_u64().ok_or("journal: bad submission_index")?,
                    ),
                },
                cached: req_bool(v, "cached")?,
                lane: match v.get("lane") {
                    None | Some(Json::Null) => None,
                    Some(x) => Some(x.as_u64().ok_or("journal: bad lane")? as u32),
                },
                completed_at_s: match v.get("completed_at_s") {
                    None | Some(Json::Null) => None,
                    Some(x) => Some(x.as_f64().ok_or("journal: bad completed_at_s")?),
                },
                plan: match v.get("plan") {
                    None | Some(Json::Null) => None,
                    Some(x) => Some(x.as_u64().ok_or("journal: bad plan")? as usize),
                },
                screened: match v.get("screened") {
                    None | Some(Json::Null) => false,
                    Some(x) => x.as_bool().ok_or("journal: bad screened flag")?,
                },
                // tolerant: journals written before the profile layer
                // have no "profile" key — no counter snapshot exists
                profile: match v.get("profile") {
                    None | Some(Json::Null) => None,
                    Some(p) => Some(ProfileReport::from_json(p)?),
                },
                // tolerant: the key exists only on federated hits —
                // pre-federation and federation-off journals omit it
                federated: match v.get("federated") {
                    None | Some(Json::Null) => false,
                    Some(x) => x.as_bool().ok_or("journal: bad federated flag")?,
                },
                // tolerant: the key exists only on lint-gate rejects —
                // pre-lint and lint-off journals omit it
                lint: match v.get("lint") {
                    None | Some(Json::Null) => Vec::new(),
                    some => parse_str_arr(some, "lint")?,
                },
            })),
            "fault" => Ok(JournalRecord::Fault(FaultRecord::from_json(v)?)),
            other => Err(format!("journal: unknown record tag '{other}'")),
        }
    }
}

/// Everything [`rebuild`] derives from the journal: the run state the
/// checkpoint does **not** need to duplicate.
pub struct RebuiltLedger {
    pub population: Population,
    pub curve: ConvergenceCurve,
    pub logs: Vec<IterationLog>,
    /// Platform submission log (committed submissions, in order).
    pub log_entries: Vec<SubmissionRecord>,
    /// Eval-cache contents (genome content hash → outcome of every
    /// evaluation).
    pub cache_entries: Vec<(u64, EvalOutcome)>,
    /// Genomes aligned with `log_entries` (the lane-replay input).
    pub committed_genomes: Vec<KernelGenome>,
}

/// Reconstruct the run state the journal encodes. `strict` is the
/// resume path (the journal was truncated to the checkpoint, so any
/// inconsistency is corruption); replay passes `false` and tolerates a
/// dangling plan reference from a mid-write crash tail.
pub fn rebuild(
    records: &[JournalRecord],
    feedback_configs: Vec<GemmConfig>,
    strict: bool,
) -> Result<RebuiltLedger, String> {
    let mut logs: Vec<IterationLog> = Vec::new();
    for rec in records {
        if let JournalRecord::Plan(p) = rec {
            if p.log_pos != logs.len() {
                return Err(format!(
                    "journal: plan at log_pos {} but {} transcripts rebuilt",
                    p.log_pos,
                    logs.len()
                ));
            }
            logs.push(IterationLog {
                iteration: p.iteration,
                selection: Selection {
                    base_id: p.base_id.clone(),
                    reference_id: p.reference_id.clone(),
                    policy: p.policy,
                    rationale: p.rationale.clone(),
                },
                avenue_names: p.avenues.clone(),
                chosen_experiments: p.chosen.clone(),
                submitted_ids: Vec::new(),
            });
        }
    }
    let mut population = Population::new(feedback_configs);
    let mut curve = ConvergenceCurve::default();
    let mut log_entries: Vec<SubmissionRecord> = Vec::new();
    let mut cache_entries: Vec<(u64, EvalOutcome)> = Vec::new();
    let mut committed_genomes: Vec<KernelGenome> = Vec::new();
    for rec in records {
        let JournalRecord::Exp(e) = rec else { continue };
        // mirror ScientistRun::record_individual's curve update exactly
        if let Some(ts) = e.individual.outcome.timings() {
            curve.record(e.submitted_at as usize, crate::metrics::geomean(ts));
        } else if let Some(best) = curve.best() {
            curve.record(e.submitted_at as usize, best);
        }
        if let Some(index) = e.submission_index {
            if index as usize != log_entries.len() {
                return Err(format!(
                    "journal: submission {index} out of order (expected {})",
                    log_entries.len()
                ));
            }
            let lane = e.lane.ok_or("journal: committed exp without lane")?;
            let completed_at_s = e
                .completed_at_s
                .ok_or("journal: committed exp without completed_at_s")?;
            log_entries.push(SubmissionRecord {
                index,
                completed_at_s,
                lane,
                outcome: e.individual.outcome.clone(),
                profile: e.profile.clone(),
                federated: e.federated,
            });
            // fault-class outcomes never entered the eval cache (the
            // platform gates the insert, DESIGN.md §14) — mirroring
            // that here keeps the rebuilt cache byte-faithful
            if !e.individual.outcome.is_fault() {
                cache_entries.push((
                    e.individual.genome.fingerprint_hash(),
                    e.individual.outcome.clone(),
                ));
            }
            committed_genomes.push(e.individual.genome.clone());
        }
        if let Some(plan) = e.plan {
            match logs.get_mut(plan) {
                Some(log) => log.submitted_ids.push(e.individual.id.clone()),
                None if strict => {
                    return Err(format!(
                        "journal: exp {} references missing plan {plan}",
                        e.individual.id
                    ))
                }
                None => {} // replay tolerance: crash-torn plan line
            }
        }
        population.add(e.individual.clone());
    }
    Ok(RebuiltLedger {
        population,
        curve,
        logs,
        log_entries,
        cache_entries,
        committed_genomes,
    })
}

/// Parse journal text into records. A parse failure on the **final**
/// non-empty line is reported separately (`torn`) so callers can treat
/// a mid-write crash tail as expected (`replay`) or as corruption
/// (`resume` — which never sees one, because it truncates the journal
/// to the checkpoint's recorded length first).
pub fn parse_journal(text: &str) -> Result<(Vec<JournalRecord>, bool), String> {
    let lines: Vec<(usize, &str)> = text
        .lines()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .collect();
    let mut records = Vec::with_capacity(lines.len());
    for (pos, (lineno, line)) in lines.iter().enumerate() {
        let parsed = json::parse(line)
            .map_err(|e| e.to_string())
            .and_then(|v| JournalRecord::from_json(&v));
        match parsed {
            Ok(rec) => records.push(rec),
            // torn final line: everything before it is intact
            Err(_) if pos + 1 == lines.len() => return Ok((records, true)),
            Err(e) => return Err(format!("journal line {}: {e}", lineno + 1)),
        }
    }
    Ok((records, false))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;

    fn sample_records() -> Vec<JournalRecord> {
        vec![
            JournalRecord::Plan(PlanRecord {
                iteration: 3,
                log_pos: 2,
                base_id: "00007".into(),
                reference_id: "00004".into(),
                policy: Some(ReferencePolicy::DivergentPath),
                rationale: "divergent \"path\" → branch\nline".into(),
                avenues: vec!["a".into(), "b\tc".into()],
                chosen: vec!["x".into()],
                screened: 3,
                linted: 0,
            }),
            JournalRecord::Plan(PlanRecord {
                iteration: 1,
                log_pos: 0,
                base_id: "00002".into(),
                reference_id: "00001".into(),
                policy: None,
                rationale: String::new(),
                avenues: vec![],
                chosen: vec![],
                screened: 0,
                linted: 0,
            }),
            JournalRecord::Exp(ExperimentRecord {
                individual: Individual {
                    id: "00009".into(),
                    parents: vec!["00007".into(), "00004".into()],
                    genome: seeds::human_oracle(),
                    experiment: "exp désc 😀".into(),
                    report: "ok".into(),
                    outcome: EvalOutcome::Timings(vec![90.5, 100.0, 3.25, 7.0, 1e6, 0.125]),
                },
                submitted_at: 9,
                submission_index: Some(8),
                cached: false,
                lane: Some(2),
                completed_at_s: Some(810.0),
                plan: Some(2),
                screened: true,
                federated: false,
                lint: Vec::new(),
                profile: Some(ProfileReport {
                    compute_us: 10.5,
                    lds_us: 2.25,
                    mem_us: 41.0,
                    occupancy_us: 0.125,
                    launch_us: 1.5,
                    bottleneck: crate::sim::Bottleneck::Memory,
                    secondary: Some(crate::sim::Bottleneck::Compute),
                }),
            }),
            JournalRecord::Exp(ExperimentRecord {
                individual: Individual {
                    id: "00010".into(),
                    parents: vec![],
                    genome: seeds::naive_hip(),
                    experiment: String::new(),
                    report: String::new(),
                    outcome: EvalOutcome::CompileFailure("LDS \\ overflow".into()),
                },
                submitted_at: 10,
                submission_index: None,
                cached: true,
                lane: None,
                completed_at_s: None,
                plan: None,
                screened: false,
                federated: false,
                lint: Vec::new(),
                profile: None,
            }),
        ]
    }

    #[test]
    fn streamed_record_matches_tree_emitter() {
        // the store's append path streams; byte-identity with the tree
        // emitter keeps the journal format (and journal_bytes
        // accounting) exactly what from_json/parse_journal expect
        for (i, rec) in sample_records().iter().enumerate() {
            let mut streamed = String::new();
            rec.write_json(&mut streamed);
            assert_eq!(streamed, rec.to_json().to_string(), "record {i}");
        }
    }

    #[test]
    fn pre_screen_journal_lines_parse_with_zero_defaults() {
        // journals written before the screen tier have no "screened"
        // key; they must parse as unscreened, not error
        let mut line = String::new();
        sample_records()[0].write_json(&mut line);
        let stripped = line.replace(",\"screened\":3", "");
        assert_ne!(stripped, line, "fixture lost its screened key");
        let JournalRecord::Plan(p) =
            JournalRecord::from_json(&json::parse(&stripped).unwrap()).unwrap()
        else {
            panic!("tag lost");
        };
        assert_eq!(p.screened, 0);
        let mut line = String::new();
        sample_records()[2].write_json(&mut line);
        let stripped = line.replace(",\"screened\":true", "");
        assert_ne!(stripped, line, "fixture lost its screened key");
        let JournalRecord::Exp(e) =
            JournalRecord::from_json(&json::parse(&stripped).unwrap()).unwrap()
        else {
            panic!("tag lost");
        };
        assert!(!e.screened);
    }

    #[test]
    fn pre_profile_journal_lines_parse_with_none_profile() {
        // journals written before the profile layer have no "profile"
        // key; they must parse as profile-less, not error
        let records = sample_records();
        let JournalRecord::Exp(e) = &records[2] else {
            panic!("fixture moved");
        };
        let mut profile_json = String::new();
        e.profile.as_ref().unwrap().write_json(&mut profile_json);
        let mut line = String::new();
        records[2].write_json(&mut line);
        let stripped = line.replace(&format!(",\"profile\":{profile_json}"), "");
        assert_ne!(stripped, line, "fixture lost its profile key");
        let JournalRecord::Exp(parsed) =
            JournalRecord::from_json(&json::parse(&stripped).unwrap()).unwrap()
        else {
            panic!("tag lost");
        };
        assert_eq!(parsed.profile, None);
        // other fields survive the stripped parse unchanged
        assert_eq!(parsed.submission_index, e.submission_index);
        assert!(parsed.screened);
    }

    #[test]
    fn federated_flag_emits_only_when_set_and_parses_tolerantly() {
        let records = sample_records();
        let JournalRecord::Exp(e) = &records[2] else {
            panic!("fixture moved");
        };
        // non-federated entries never carry the key: federation-off
        // journal bytes match a build without the federation layer
        let mut base_line = String::new();
        records[2].write_json(&mut base_line);
        assert!(!base_line.contains("federated"), "{base_line}");
        let JournalRecord::Exp(parsed) =
            JournalRecord::from_json(&json::parse(&base_line).unwrap()).unwrap()
        else {
            panic!("tag lost");
        };
        assert!(!parsed.federated, "absent key parses as false");
        // a federated hit emits the key, streamed == tree, roundtrips
        let mut fed = e.clone();
        fed.federated = true;
        let fed_rec = JournalRecord::Exp(fed);
        let mut line = String::new();
        fed_rec.write_json(&mut line);
        assert_eq!(line, fed_rec.to_json().to_string());
        assert!(
            line.contains(",\"federated\":true,\"ind\":"),
            "sorted between completed_at_s and ind: {line}"
        );
        let JournalRecord::Exp(parsed) =
            JournalRecord::from_json(&json::parse(&line).unwrap()).unwrap()
        else {
            panic!("tag lost");
        };
        assert!(parsed.federated);
    }

    #[test]
    fn lint_fields_emit_only_when_set_and_parse_tolerantly() {
        let records = sample_records();
        // lint-off lines never carry the keys: lint-off journal bytes
        // match a build without the analyzer
        for rec in &records {
            let mut line = String::new();
            rec.write_json(&mut line);
            assert!(!line.contains("lint"), "{line}");
        }
        let JournalRecord::Plan(p) = &records[0] else {
            panic!("fixture moved");
        };
        let mut gated = p.clone();
        gated.linted = 2;
        let gated_rec = JournalRecord::Plan(gated);
        let mut line = String::new();
        gated_rec.write_json(&mut line);
        assert_eq!(line, gated_rec.to_json().to_string());
        assert!(
            line.contains(",\"linted\":2,\"log_pos\":"),
            "sorted between iteration and log_pos: {line}"
        );
        let JournalRecord::Plan(parsed) =
            JournalRecord::from_json(&json::parse(&line).unwrap()).unwrap()
        else {
            panic!("tag lost");
        };
        assert_eq!(parsed.linted, 2);
        // exp records: rejected codes round-trip, sorted after lane
        let JournalRecord::Exp(e) = &records[2] else {
            panic!("fixture moved");
        };
        let mut rej = e.clone();
        rej.lint = vec!["L001-lds-over-budget".into(), "L030-workload-inadmissible".into()];
        let rej_rec = JournalRecord::Exp(rej);
        let mut line = String::new();
        rej_rec.write_json(&mut line);
        assert_eq!(line, rej_rec.to_json().to_string());
        assert!(
            line.contains(",\"lint\":[\"L001-lds-over-budget\""),
            "{line}"
        );
        let JournalRecord::Exp(parsed) =
            JournalRecord::from_json(&json::parse(&line).unwrap()).unwrap()
        else {
            panic!("tag lost");
        };
        assert_eq!(parsed.lint.len(), 2);
        assert_eq!(parsed.lint[1], "L030-workload-inadmissible");
    }

    #[test]
    fn streamed_record_roundtrips_through_parse() {
        let mut text = String::new();
        for rec in sample_records() {
            rec.write_json(&mut text);
            text.push('\n');
        }
        let (records, torn) = parse_journal(&text).unwrap();
        assert!(!torn);
        assert_eq!(records.len(), 4);
        let JournalRecord::Exp(e) = &records[2] else {
            panic!("tag lost");
        };
        assert_eq!(e.individual.id, "00009");
        assert_eq!(e.lane, Some(2));
        let original = sample_records();
        let JournalRecord::Exp(o) = &original[2] else {
            panic!("fixture moved");
        };
        assert_eq!(e.profile, o.profile, "profile survives the round-trip");
    }
}
