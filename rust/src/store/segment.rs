//! Indexed binary journal segments: the `compact` representation of a
//! JSONL ledger (DESIGN.md §12).
//!
//! A JSONL journal is the right *write* format — append-only,
//! crash-tolerant, human-greppable — but the wrong *cold-load* format:
//! opening a 1M-entry federated archive means parsing every line. A
//! segment keeps the exact line bytes (so rehydration back to JSONL is
//! byte-identical — the checkpoint `journal_bytes` contract survives
//! compaction) but prefixes each record with its length and appends a
//! fingerprint/offset index block, so a reader that only needs the
//! index — "which fingerprints does this archive hold, and where" —
//! touches O(index) bytes, never the records ([`open_index`]).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic "GKSSEG1\n" (version 1)
//! 8       8     record_count: u64
//! 16      8     index_offset: u64 (absolute)
//! 24      4     records_crc: u32 (IEEE CRC-32 of bytes [32, index_offset))
//! 28      4     index_crc:   u32 (IEEE CRC-32 of the index block)
//! 32      ...   records: record_count x { len: u32, line: [u8; len] }
//! index_offset  index: record_count x { fingerprint: u64, offset: u64 }
//! ```
//!
//! `fingerprint` is the journaled genome's u64 content hash for `exp`
//! records and 0 for `plan` records (0 is reserved: the genome hash's
//! non-zero seed constant makes a zero fingerprint unreachable).
//! `offset` is the absolute file offset of the record's length prefix.
//!
//! Torn or tampered segments are rejected, never partially served: the
//! header is fixed-size, both regions are CRC-checked against it, and
//! the file length must equal `index_offset + 16 * record_count`
//! exactly. Writes go through a temp file + rename ([`write_segment`]),
//! so a crash mid-compaction leaves the original JSONL untouched.

use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

/// Compacted journal file name inside a run store directory (the
/// sibling of `journal.jsonl` — `compact` replaces one with the other).
pub const SEGMENT_FILE: &str = "journal.seg";

const MAGIC: &[u8; 8] = b"GKSSEG1\n";
const HEADER_LEN: u64 = 32;
const INDEX_ENTRY_LEN: u64 = 16;

/// IEEE CRC-32 lookup table (reflected polynomial 0xEDB88320),
/// computed at compile time — no external crate, no runtime init.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 == 1 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// IEEE CRC-32 (the zlib/PNG polynomial) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// The O(index) view of a segment: every record's fingerprint and file
/// offset, without reading a single record byte.
#[derive(Debug, Clone)]
pub struct SegmentIndex {
    /// `(fingerprint, record offset)` in record order. Fingerprint 0
    /// marks a non-`exp` (plan) record.
    pub entries: Vec<(u64, u64)>,
}

impl SegmentIndex {
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().expect("bounds checked"))
}

fn get_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().expect("bounds checked"))
}

/// Write `records` — `(fingerprint, line)` pairs, line bytes exactly as
/// they appeared in the JSONL journal (no trailing newline) — as a
/// segment at `path`. Atomic: staged in `<path>.tmp`, renamed into
/// place, so readers never observe a half-written segment.
pub fn write_segment(path: &Path, records: &[(u64, &str)]) -> Result<(), String> {
    let mut buf: Vec<u8> = Vec::with_capacity(
        HEADER_LEN as usize
            + records
                .iter()
                .map(|(_, l)| l.len() + 4 + INDEX_ENTRY_LEN as usize)
                .sum::<usize>(),
    );
    buf.extend_from_slice(&[0u8; HEADER_LEN as usize]); // header patched below
    let mut index: Vec<(u64, u64)> = Vec::with_capacity(records.len());
    for (fp, line) in records {
        if line.len() as u64 > u32::MAX as u64 {
            return Err(format!("segment record exceeds u32 length: {}", line.len()));
        }
        index.push((*fp, buf.len() as u64));
        put_u32(&mut buf, line.len() as u32);
        buf.extend_from_slice(line.as_bytes());
    }
    let index_offset = buf.len() as u64;
    for &(fp, off) in &index {
        put_u64(&mut buf, fp);
        put_u64(&mut buf, off);
    }
    let records_crc = crc32(&buf[HEADER_LEN as usize..index_offset as usize]);
    let index_crc = crc32(&buf[index_offset as usize..]);
    buf[0..8].copy_from_slice(MAGIC);
    buf[8..16].copy_from_slice(&(records.len() as u64).to_le_bytes());
    buf[16..24].copy_from_slice(&index_offset.to_le_bytes());
    buf[24..28].copy_from_slice(&records_crc.to_le_bytes());
    buf[28..32].copy_from_slice(&index_crc.to_le_bytes());
    let tmp = path.with_extension("seg.tmp");
    std::fs::write(&tmp, &buf).map_err(|e| format!("{}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(())
}

/// Parse and sanity-check a segment header. Returns
/// `(record_count, index_offset, records_crc, index_crc)`.
fn parse_header(h: &[u8], file_len: u64, path: &Path) -> Result<(u64, u64, u32, u32), String> {
    if h.len() < HEADER_LEN as usize {
        return Err(format!("{}: truncated segment header", path.display()));
    }
    if &h[0..8] != MAGIC {
        return Err(format!("{}: not a GKSSEG1 segment", path.display()));
    }
    let record_count = get_u64(h, 8);
    let index_offset = get_u64(h, 16);
    let records_crc = get_u32(h, 24);
    let index_crc = get_u32(h, 28);
    if index_offset < HEADER_LEN {
        return Err(format!("{}: index offset inside header", path.display()));
    }
    let expect_len = index_offset
        .checked_add(record_count.checked_mul(INDEX_ENTRY_LEN).ok_or_else(|| {
            format!("{}: index size overflows", path.display())
        })?)
        .ok_or_else(|| format!("{}: segment size overflows", path.display()))?;
    if file_len != expect_len {
        return Err(format!(
            "{}: segment is {file_len} bytes but header covers {expect_len} — torn or truncated",
            path.display()
        ));
    }
    Ok((record_count, index_offset, records_crc, index_crc))
}

/// Open a segment's index **without reading the records region**: the
/// fixed-size header plus `16 * record_count` index bytes are the only
/// I/O — O(index) regardless of how many megabytes of records the
/// segment holds. The index block is CRC-verified; the records region
/// is not touched (full verification is [`read_lines`]'s job).
pub fn open_index(path: &Path) -> Result<SegmentIndex, String> {
    let mut file =
        std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let file_len = file
        .metadata()
        .map_err(|e| format!("{}: {e}", path.display()))?
        .len();
    let mut header = [0u8; HEADER_LEN as usize];
    file.read_exact(&mut header)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let (record_count, index_offset, _records_crc, index_crc) =
        parse_header(&header, file_len, path)?;
    file.seek(SeekFrom::Start(index_offset))
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let mut index_bytes = vec![0u8; (record_count * INDEX_ENTRY_LEN) as usize];
    file.read_exact(&mut index_bytes)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    if crc32(&index_bytes) != index_crc {
        return Err(format!("{}: index CRC mismatch", path.display()));
    }
    let mut entries = Vec::with_capacity(record_count as usize);
    for i in 0..record_count as usize {
        let at = i * INDEX_ENTRY_LEN as usize;
        let fp = get_u64(&index_bytes, at);
        let off = get_u64(&index_bytes, at + 8);
        if off < HEADER_LEN || off + 4 > index_offset {
            return Err(format!("{}: index entry {i} out of bounds", path.display()));
        }
        entries.push((fp, off));
    }
    Ok(SegmentIndex { entries })
}

/// Read one record by its index offset: a seek plus two small reads —
/// the point-lookup path a fingerprint probe takes after [`open_index`].
pub fn read_record_at(path: &Path, offset: u64) -> Result<String, String> {
    let mut file =
        std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    file.seek(SeekFrom::Start(offset))
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let mut len_bytes = [0u8; 4];
    file.read_exact(&mut len_bytes)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    let mut line = vec![0u8; len];
    file.read_exact(&mut line)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    String::from_utf8(line).map_err(|_| format!("{}: record is not UTF-8", path.display()))
}

/// Read every record line (full verification: header geometry plus
/// both CRCs). The returned lines are byte-identical to the JSONL
/// journal the segment was compacted from, in order — joining them
/// with `'\n'` (plus a trailing newline) rehydrates the exact journal.
pub fn read_lines(path: &Path) -> Result<Vec<String>, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let (record_count, index_offset, records_crc, index_crc) =
        parse_header(&bytes, bytes.len() as u64, path)?;
    let records_region = &bytes[HEADER_LEN as usize..index_offset as usize];
    if crc32(records_region) != records_crc {
        return Err(format!("{}: records CRC mismatch", path.display()));
    }
    if crc32(&bytes[index_offset as usize..]) != index_crc {
        return Err(format!("{}: index CRC mismatch", path.display()));
    }
    let mut lines = Vec::with_capacity(record_count as usize);
    let mut at = 0usize;
    while at < records_region.len() {
        if at + 4 > records_region.len() {
            return Err(format!("{}: torn record length prefix", path.display()));
        }
        let len = get_u32(records_region, at) as usize;
        at += 4;
        if at + len > records_region.len() {
            return Err(format!("{}: torn record body", path.display()));
        }
        let line = std::str::from_utf8(&records_region[at..at + len])
            .map_err(|_| format!("{}: record is not UTF-8", path.display()))?;
        lines.push(line.to_string());
        at += len;
    }
    if lines.len() as u64 != record_count {
        return Err(format!(
            "{}: header promises {record_count} records, region holds {}",
            path.display(),
            lines.len()
        ));
    }
    Ok(lines)
}

/// Rehydrate the exact JSONL text a segment was compacted from (one
/// trailing newline per record — the journal's append invariant).
pub fn rehydrate_jsonl(path: &Path) -> Result<String, String> {
    let lines = read_lines(path)?;
    let mut text = String::with_capacity(lines.iter().map(|l| l.len() + 1).sum());
    for line in &lines {
        text.push_str(line);
        text.push('\n');
    }
    Ok(text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::test_support::scratch_dir;

    fn sample() -> Vec<(u64, String)> {
        vec![
            (0, r#"{"t":"plan","iteration":1}"#.to_string()),
            (0x1234_5678_9abc_def0, r#"{"t":"exp","ind":"x"}"#.to_string()),
            (u64::MAX, String::new()), // empty record line survives
            (42, "päyload \u{1F600}".to_string()),
        ]
    }

    fn write_sample(dir: &std::path::Path) -> std::path::PathBuf {
        let path = dir.join(SEGMENT_FILE);
        let records: Vec<(u64, &str)> =
            sample().iter().map(|(fp, l)| (*fp, l.as_str())).collect();
        write_segment(&path, &records).unwrap();
        path
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // the IEEE check value ("123456789" -> 0xCBF43926) pins the
        // polynomial/reflection conventions
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_preserves_lines_and_order() {
        let dir = scratch_dir("segment-roundtrip");
        let path = write_sample(&dir);
        let lines = read_lines(&path).unwrap();
        let expect: Vec<String> = sample().into_iter().map(|(_, l)| l).collect();
        assert_eq!(lines, expect);
        let text = rehydrate_jsonl(&path).unwrap();
        assert_eq!(text, expect.join("\n") + "\n");
    }

    #[test]
    fn index_carries_fingerprints_and_point_reads_resolve() {
        let dir = scratch_dir("segment-index");
        let path = write_sample(&dir);
        let index = open_index(&path).unwrap();
        let fps: Vec<u64> = index.entries.iter().map(|&(fp, _)| fp).collect();
        assert_eq!(fps, vec![0, 0x1234_5678_9abc_def0, u64::MAX, 42]);
        for (i, &(_, off)) in index.entries.iter().enumerate() {
            assert_eq!(read_record_at(&path, off).unwrap(), sample()[i].1, "record {i}");
        }
    }

    #[test]
    fn empty_segment_roundtrips() {
        let dir = scratch_dir("segment-empty");
        let path = dir.join(SEGMENT_FILE);
        write_segment(&path, &[]).unwrap();
        assert!(open_index(&path).unwrap().is_empty());
        assert_eq!(read_lines(&path).unwrap().len(), 0);
        assert_eq!(rehydrate_jsonl(&path).unwrap(), "");
    }

    #[test]
    fn torn_and_tampered_segments_are_rejected() {
        let dir = scratch_dir("segment-torn");
        let path = write_sample(&dir);
        let good = std::fs::read(&path).unwrap();
        // truncation anywhere: header geometry no longer matches
        for cut in [good.len() - 1, good.len() - 20, 31, 8, 0] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(open_index(&path).is_err(), "cut at {cut} accepted by index");
            assert!(read_lines(&path).is_err(), "cut at {cut} accepted by reader");
        }
        // a flipped record byte passes the index open (which never
        // reads records) but fails the full read's CRC
        let mut flipped = good.clone();
        flipped[40] ^= 0xFF;
        std::fs::write(&path, &flipped).unwrap();
        assert!(open_index(&path).is_ok());
        let err = read_lines(&path).unwrap_err();
        assert!(err.contains("CRC"), "{err}");
        // a flipped index byte fails even the O(index) open
        let mut flipped = good.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0xFF;
        std::fs::write(&path, &flipped).unwrap();
        assert!(open_index(&path).unwrap_err().contains("CRC"));
        // wrong magic is rejected outright
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        std::fs::write(&path, &bad_magic).unwrap();
        assert!(read_lines(&path).unwrap_err().contains("GKSSEG1"));
        // trailing garbage is a geometry mismatch, not silently ignored
        let mut padded = good.clone();
        padded.push(0);
        std::fs::write(&path, &padded).unwrap();
        assert!(open_index(&path).is_err());
    }

    #[test]
    fn write_is_atomic_no_tmp_left_behind() {
        let dir = scratch_dir("segment-atomic");
        let path = write_sample(&dir);
        assert!(path.exists());
        assert!(!path.with_extension("seg.tmp").exists());
    }
}
