//! Replay: re-render a persisted run's transcripts, convergence curve,
//! and lineage from the journal alone — no evaluation, no RNG, no
//! platform (DESIGN.md §9). The audit path: everything the `run`
//! command printed live is reconstructible after the fact.

use std::path::Path;

use super::{checkpoint::Checkpoint, journal, segment, JOURNAL_FILE};
use crate::config::RunConfig;
use crate::metrics::ConvergenceCurve;
use crate::population::Population;
use crate::scientist::IterationLog;
use crate::workload::Workload;

/// A run reconstructed from its journal.
pub struct ReplayedRun {
    pub config: RunConfig,
    pub workload: String,
    pub population: Population,
    pub logs: Vec<IterationLog>,
    pub curve: ConvergenceCurve,
    /// Committed (quota-consuming) submissions recorded.
    pub submissions: u64,
    /// True when the journal ended in a torn line (crash mid-append);
    /// the torn tail is dropped, everything before it is rendered.
    pub torn_tail: bool,
}

/// Rebuild a run from `<dir>`'s journal. Unlike `resume`, replay reads
/// the **full** journal — including entries past the last checkpoint —
/// because it renders what happened rather than reconstructing a
/// consistent execution state; a torn final line (crash mid-write) is
/// tolerated and reported via [`ReplayedRun::torn_tail`].
pub fn replay(dir: &Path) -> Result<ReplayedRun, String> {
    let cp = Checkpoint::load(dir)?;
    let workload = crate::workload::lookup(&cp.config.workload)
        .ok_or_else(|| format!("unknown workload '{}' in checkpoint", cp.config.workload))?;
    let path = dir.join(JOURNAL_FILE);
    // a compacted store serves replay from its segment directly (no
    // rehydration write — replay never modifies the store); segments
    // are written whole, so a torn tail is impossible there
    let text = if path.exists() {
        std::fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?
    } else {
        segment::rehydrate_jsonl(&dir.join(segment::SEGMENT_FILE))?
    };
    let (records, torn_tail) = journal::parse_journal(&text)?;
    let ledger = journal::rebuild(
        &records,
        workload.feedback_suite().configs,
        /* strict= */ false,
    )?;
    Ok(ReplayedRun {
        workload: cp.config.workload.clone(),
        config: cp.config,
        submissions: ledger.log_entries.len() as u64,
        population: ledger.population,
        logs: ledger.logs,
        curve: ledger.curve,
        torn_tail,
    })
}
