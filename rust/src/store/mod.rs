//! The durable run store: crash-safe persistence of the evolutionary
//! archive (DESIGN.md §9).
//!
//! The paper's loop works by "strategically selecting promising prior
//! code versions as a basis for new iterations" (§3.1) — the archive
//! *is* the asset. This module makes it durable: every experiment is
//! journaled to `<dir>/journal.jsonl` as it lands (genome, lineage,
//! selector rationale, writer self-report, verifier verdict, timings,
//! virtual-clock metadata), and the run periodically snapshots the
//! non-derivable remainder — RNG streams, platform clocks, eval-cache
//! stats, pending pipeline work — to `<dir>/checkpoint.json`
//! ([`checkpoint`]).
//!
//! Crash model: journal lines are appended before the in-memory state
//! advances past them, and checkpoints are written atomically (temp +
//! rename). After a crash, `resume` loads the last checkpoint,
//! **truncates the journal to the length that checkpoint is consistent
//! with**, rebuilds the ledger from the journal prefix
//! ([`journal::rebuild`]), restores the RNG streams and platform
//! accounting, and continues — bit-identically to a run that never
//! crashed (`tests/resume.rs` locks this for every registered workload
//! under both schedulers). `replay` ([`replay`]) re-renders transcripts
//! and reports from the journal alone, without evaluating anything.
//!
//! Store writes are **fail-stop**: an I/O error aborts the run (panic)
//! rather than silently continuing with an unpersisted ledger — a
//! durability subsystem that drops writes is worse than none.

pub mod checkpoint;
pub mod federation;
pub mod journal;
pub mod replay;
pub mod segment;

use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

pub use checkpoint::{Checkpoint, PendingPlan, SchedSnapshot};
pub use federation::{config_digest, FedEntry, FederationSnapshot, FederationStats};
pub use journal::{ExperimentRecord, JournalRecord, PlanRecord, RebuiltLedger};
pub use replay::{replay, ReplayedRun};
pub use segment::SEGMENT_FILE;

pub const JOURNAL_FILE: &str = "journal.jsonl";
const CAMPAIGN_MANIFEST: &str = "campaign.json";

/// Append handle on a run's store directory.
///
/// Appends go through a persistent [`BufWriter`] and a reusable
/// serialization buffer (§Perf): one streamed JSONL emission per
/// entry — no per-entry `Json` tree or `String` — flushed to the OS
/// at the end of every append. That flush keeps the pre-streaming
/// flush points exactly: one `write` syscall per record, so a record
/// is in the OS page cache (and survives a process kill) the moment
/// `append` returns — the "journaled as it lands" crash property
/// `replay` depends on. Fsync still happens only at checkpoints
/// ([`RunStore::write_checkpoint`]), so a checkpoint never names
/// journal bytes that are not durably on disk.
pub struct RunStore {
    dir: PathBuf,
    journal: BufWriter<std::fs::File>,
    /// Reused per-append serialization buffer.
    line: String,
    journal_bytes: u64,
}

impl RunStore {
    /// Start a fresh store in `dir` (created if needed). Any previous
    /// journal **and checkpoint** there are removed — `run` starts a
    /// new campaign; only `resume` continues one. Removing the old
    /// checkpoint first matters: a crash before this run's first
    /// checkpoint must leave "no checkpoint" (a clear error), never a
    /// stale checkpoint paired with the new run's journal.
    pub fn create(dir: &Path) -> Result<RunStore, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        for stale in [
            checkpoint::CHECKPOINT_FILE.to_string(),
            format!("{}.tmp", checkpoint::CHECKPOINT_FILE),
            // a compacted predecessor's segment: a fresh run's journal
            // must never coexist with a stale segment of the old one
            segment::SEGMENT_FILE.to_string(),
            format!("{}.tmp", segment::SEGMENT_FILE),
        ] {
            let path = dir.join(&stale);
            if path.exists() {
                std::fs::remove_file(&path).map_err(|e| format!("{}: {e}", path.display()))?;
            }
        }
        let path = dir.join(JOURNAL_FILE);
        let journal = std::fs::File::create(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(RunStore {
            dir: dir.to_path_buf(),
            journal: BufWriter::new(journal),
            line: String::new(),
            journal_bytes: 0,
        })
    }

    /// Reopen a store for resumption: load the checkpoint and parse the
    /// journal prefix the checkpoint is consistent with. **Nothing on
    /// disk is modified yet** — the journal tail past the checkpoint is
    /// only discarded by [`RunStore::commit_truncation`], which the
    /// resume path calls after every validation step has passed, so a
    /// *failed* resume leaves the full journal (and the history
    /// `replay` renders from it) intact for diagnosis.
    pub fn open_for_resume(
        dir: &Path,
    ) -> Result<(RunStore, Checkpoint, Vec<JournalRecord>), String> {
        let cp = Checkpoint::load(dir)?;
        let path = dir.join(JOURNAL_FILE);
        // a compacted store holds `journal.seg` instead of the JSONL:
        // rehydrate it (segments preserve exact line bytes, so the
        // checkpoint's journal_bytes marker stays valid) and drop the
        // segment — resumption appends, which would stale it
        let seg_path = dir.join(segment::SEGMENT_FILE);
        if !path.exists() && seg_path.exists() {
            let text = segment::rehydrate_jsonl(&seg_path)?;
            std::fs::write(&path, &text).map_err(|e| format!("{}: {e}", path.display()))?;
            std::fs::remove_file(&seg_path)
                .map_err(|e| format!("{}: {e}", seg_path.display()))?;
        }
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        if (text.len() as u64) < cp.journal_bytes {
            return Err(format!(
                "journal is {} bytes but the checkpoint covers {} — store corrupted",
                text.len(),
                cp.journal_bytes
            ));
        }
        // .get: a corrupt byte count landing mid-UTF-8 must error, not
        // panic the resume path
        let prefix = text
            .get(..cp.journal_bytes as usize)
            .ok_or("checkpoint journal length splits a UTF-8 scalar — store corrupted")?;
        let (records, torn) = journal::parse_journal(prefix)?;
        if torn {
            return Err("journal torn inside the checkpointed prefix — store corrupted".into());
        }
        let journal = std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Ok((
            RunStore {
                dir: dir.to_path_buf(),
                journal: BufWriter::new(journal),
                line: String::new(),
                journal_bytes: cp.journal_bytes,
            },
            cp,
            records,
        ))
    }

    /// Discard the journal tail past the checkpointed prefix and
    /// position the append cursor at its end. Called once, after a
    /// resume has fully validated and restored — appends before this
    /// would interleave with the stale tail.
    pub fn commit_truncation(&mut self) -> Result<(), String> {
        use std::io::Seek;
        let path = self.dir.join(JOURNAL_FILE);
        // nothing has been appended yet (resume truncates before any
        // append), but drain the writer defensively before touching
        // the file cursor underneath it
        self.journal
            .flush()
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let file = self.journal.get_mut();
        file.set_len(self.journal_bytes)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        file.seek(std::io::SeekFrom::Start(self.journal_bytes))
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(())
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Journal length in bytes — the consistency marker checkpoints
    /// record.
    pub fn journal_bytes(&self) -> u64 {
        self.journal_bytes
    }

    /// Append one record to the journal: streamed into the reusable
    /// line buffer ([`JournalRecord::write_json`] — no intermediate
    /// `Json` tree or `String`), written through the persistent
    /// writer, and flushed to the OS before returning (one syscall per
    /// record, the pre-streaming flush cadence — see the struct docs
    /// for why the crash-record property needs it). Fail-stop on I/O
    /// errors (see module docs).
    pub fn append(&mut self, record: &JournalRecord) {
        self.line.clear();
        record.write_json(&mut self.line);
        self.line.push('\n');
        self.journal
            .write_all(self.line.as_bytes())
            .expect("run store: journal write failed (fail-stop)"); // detlint: allow(DL004)
        self.journal_bytes += self.line.len() as u64;
        self.flush();
    }

    /// Drain buffered journal bytes to the OS (no fsync). Every append
    /// ends with this; exposed for symmetry and for readers that
    /// inspect the journal file while the store is open.
    pub fn flush(&mut self) {
        self.journal
            .flush()
            .expect("run store: journal flush failed (fail-stop)"); // detlint: allow(DL004)
    }

    /// Atomically persist a checkpoint stamped with the current journal
    /// length. The journal is flushed and fsynced first: a checkpoint
    /// must never name bytes the journal hasn't durably reached, or a
    /// power loss between the two would make the store unresumable.
    /// Fail-stop on I/O errors.
    pub fn write_checkpoint(&mut self, mut cp: Checkpoint) {
        self.flush();
        self.journal
            .get_ref()
            .sync_all()
            .expect("run store: journal fsync failed (fail-stop)"); // detlint: allow(DL004)
        cp.journal_bytes = self.journal_bytes;
        cp.write_atomic(&self.dir)
            .expect("run store: checkpoint write failed (fail-stop)"); // detlint: allow(DL004)
    }
}

/// Compact a run store's `journal.jsonl` into its indexed binary
/// segment form (`journal.seg`, [`segment`]): O(index) cold loads for
/// fingerprint-addressed readers, exact-byte rehydration for `resume`.
/// The JSONL original is removed only after the written segment
/// verifies by read-back against the original bytes — the checkpoint's
/// `journal_bytes` marker must survive a compact → resume round trip.
/// Returns `false` when the store is already segment-only.
pub fn compact_run_store(dir: &Path) -> Result<bool, String> {
    let jsonl = dir.join(JOURNAL_FILE);
    let seg = dir.join(segment::SEGMENT_FILE);
    if !jsonl.exists() {
        return if seg.exists() {
            Ok(false)
        } else {
            Err(format!("{}: no journal to compact", dir.display()))
        };
    }
    let text =
        std::fs::read_to_string(&jsonl).map_err(|e| format!("{}: {e}", jsonl.display()))?;
    // compaction is for settled stores: a torn final line means a
    // crashed run that `resume` should repair first
    let (records, torn) = journal::parse_journal(&text)?;
    if torn {
        return Err(format!(
            "{}: journal has a torn final line — resume the run before compacting",
            jsonl.display()
        ));
    }
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    if lines.len() != records.len() {
        return Err(format!(
            "{}: {} journal lines parsed to {} records",
            jsonl.display(),
            lines.len(),
            records.len()
        ));
    }
    let indexed: Vec<(u64, &str)> = lines
        .iter()
        .zip(&records)
        .map(|(&line, rec)| {
            let fp = match rec {
                JournalRecord::Exp(e) => e.individual.genome.fingerprint_hash(),
                // plan and fault records are not genome-addressed
                JournalRecord::Plan(_) | JournalRecord::Fault(_) => 0,
            };
            (fp, line)
        })
        .collect();
    segment::write_segment(&seg, &indexed)?;
    let rehydrated = segment::rehydrate_jsonl(&seg)?;
    if rehydrated != text {
        let _ = std::fs::remove_file(&seg);
        return Err(format!(
            "{}: segment read-back does not match the journal bytes",
            seg.display()
        ));
    }
    std::fs::remove_file(&jsonl).map_err(|e| format!("{}: {e}", jsonl.display()))?;
    Ok(true)
}

/// Record a campaign's workload list (in request order) so `resume`
/// and `replay` can reconstruct the whole campaign from its directory.
pub fn write_campaign_manifest(dir: &Path, workloads: &[String]) -> Result<(), String> {
    use crate::util::json::Json;
    std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let doc = Json::obj(vec![(
        "workloads",
        Json::Arr(workloads.iter().map(|w| Json::Str(w.clone())).collect()),
    )]);
    let path = dir.join(CAMPAIGN_MANIFEST);
    std::fs::write(&path, doc.to_string()).map_err(|e| format!("{}: {e}", path.display()))
}

/// Read a campaign manifest, if `dir` holds one (`None` means `dir` is
/// a single-run store).
pub fn read_campaign_manifest(dir: &Path) -> Result<Option<Vec<String>>, String> {
    let path = dir.join(CAMPAIGN_MANIFEST);
    if !path.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&path).map_err(|e| format!("{}: {e}", path.display()))?;
    let doc = crate::util::json::parse(&text).map_err(|e| e.to_string())?;
    let workloads = doc
        .get("workloads")
        .and_then(|x| x.as_arr())
        .ok_or("campaign manifest: missing workloads")?
        .iter()
        .map(|w| {
            w.as_str()
                .map(String::from)
                .ok_or_else(|| "campaign manifest: non-string workload".to_string())
        })
        .collect::<Result<Vec<_>, _>>()?;
    Ok(Some(workloads))
}

/// The per-workload store directory inside a campaign store.
pub fn campaign_member_dir(dir: &str, workload: &str) -> String {
    format!("{}/{}", dir.trim_end_matches('/'), workload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::test_support::scratch_dir;

    #[test]
    fn campaign_manifest_roundtrip() {
        let dir = scratch_dir("manifest");
        assert_eq!(read_campaign_manifest(&dir).unwrap(), None);
        let workloads = vec!["fp8-gemm".to_string(), "row-softmax".to_string()];
        write_campaign_manifest(&dir, &workloads).unwrap();
        assert_eq!(read_campaign_manifest(&dir).unwrap(), Some(workloads));
        assert_eq!(
            campaign_member_dir("runs/camp/", "fp8-gemm"),
            "runs/camp/fp8-gemm"
        );
    }

    #[test]
    fn journal_append_tracks_bytes_and_roundtrips() {
        use crate::genome::seeds;
        use crate::population::{EvalOutcome, Individual};
        let dir = scratch_dir("journal");
        let mut store = RunStore::create(&dir).unwrap();
        assert_eq!(store.journal_bytes(), 0);
        let record = JournalRecord::Exp(ExperimentRecord {
            individual: Individual {
                id: "00001".into(),
                parents: vec![],
                genome: seeds::mfma_seed(),
                experiment: "seed kernel: mfma-seed".into(),
                report: "provided seed".into(),
                outcome: EvalOutcome::Timings(vec![100.0; 6]),
            },
            submitted_at: 1,
            submission_index: Some(0),
            cached: false,
            lane: Some(0),
            completed_at_s: Some(90.0),
            plan: None,
            screened: false,
            profile: None,
            federated: false,
            lint: Vec::new(),
        });
        store.append(&record);
        // append flushes to the OS before returning — the line is
        // immediately visible to readers of the file
        let on_disk = std::fs::read_to_string(dir.join(JOURNAL_FILE)).unwrap();
        assert_eq!(on_disk.len() as u64, store.journal_bytes());
        let (records, torn) = journal::parse_journal(&on_disk).unwrap();
        assert!(!torn);
        assert_eq!(records.len(), 1);
        // torn tails are detected and everything before them survives
        let torn_text = format!("{on_disk}{{\"t\":\"exp\",\"ind\":");
        let (records, torn) = journal::parse_journal(&torn_text).unwrap();
        assert!(torn);
        assert_eq!(records.len(), 1);
    }

    #[test]
    fn compact_run_store_preserves_exact_journal_bytes() {
        use crate::genome::seeds;
        use crate::population::{EvalOutcome, Individual};
        let dir = scratch_dir("compact-store");
        let mut store = RunStore::create(&dir).unwrap();
        for i in 0..3u64 {
            store.append(&JournalRecord::Exp(ExperimentRecord {
                individual: Individual {
                    id: format!("{:05}", i + 1),
                    parents: vec![],
                    genome: seeds::mfma_seed(),
                    experiment: format!("exp {i}"),
                    report: String::new(),
                    outcome: EvalOutcome::Timings(vec![100.0 + i as f64; 6]),
                },
                submitted_at: i + 1,
                submission_index: Some(i),
                cached: false,
                lane: Some(0),
                completed_at_s: Some(90.0 * (i + 1) as f64),
                plan: None,
                screened: false,
                profile: None,
                federated: false,
                lint: Vec::new(),
            }));
        }
        drop(store);
        let original = std::fs::read_to_string(dir.join(JOURNAL_FILE)).unwrap();
        assert!(compact_run_store(&dir).unwrap());
        assert!(!dir.join(JOURNAL_FILE).exists());
        let seg = dir.join(segment::SEGMENT_FILE);
        assert!(seg.exists());
        // the segment preserves exact bytes (resume's journal_bytes
        // marker depends on it) and indexes every record's fingerprint
        assert_eq!(segment::rehydrate_jsonl(&seg).unwrap(), original);
        let idx = segment::open_index(&seg).unwrap();
        assert_eq!(idx.entries.len(), 3);
        let fp = seeds::mfma_seed().fingerprint_hash();
        assert!(idx.entries.iter().all(|&(f, _)| f == fp));
        // idempotent: an already-compacted store is a no-op, an empty
        // dir is an error
        assert!(!compact_run_store(&dir).unwrap());
        assert!(compact_run_store(&scratch_dir("compact-empty")).is_err());
    }
}
