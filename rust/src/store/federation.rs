//! The federated archive: cross-run, content-addressed result reuse
//! (DESIGN.md §12).
//!
//! A run's archive is its central asset, but per-run stores forget
//! everything between campaigns. This module persists evaluation
//! results across runs, keyed on the triple
//! `(workload, config digest, genome fingerprint)`:
//!
//! * **workload** — fingerprints are only meaningful within one cost
//!   model, so results never cross workload boundaries in the cache;
//! * **config digest** ([`config_digest`]) — an FNV-1a hash of every
//!   knob that can change what an evaluation *returns*: measurement
//!   reps, noise sigma, eval-cache mode, the full `[screen]` and
//!   `[profile]` state, and the workload's cost-model version. The
//!   seed is deliberately excluded (cross-seed reuse is the point);
//!   anything that only changes *scheduling* (parallelism, budget) is
//!   too. Flip a digested knob and every prior entry misses — stale
//!   hits are unrepresentable rather than filtered;
//! * **fingerprint** — the PR 5 u64 genome content hash.
//!
//! Storage is one JSONL file per completed run
//! (`run-<workload>-<seed>-<digest>.jsonl`, written atomically at
//! successful completion only — a crashed run contributes nothing), or
//! the compacted segment form ([`super::segment`]). Readers load every
//! file in sorted filename order, so a snapshot's contents — and every
//! trajectory derived from them — are a pure function of the directory
//! listing, never of scan timing.
//!
//! Warm-start mining ([`FederationSnapshot::mine_elites`]) looks
//! *across* workloads: an elite bf16-gemm genome is a candidate seed
//! for fp8-gemm if it passes the target's `admits` gate. Ordering is
//! fully deterministic: dedupe by fingerprint keeping the best
//! geomean, rank by (geomean asc, fingerprint asc), take k.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::RunConfig;
use crate::genome::KernelGenome;
use crate::metrics::geomean;
use crate::population::EvalOutcome;
use crate::util::json::{self, parse_u64_hex, u64_hex, Json};
use crate::workload::Workload;

/// Federation counters surfaced in `RunOutcome` and reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FederationStats {
    /// Submissions served from the federated store (no backend work).
    pub hits: u64,
    /// Cross-run elites injected as extra seed candidates.
    pub warm_start_injected: u64,
}

/// One persisted evaluation result.
#[derive(Debug, Clone, PartialEq)]
pub struct FedEntry {
    pub workload: String,
    /// [`config_digest`] of the run that produced the result.
    pub digest: u64,
    /// Genome content hash (the cache key within a digest).
    pub fingerprint: u64,
    pub genome: KernelGenome,
    pub outcome: EvalOutcome,
}

fn outcome_to_json(o: &EvalOutcome) -> Json {
    match o {
        EvalOutcome::Timings(t) => Json::obj(vec![
            ("kind", Json::Str("timings".into())),
            ("us", Json::Arr(t.iter().map(|&x| Json::Num(x)).collect())),
        ]),
        EvalOutcome::CompileFailure(msg) => Json::obj(vec![
            ("kind", Json::Str("compile_failure".into())),
            ("msg", Json::Str(msg.clone())),
        ]),
        EvalOutcome::IncorrectResult(msg) => Json::obj(vec![
            ("kind", Json::Str("incorrect_result".into())),
            ("msg", Json::Str(msg.clone())),
        ]),
    }
}

fn outcome_from_json(o: &Json) -> Result<EvalOutcome, String> {
    match o.get("kind").and_then(|x| x.as_str()) {
        Some("timings") => Ok(EvalOutcome::Timings(
            o.get("us")
                .and_then(|x| x.as_arr())
                .ok_or("federation: outcome missing us")?
                .iter()
                .map(|x| x.as_f64().ok_or("federation: bad timing"))
                .collect::<Result<Vec<_>, _>>()?,
        )),
        Some("compile_failure") => Ok(EvalOutcome::CompileFailure(
            o.get("msg").and_then(|x| x.as_str()).unwrap_or("").into(),
        )),
        Some("incorrect_result") => Ok(EvalOutcome::IncorrectResult(
            o.get("msg").and_then(|x| x.as_str()).unwrap_or("").into(),
        )),
        _ => Err("federation: bad outcome kind".into()),
    }
}

impl FedEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("digest", u64_hex(self.digest)),
            ("fp", u64_hex(self.fingerprint)),
            ("genome", self.genome.to_json()),
            ("outcome", outcome_to_json(&self.outcome)),
            ("workload", Json::Str(self.workload.clone())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FedEntry, String> {
        Ok(FedEntry {
            workload: v
                .get("workload")
                .and_then(|x| x.as_str())
                .ok_or("federation: entry missing workload")?
                .to_string(),
            digest: parse_u64_hex(v.get("digest").ok_or("federation: entry missing digest")?)?,
            fingerprint: parse_u64_hex(v.get("fp").ok_or("federation: entry missing fp")?)?,
            genome: KernelGenome::from_json(
                v.get("genome").ok_or("federation: entry missing genome")?,
            )?,
            outcome: outcome_from_json(
                v.get("outcome").ok_or("federation: entry missing outcome")?,
            )?,
        })
    }
}

/// FNV-1a 64-bit (the repo's stable string hash for digests).
fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Digest of every config knob that can change an evaluation result
/// (module docs list the inclusion rule). Versioned (`v1;`) so the
/// canonical string itself can evolve without serving stale entries.
pub fn config_digest(cfg: &RunConfig, cost_model_version: u32) -> u64 {
    let mut canonical = format!(
        "v1;workload={};cost_model={};reps={};noise={};cache={};screen={}/{}/{};profile={}",
        cfg.workload,
        cost_model_version,
        cfg.reps_per_config,
        cfg.noise_sigma,
        cfg.eval_cache,
        cfg.screen_enabled,
        cfg.screen_rung,
        cfg.screen_keep,
        cfg.profile_guided,
    );
    // the fault model changes what a dispatch measures (an unconfirmed
    // corrupted timing publishes as an ordinary result), so chaos runs
    // must never share entries with clean runs — or with chaos runs at
    // different rates. Appended only when enabled: faults-off digests
    // stay byte-identical to pre-§14 archives.
    if cfg.faults.enabled {
        use std::fmt::Write;
        let f = &cfg.faults;
        let _ = write!(
            canonical,
            ";faults={}/{}/{}/{}/{}/{}/{}/{}/{}",
            f.transient,
            f.straggler,
            f.straggler_factor,
            f.straggler_timeout,
            f.corrupt,
            f.corrupt_factor,
            f.lane_death,
            f.confirm_outliers,
            f.outlier_threshold,
        );
    }
    fnv1a64(&canonical)
}

/// An immutable, fully loaded view of a federation directory. Loaded
/// once per run (or once per campaign, shared across members) so every
/// consumer sees the same store contents regardless of thread timing.
#[derive(Debug, Default)]
pub struct FederationSnapshot {
    entries: Vec<FedEntry>,
}

impl FederationSnapshot {
    /// Load every `*.jsonl` and `*.seg` file under `dir`, in sorted
    /// filename order. A missing directory is an empty store (a fresh
    /// federation dir needs no setup step); a corrupt file is an error
    /// — silently skipping it would make trajectories depend on *how*
    /// the store is broken.
    pub fn load(dir: &Path) -> Result<FederationSnapshot, String> {
        if !dir.exists() {
            return Ok(FederationSnapshot::default());
        }
        let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| format!("{}: {e}", dir.display()))?
            .map(|entry| entry.map(|e| e.path()))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| format!("{}: {e}", dir.display()))?
            .into_iter()
            .filter(|p| {
                matches!(
                    p.extension().and_then(|e| e.to_str()),
                    Some("jsonl") | Some("seg")
                )
            })
            .collect();
        files.sort();
        let mut entries = Vec::new();
        for path in files {
            let lines: Vec<String> =
                if path.extension().and_then(|e| e.to_str()) == Some("seg") {
                    super::segment::read_lines(&path)?
                } else {
                    std::fs::read_to_string(&path)
                        .map_err(|e| format!("{}: {e}", path.display()))?
                        .lines()
                        .map(String::from)
                        .collect()
                };
            for (i, line) in lines.iter().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let v = json::parse(line)
                    .map_err(|e| format!("{} line {}: {e}", path.display(), i + 1))?;
                entries.push(
                    FedEntry::from_json(&v)
                        .map_err(|e| format!("{} line {}: {e}", path.display(), i + 1))?,
                );
            }
        }
        Ok(FederationSnapshot { entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[FedEntry] {
        &self.entries
    }

    /// Every stored result under an exact `(workload, digest)` key,
    /// as fingerprint → outcome. The first entry per fingerprint (in
    /// the snapshot's sorted-file order) wins, so duplicate keys from
    /// different runs resolve deterministically.
    pub fn results_for(&self, workload: &str, digest: u64) -> HashMap<u64, EvalOutcome> {
        let mut map = HashMap::new();
        for e in &self.entries {
            if e.workload == workload && e.digest == digest {
                map.entry(e.fingerprint).or_insert_with(|| e.outcome.clone());
            }
        }
        map
    }

    /// Mine the snapshot — **across workloads and digests** — for the
    /// top-`k` elite genomes admissible to `workload`, each as
    /// `(fingerprint, genome, source geomean)`. Deterministic:
    /// successful entries are deduped by fingerprint keeping the best
    /// (lowest) source geomean, filtered through `validate` +
    /// `admits`, and ranked by (geomean asc, fingerprint asc). The
    /// source geomean is a *ranking* signal only — injected elites are
    /// re-evaluated under the target workload like any other seed.
    pub fn mine_elites(
        &self,
        workload: &dyn Workload,
        k: usize,
    ) -> Vec<(u64, KernelGenome, f64)> {
        if k == 0 {
            return Vec::new();
        }
        let mut best: HashMap<u64, (f64, &FedEntry)> = HashMap::new();
        for e in &self.entries {
            let Some(ts) = e.outcome.timings() else { continue };
            if ts.is_empty() {
                continue;
            }
            let g = geomean(ts);
            if !g.is_finite() {
                continue;
            }
            let improves = match best.get(&e.fingerprint) {
                Some(&(prev, _)) => g < prev,
                None => true,
            };
            if improves {
                best.insert(e.fingerprint, (g, e));
            }
        }
        let mut ranked: Vec<(u64, &FedEntry, f64)> = best
            .into_iter() // detlint: allow(DL003) — fully sorted below
            .filter(|&(_, (_, e))| {
                e.genome.validate().is_ok() && workload.admits(&e.genome).is_ok()
            })
            .map(|(fp, (g, e))| (fp, e, g))
            .collect();
        ranked.sort_by(|a, b| a.2.total_cmp(&b.2).then(a.0.cmp(&b.0)));
        ranked
            .into_iter()
            .take(k)
            .map(|(fp, e, g)| (fp, e.genome.clone(), g))
            .collect()
    }
}

/// The store file a run writes at successful completion.
pub fn run_file_name(workload: &str, seed: u64, digest: u64) -> String {
    format!("run-{workload}-{seed}-{digest:016x}.jsonl")
}

/// Persist one run's results to `dir` atomically (temp + rename).
/// Idempotent: re-running the same (workload, seed, digest) overwrites
/// its own file with identical contents. `read_only` stores are never
/// written — callers gate on the config knob before calling this.
pub fn write_run_results(
    dir: &Path,
    workload: &str,
    seed: u64,
    digest: u64,
    entries: &[FedEntry],
) -> Result<PathBuf, String> {
    std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let path = dir.join(run_file_name(workload, seed, digest));
    let mut text = String::new();
    for e in entries {
        text.push_str(&e.to_json().to_string());
        text.push('\n');
    }
    let tmp = path.with_extension("jsonl.tmp");
    std::fs::write(&tmp, &text).map_err(|e| format!("{}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &path).map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(path)
}

/// Compact every `*.jsonl` federation file under `dir` into its
/// segment form (same stem, `.seg` extension, entry fingerprints in
/// the index), removing the JSONL original after a verified write.
/// Returns the number of files compacted.
pub fn compact_dir(dir: &Path) -> Result<usize, String> {
    let snapshot_before = FederationSnapshot::load(dir)?;
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("{}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("jsonl"))
        .collect();
    files.sort();
    let mut compacted = 0;
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let mut records: Vec<(u64, &str)> = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let v = json::parse(line).map_err(|e| format!("{}: {e}", path.display()))?;
            let entry = FedEntry::from_json(&v).map_err(|e| format!("{}: {e}", path.display()))?;
            records.push((entry.fingerprint, line));
        }
        let seg = path.with_extension("seg");
        super::segment::write_segment(&seg, &records)?;
        // verify the segment serves the exact lines before dropping
        // the JSONL original
        let back = super::segment::read_lines(&seg)?;
        let expect: Vec<&str> = records.iter().map(|&(_, l)| l).collect();
        if back != expect {
            return Err(format!("{}: segment verification failed", seg.display()));
        }
        std::fs::remove_file(path).map_err(|e| format!("{}: {e}", path.display()))?;
        compacted += 1;
    }
    // the compacted store must serve the identical snapshot
    let snapshot_after = FederationSnapshot::load(dir)?;
    if snapshot_after.len() != snapshot_before.len() {
        return Err(format!(
            "{}: compaction changed entry count ({} -> {})",
            dir.display(),
            snapshot_before.len(),
            snapshot_after.len()
        ));
    }
    Ok(compacted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::test_support::scratch_dir;
    use crate::workload;

    fn entry(workload: &str, digest: u64, genome: KernelGenome, us: f64) -> FedEntry {
        FedEntry {
            workload: workload.into(),
            digest,
            fingerprint: genome.fingerprint_hash(),
            genome,
            outcome: EvalOutcome::Timings(vec![us; 6]),
        }
    }

    #[test]
    fn entry_roundtrips_through_json() {
        let e = entry("fp8-gemm", 0xdead_beef_0000_0001, seeds::mfma_seed(), 123.5);
        let back = FedEntry::from_json(&e.to_json()).unwrap();
        assert_eq!(back.workload, e.workload);
        assert_eq!(back.digest, e.digest);
        assert_eq!(back.fingerprint, e.fingerprint);
        assert_eq!(back.genome, e.genome);
        assert_eq!(back.outcome, e.outcome);
        let f = FedEntry {
            outcome: EvalOutcome::CompileFailure("LDS overflow".into()),
            ..e
        };
        let back = FedEntry::from_json(&f.to_json()).unwrap();
        assert_eq!(back.outcome, f.outcome);
    }

    #[test]
    fn digest_separates_eval_relevant_knobs_and_ignores_schedule_knobs() {
        let base = RunConfig::default();
        let d = config_digest(&base, 1);
        assert_eq!(d, config_digest(&base.clone(), 1), "digest is stable");
        // seed and scheduling knobs are excluded: cross-seed reuse
        let mut c = base.clone();
        c.seed = 99;
        c.eval_parallelism = 7;
        c.max_submissions = 3;
        c.pipeline = true;
        // lint gates which genomes reach eval, never a genome's result
        c.lint_gate = true;
        c.lint_guided = true;
        assert_eq!(config_digest(&c, 1), d);
        // every eval-relevant knob separates
        let mut c = base.clone();
        c.noise_sigma = 0.5;
        assert_ne!(config_digest(&c, 1), d);
        let mut c = base.clone();
        c.reps_per_config += 1;
        assert_ne!(config_digest(&c, 1), d);
        let mut c = base.clone();
        c.screen_enabled = true;
        assert_ne!(config_digest(&c, 1), d);
        let mut c = base.clone();
        c.screen_keep = 0.25;
        assert_ne!(config_digest(&c, 1), d);
        let mut c = base.clone();
        c.profile_guided = true;
        assert_ne!(config_digest(&c, 1), d);
        let mut c = base.clone();
        c.workload = "bf16-gemm".into();
        assert_ne!(config_digest(&c, 1), d);
        // a disabled [faults] section is inert whatever its rates; an
        // enabled one separates (a chaos run's corrupted timings must
        // never serve a clean run), and so do its measurement-relevant
        // rates — while pure recovery-scheduling knobs still share
        let mut c = base.clone();
        c.faults.transient = 0.9;
        c.faults.corrupt = 0.9;
        assert_eq!(config_digest(&c, 1), d, "disabled faults must be inert");
        let mut c = base.clone();
        c.faults.enabled = true;
        let chaos = config_digest(&c, 1);
        assert_ne!(chaos, d);
        let mut c2 = c.clone();
        c2.faults.corrupt = 0.5;
        assert_ne!(config_digest(&c2, 1), chaos);
        let mut c2 = c.clone();
        c2.faults.recovery = false;
        c2.faults.max_retries = 9;
        c2.faults.quarantine_after = 1;
        assert_eq!(config_digest(&c2, 1), chaos, "recovery knobs schedule, not measure");
        // a bumped cost-model version invalidates everything
        assert_ne!(config_digest(&base, 2), d);
    }

    #[test]
    fn snapshot_load_write_and_results_for() {
        let dir = scratch_dir("fed-snapshot");
        assert!(FederationSnapshot::load(&dir.join("missing")).unwrap().is_empty());
        let e1 = entry("fp8-gemm", 7, seeds::mfma_seed(), 100.0);
        let e2 = entry("fp8-gemm", 7, seeds::naive_hip(), 900.0);
        let e3 = entry("fp8-gemm", 8, seeds::human_oracle(), 50.0); // other digest
        write_run_results(&dir, "fp8-gemm", 1, 7, &[e1.clone(), e2.clone()]).unwrap();
        write_run_results(&dir, "fp8-gemm", 2, 8, &[e3.clone()]).unwrap();
        let snap = FederationSnapshot::load(&dir).unwrap();
        assert_eq!(snap.len(), 3);
        let hits = snap.results_for("fp8-gemm", 7);
        assert_eq!(hits.len(), 2);
        assert_eq!(hits.get(&e1.fingerprint), Some(&e1.outcome));
        assert!(!hits.contains_key(&e3.fingerprint), "digest 8 must not leak");
        assert!(snap.results_for("bf16-gemm", 7).is_empty());
        // idempotent rewrite leaves one file per (workload, seed, digest)
        write_run_results(&dir, "fp8-gemm", 1, 7, &[e1.clone(), e2]).unwrap();
        assert_eq!(FederationSnapshot::load(&dir).unwrap().len(), 3);
    }

    #[test]
    fn mine_elites_is_deterministic_and_gated() {
        let dir = scratch_dir("fed-elites");
        let fp8 = workload::lookup("fp8-gemm").unwrap();
        let good = entry("bf16-gemm", 3, seeds::human_oracle(), 80.0);
        let better = entry("bf16-gemm", 3, seeds::mfma_seed(), 60.0);
        // duplicate fingerprint with a worse geomean: deduped away
        let dup = entry("fp8-gemm", 4, seeds::mfma_seed(), 70.0);
        let failed = FedEntry {
            outcome: EvalOutcome::CompileFailure("nope".into()),
            ..entry("fp8-gemm", 4, seeds::naive_hip(), 0.0)
        };
        write_run_results(&dir, "bf16-gemm", 1, 3, &[good.clone(), better.clone()]).unwrap();
        write_run_results(&dir, "fp8-gemm", 1, 4, &[dup, failed]).unwrap();
        let snap = FederationSnapshot::load(&dir).unwrap();
        let elites = snap.mine_elites(fp8.as_ref(), 10);
        let fps: Vec<u64> = elites.iter().map(|e| e.0).collect();
        assert_eq!(
            fps,
            vec![better.fingerprint, good.fingerprint],
            "geomean-ascending, deduped, failures excluded"
        );
        assert_eq!(elites[0].2, 60.0, "dedup keeps the best source geomean");
        // same store, same answer; k truncates deterministically
        let again = snap.mine_elites(fp8.as_ref(), 1);
        assert_eq!(again.len(), 1);
        assert_eq!(again[0].0, better.fingerprint);
        assert!(snap.mine_elites(fp8.as_ref(), 0).is_empty());
    }

    #[test]
    fn compact_dir_preserves_the_snapshot() {
        let dir = scratch_dir("fed-compact");
        let e1 = entry("fp8-gemm", 7, seeds::mfma_seed(), 100.0);
        let e2 = entry("row-softmax", 9, seeds::naive_hip(), 200.0);
        write_run_results(&dir, "fp8-gemm", 1, 7, &[e1.clone()]).unwrap();
        write_run_results(&dir, "row-softmax", 2, 9, &[e2.clone()]).unwrap();
        let before = FederationSnapshot::load(&dir).unwrap();
        assert_eq!(compact_dir(&dir).unwrap(), 2);
        // no JSONL left; the segment store serves the identical view
        let leftover: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().and_then(|x| x.to_str()) == Some("jsonl"))
            .collect();
        assert!(leftover.is_empty());
        let after = FederationSnapshot::load(&dir).unwrap();
        assert_eq!(after.len(), before.len());
        assert_eq!(
            after.results_for("fp8-gemm", 7),
            before.results_for("fp8-gemm", 7)
        );
        // compacting an already compacted dir is a no-op
        assert_eq!(compact_dir(&dir).unwrap(), 0);
    }
}
