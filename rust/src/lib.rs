//! # GPU Kernel Scientist
//!
//! A reproduction of *"GPU Kernel Scientist: An LLM-Driven Framework for
//! Iterative Kernel Optimization"* (Andrews & Witteveen, ES-FoMo III @
//! ICML 2025) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper's contribution is a closed-loop, LLM-driven evolutionary
//! system that optimizes a single complex GPU kernel (FP8 block-scaled
//! GEMM, AMD Developer Challenge 2025, MI300 target) using **only
//! end-to-end black-box timings** as feedback. The loop (paper Fig. 1):
//!
//! ```text
//!          ┌──────────────────────────────────────────────┐
//!          ▼                                              │
//!   [population of kernels + timings]                     │
//!          │                                              │
//!   (1) Evolutionary Selector  → Base + Reference         │
//!          │                                              │
//!   (2) Experiment Designer    → 10 avenues → 5 plans     │
//!          │                      → pick 3 (innov/max/min)│
//!   (3) Kernel Writer (×3)     → new kernels + reports    │
//!          │                                              │
//!   (4) Batched evaluation     → correctness + 6 timings ─┘
//!       (multi-lane executor)
//! ```
//!
//! This crate is Layer 3: the coordinator that owns the loop, the
//! population, the evaluation platform, and every substrate the paper
//! depends on (an MI300-class timing simulator standing in for the
//! competition's hardware, and surrogate agents standing in for the
//! Gemini models — see `DESIGN.md` §2 for the substitution argument).
//! Layers 2/1 are the JAX model + Pallas kernel compiled ahead of time
//! to HLO artifacts which [`runtime`] loads and times over PJRT — the
//! *real* evaluation backend proving the stack composes.
//!
//! Step (4) runs each iteration's children as one batch through the
//! platform's multi-lane executor ([`eval::executor`], `DESIGN.md`
//! §3): with the paper's 1-lane good-citizen default the batch is
//! bit-identical to sequential submission, while higher lane counts
//! evaluate on real worker threads with an eval-result cache making
//! duplicate genomes free. See `README.md` for the crate layout, the
//! tier-1 verify command, and how to run every bench and example.
//!
//! Runs can be made **durable**: with a `[store] dir` configured,
//! every experiment journals to an append-only ledger and the run
//! checkpoints its RNG streams, platform clocks, and eval cache —
//! `resume` continues a crashed campaign bit-identically and `replay`
//! re-renders it without evaluating ([`store`], `DESIGN.md` §9).
//!
//! The loop is **workload-generic**: every scenario-specific piece —
//! benchmark suites, seed genomes, verifier tolerance, the analytic
//! cost model — lives behind the [`workload::Workload`] trait, and
//! [`workload::registry`] ships three families (the paper's fp8 GEMM,
//! a bf16 inference GEMM, and a bandwidth-bound fused row-softmax).
//! [`scientist::campaign`] runs several workloads concurrently, each
//! over its own multi-lane platform and eval cache.
//!
//! ## Quick start
//!
//! ```no_run
//! use gpu_kernel_scientist::prelude::*;
//!
//! let cfg = RunConfig::default();
//! let mut run = ScientistRun::new(cfg).unwrap();
//! let outcome = run.run_to_completion().unwrap();
//! println!("best geomean: {:.1} us", outcome.best_geomean_us);
//! ```

pub mod agents;
pub mod analysis;
pub mod baselines;
pub mod config;
pub mod eval;
pub mod genome;
pub mod gpu;
pub mod metrics;
pub mod population;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod test_support;
pub mod util;
pub mod scientist;
pub mod sim;
pub mod store;
pub mod workload;

/// Plural alias for the workload registry module (`workloads::registry()`
/// reads naturally at call sites).
pub use crate::workload as workloads;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::agents::{AgentSuite, SurrogateLlm};
    pub use crate::analysis::{lint, Diagnostic, Severity};
    pub use crate::config::RunConfig;
    pub use crate::eval::{EvalBackend, EvalPlatform, FaultConfig, FaultyBackend};
    pub use crate::agents::{ExperimentRule, KnowledgeProfile, SelectionPolicy};
    pub use crate::genome::{seeds, KernelGenome};
    pub use crate::metrics::geomean;
    pub use crate::population::{Individual, Population};
    pub use crate::scientist::campaign::{run_campaign, CampaignConfig, CampaignOutcome};
    pub use crate::scientist::{PipelineStats, RunOutcome, ScientistRun};
    pub use crate::sim::SimBackend;
    pub use crate::workload::{registry, BenchmarkSuite, GemmConfig, Workload};
}
