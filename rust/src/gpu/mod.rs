//! MI300-class (CDNA3-like) GPU architecture model.
//!
//! The paper's evaluation platform runs on real MI300 hardware that we
//! do not have; this module is the mechanistic model underneath the
//! timing simulator (`sim/`). It is **not** a cycle-accurate CDNA3
//! simulator — it is the same class of model a kernel engineer uses on
//! paper: peak pipes, bandwidths, occupancy limits, bank-conflict
//! multipliers — with constants close to public MI300X figures.
//! `sim::calibration` pins the end-to-end outputs to Table-1
//! magnitudes; the *relative* responses to genome changes are what the
//! scientist loop observes, and those come from the structure here.

pub mod lds;
pub mod memory;
pub mod mfma;
pub mod occupancy;

use crate::genome::KernelGenome;

/// Architecture constants (MI300X-flavoured).
#[derive(Debug, Clone)]
pub struct GpuArch {
    pub name: &'static str,
    /// Compute units.
    pub num_cus: u32,
    /// Shader clock, GHz.
    pub clock_ghz: f64,
    /// Peak matrix-pipe throughput, TFLOP/s, by operand precision.
    pub mfma_fp8_tflops: f64,
    pub mfma_fp16_tflops: f64,
    /// Peak vector-pipe throughput, TFLOP/s.
    pub vector_fp32_tflops: f64,
    /// Effective scalar-issue throughput, TFLOP/s (un-vectorized FMAs).
    pub scalar_tflops: f64,
    /// HBM bandwidth, TB/s.
    pub hbm_tbps: f64,
    /// Infinity-cache / L2 bandwidth, TB/s (serves re-reads).
    pub l2_tbps: f64,
    /// L2 / infinity cache capacity, MiB.
    pub l2_mib: f64,
    /// Aggregate LDS bandwidth, TB/s.
    pub lds_tbps: f64,
    /// LDS bytes per workgroup.
    pub lds_bytes: u32,
    /// Wave slots per CU (resident waves for latency hiding).
    pub wave_slots_per_cu: u32,
    /// VGPRs per lane.
    pub vgprs_per_lane: u32,
    /// Kernel launch overhead, microseconds.
    pub launch_overhead_us: f64,
    /// Workgroup dispatch rate, workgroups per microsecond.
    pub dispatch_rate_per_us: f64,
}

/// The default MI300X-like target.
pub const MI300: GpuArch = GpuArch {
    name: "mi300-sim",
    num_cus: 304,
    clock_ghz: 2.1,
    mfma_fp8_tflops: 2614.0,
    mfma_fp16_tflops: 1307.0,
    vector_fp32_tflops: 163.4,
    scalar_tflops: 55.0,
    hbm_tbps: 5.3,
    l2_tbps: 17.0,
    l2_mib: 256.0,
    lds_tbps: 130.0,
    lds_bytes: 64 * 1024,
    wave_slots_per_cu: 32,
    vgprs_per_lane: 512,
    launch_overhead_us: 4.0,
    dispatch_rate_per_us: 128.0,
};

impl GpuArch {
    /// Peak TFLOP/s for a genome's compute+precision path.
    pub fn peak_tflops(&self, g: &KernelGenome) -> f64 {
        use crate::genome::{ComputePath, Precision};
        match (g.compute, g.precision) {
            (ComputePath::Mfma, Precision::Fp8) => self.mfma_fp8_tflops,
            (ComputePath::Mfma, Precision::Fp16) => self.mfma_fp16_tflops,
            // MFMA+fp32 is rejected by validation; unreachable in sim.
            (ComputePath::Mfma, Precision::Fp32) => self.vector_fp32_tflops,
            (ComputePath::Vectorized, Precision::Fp32) => self.vector_fp32_tflops,
            // packed fp16/fp8 vector ops double f32 vector rate
            (ComputePath::Vectorized, _) => self.vector_fp32_tflops * 1.3,
            (ComputePath::Scalar, _) => self.scalar_tflops,
        }
    }

    /// Bytes per operand element for a precision path.
    pub fn operand_elt_bytes(g: &KernelGenome) -> u32 {
        use crate::genome::Precision;
        match g.precision {
            Precision::Fp32 => 4,
            Precision::Fp16 => 2,
            Precision::Fp8 => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;

    #[test]
    fn mi300_constants_sane() {
        assert!(MI300.mfma_fp8_tflops > MI300.mfma_fp16_tflops);
        assert!(MI300.mfma_fp16_tflops > MI300.vector_fp32_tflops);
        assert!(MI300.vector_fp32_tflops > MI300.scalar_tflops);
        assert!(MI300.l2_tbps > MI300.hbm_tbps);
        assert!(MI300.lds_tbps > MI300.l2_tbps);
    }

    #[test]
    fn peak_ranking_matches_paths() {
        let oracle = seeds::human_oracle(); // MFMA fp8
        let naive = seeds::naive_hip(); // scalar f32
        let lib = seeds::pytorch_reference(); // vectorized fp16
        let p_oracle = MI300.peak_tflops(&oracle);
        let p_lib = MI300.peak_tflops(&lib);
        let p_naive = MI300.peak_tflops(&naive);
        assert!(p_oracle > p_lib && p_lib > p_naive);
    }

    #[test]
    fn elt_bytes() {
        assert_eq!(GpuArch::operand_elt_bytes(&seeds::naive_hip()), 4);
        assert_eq!(GpuArch::operand_elt_bytes(&seeds::human_oracle()), 1);
        assert_eq!(GpuArch::operand_elt_bytes(&seeds::pytorch_reference()), 2);
    }
}
