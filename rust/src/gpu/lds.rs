//! LDS (Local Data Share) bank-conflict and pressure model.
//!
//! CDNA3 LDS has 32 banks of 4 bytes. Row-major tiles whose row pitch
//! is a multiple of the bank stride serialize column accesses — the
//! classic conflict the paper's designer repeatedly targets ("LDS Bank
//! Conflict Mitigation for A/B Data: analyze and re-pad shared
//! memory...", App. A.2). The two standard cures, row padding and
//! XOR swizzling, are genome axes.

use crate::genome::{ComputePath, KernelGenome, Swizzle};

/// Number of LDS banks (4-byte wide each).
pub const NUM_BANKS: u32 = 32;

/// Average access serialization factor (1.0 = conflict-free; N = every
/// access N-way serialized).
pub fn conflict_factor(g: &KernelGenome) -> f64 {
    if !g.lds_staging {
        return 1.0; // no LDS use at all
    }
    if g.swizzle == Swizzle::Xor {
        // XOR swizzle fully de-conflicts strided column walks.
        return 1.0;
    }
    let elt = crate::gpu::GpuArch::operand_elt_bytes(g);
    // Row pitch in bytes, including padding.
    let pitch = (g.block_k + g.lds_pad) * elt;
    // Column walk stride in banks; pitch that is a multiple of the full
    // bank span (128 B) lands every row on the same bank.
    let span = NUM_BANKS * 4;
    let rem = pitch % span;
    if rem == 0 {
        // Worst case: ways limited by wavefront quarter (16-lane phase).
        4.0
    } else if rem % 64 == 0 {
        2.0
    } else if rem % 32 == 0 {
        1.5
    } else {
        // Odd/unaligned pitch: effectively conflict-free, tiny cost for
        // the wasted padding bandwidth.
        1.0 + (g.lds_pad as f64 / g.block_k as f64) * 0.5
    }
}

/// Fraction of compute time spent waiting on LDS ports if the compute
/// pipe were never starved — the "LDS pressure" multiplier. Matrix
/// fragments amortize LDS reads across a whole wave; scalar paths
/// re-read per lane.
pub fn pressure(g: &KernelGenome) -> f64 {
    if !g.lds_staging {
        return 0.0;
    }
    let path = match g.compute {
        ComputePath::Mfma => 0.25,
        ComputePath::Vectorized => 0.5,
        ComputePath::Scalar => 1.0,
    };
    path * conflict_factor(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome};

    fn staged(block_k: u32, lds_pad: u32, swizzle: Swizzle) -> KernelGenome {
        KernelGenome {
            block_k,
            lds_pad,
            swizzle,
            lds_staging: true,
            ..seeds::mfma_seed()
        }
    }

    #[test]
    fn unpadded_pow2_pitch_conflicts() {
        // fp8, block_k=128 -> pitch 128 B = full bank span -> 4-way.
        let g = staged(128, 0, Swizzle::None);
        assert_eq!(conflict_factor(&g), 4.0);
    }

    #[test]
    fn padding_removes_conflicts() {
        let bad = staged(128, 0, Swizzle::None);
        let padded = staged(128, 4, Swizzle::None);
        assert!(conflict_factor(&padded) < conflict_factor(&bad));
        assert!(conflict_factor(&padded) < 1.1);
    }

    #[test]
    fn swizzle_removes_conflicts() {
        let g = staged(128, 0, Swizzle::Xor);
        assert_eq!(conflict_factor(&g), 1.0);
    }

    #[test]
    fn no_staging_no_pressure() {
        let g = seeds::naive_hip();
        assert_eq!(pressure(&g), 0.0);
        assert_eq!(conflict_factor(&g), 1.0);
    }

    #[test]
    fn mfma_amortizes_lds_reads() {
        let mfma = staged(64, 4, Swizzle::None);
        let scalar = KernelGenome {
            compute: ComputePath::Scalar,
            precision: crate::genome::Precision::Fp32,
            ..staged(64, 4, Swizzle::None)
        };
        assert!(pressure(&mfma) < pressure(&scalar));
    }

    #[test]
    fn fp16_half_pitch_conflicts_differ() {
        // fp16 (2B): block_k=64 -> pitch 128 B -> 4-way conflicts.
        let mut g = staged(64, 0, Swizzle::None);
        g.precision = crate::genome::Precision::Fp16;
        assert_eq!(conflict_factor(&g), 4.0);
        // block_k=32 -> pitch 64 -> rem 64 -> 2-way
        let mut g2 = staged(32, 0, Swizzle::None);
        g2.precision = crate::genome::Precision::Fp16;
        assert_eq!(conflict_factor(&g2), 2.0);
    }
}
