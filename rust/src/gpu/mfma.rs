//! Matrix-core (MFMA) pipe model: tile-shape alignment and inner-loop
//! pipelining efficiency.
//!
//! CDNA3's fp8 MFMA primitive is 32x32x16 (the shape the paper's
//! evolved kernel configures, App. A.3). Block tiles that are not
//! multiples of the primitive waste lanes; shallow k-loop unrolling
//! starves the pipe between dependent MFMAs; extreme unrolling burns
//! registers. The paper's avenue list targets exactly these knobs
//! ("Fine-tune Tile Sizes (TB_M, TB_N, TB_K)", "Register Pressure
//! Management").

use crate::genome::{ComputePath, KernelGenome};

/// MFMA primitive shape for fp8/fp16 on this architecture.
pub const MFMA_M: u32 = 32;
pub const MFMA_N: u32 = 32;
pub const MFMA_K: u32 = 16;

/// Fraction of matrix-pipe peak reachable with the genome's tile
/// shape: penalty when tiles don't wrap the primitive evenly.
pub fn tile_alignment_efficiency(g: &KernelGenome) -> f64 {
    if g.compute != ComputePath::Mfma {
        return 1.0; // vector/scalar paths have no fragment constraint
    }
    let mut eff = 1.0;
    if g.block_m % MFMA_M != 0 {
        eff *= 0.55;
    }
    if g.block_n % MFMA_N != 0 {
        eff *= 0.55;
    }
    if g.block_k % MFMA_K != 0 {
        eff *= 0.70;
    }
    // Very small tiles can't fill the fragment pipeline.
    if g.block_m * g.block_n < MFMA_M * MFMA_N * 4 {
        eff *= 0.80;
    }
    eff
}

/// Inner-loop issue efficiency from k-unrolling: dependent MFMAs stall
/// the pipe at unroll 1; unroll 4 keeps it full; unroll 8 starts to
/// thrash registers/instruction cache.
pub fn unroll_efficiency(g: &KernelGenome) -> f64 {
    match g.unroll_k {
        1 => 0.70,
        2 => 0.85,
        4 => 0.96,
        _ => 0.90, // 8
    }
}

/// Loop-order efficiency: hoisting k to the outer loop forces the
/// accumulator to make round-trips (or C to be re-read), costing both
/// pipes; the k-innermost order is the natural GEMM structure.
pub fn loop_order_efficiency(g: &KernelGenome) -> f64 {
    if g.k_innermost {
        1.0
    } else {
        0.72
    }
}

/// Accumulator-placement efficiency: read-modify-write accumulation
/// through memory pays latency every k step.
pub fn accumulator_efficiency(g: &KernelGenome) -> f64 {
    if g.acc_in_regs {
        1.0
    } else {
        0.45
    }
}

/// Compiler-scheduled vs hand-scheduled MFMA issue: without ISA-level
/// software pipelining, dependent MFMA chains and VALU/MFMA co-issue
/// hazards cap the matrix pipe well below peak. The competition's top
/// human kernels recovered this with hand-written assembly — a
/// technique that needs hardware access + ISA docs, so it sits outside
/// the scientist-reachable genome space (`isa_scheduling` has no edit
/// operator; only the human-oracle seed carries it).
pub fn issue_scheduling_efficiency(g: &KernelGenome) -> f64 {
    if g.compute != ComputePath::Mfma || g.isa_scheduling {
        1.0
    } else {
        0.22
    }
}

/// Combined compute-pipe efficiency (excluding occupancy effects,
/// which `occupancy::compute_issue_efficiency` owns).
pub fn pipe_efficiency(g: &KernelGenome) -> f64 {
    tile_alignment_efficiency(g)
        * unroll_efficiency(g)
        * loop_order_efficiency(g)
        * accumulator_efficiency(g)
        * issue_scheduling_efficiency(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome};

    #[test]
    fn oracle_tiles_fully_aligned() {
        assert_eq!(tile_alignment_efficiency(&seeds::human_oracle()), 1.0);
    }

    #[test]
    fn misaligned_tiles_penalized() {
        let g = KernelGenome {
            block_m: 16, // not a multiple of MFMA_M=32
            ..seeds::mfma_seed()
        };
        assert!(tile_alignment_efficiency(&g) < 0.6);
    }

    #[test]
    fn non_mfma_unaffected_by_alignment() {
        let g = KernelGenome {
            block_m: 16,
            ..seeds::naive_hip()
        };
        assert_eq!(tile_alignment_efficiency(&g), 1.0);
    }

    #[test]
    fn unroll_sweet_spot_at_four() {
        let mk = |u: u32| KernelGenome {
            unroll_k: u,
            ..seeds::mfma_seed()
        };
        assert!(unroll_efficiency(&mk(4)) > unroll_efficiency(&mk(1)));
        assert!(unroll_efficiency(&mk(4)) > unroll_efficiency(&mk(8)));
    }

    #[test]
    fn k_outer_penalized() {
        let inner = seeds::mfma_seed();
        let outer = KernelGenome {
            k_innermost: false,
            ..inner.clone()
        };
        assert!(loop_order_efficiency(&outer) < loop_order_efficiency(&inner));
    }

    #[test]
    fn pipe_efficiency_in_unit_interval() {
        for (_, g) in seeds::all_seeds() {
            let e = pipe_efficiency(&g);
            assert!(e > 0.0 && e <= 1.0, "{e}");
        }
    }
}
