//! Global-memory system model: HBM + infinity-cache traffic for the
//! tiled GEMM, load coalescing, and writeback efficiency.
//!
//! The block-tiled GEMM's DRAM traffic is the textbook expression:
//! every A tile is re-read once per column of output tiles and every B
//! tile once per row, so traffic shrinks with larger block_n/block_m —
//! that is what makes tile-size experiments matter. The MI300's large
//! infinity cache absorbs part of the re-read traffic; the grid
//! mapping decides how much locality neighbouring workgroups share
//! (the paper avenue "Padding Global Memory Inputs / L2-friendly
//! mappings").

use super::GpuArch;
use crate::genome::{GridMapping, KernelGenome, ScaleCache, Writeback};
use crate::workload::GemmConfig;

/// Coalescing efficiency of global loads by per-lane vector width.
pub fn coalescing_efficiency(vector_width: u32) -> f64 {
    match vector_width {
        1 => 0.25,
        2 => 0.45,
        4 => 0.70,
        8 => 0.90,
        _ => 1.0, // 16-byte dwordx4
    }
}

/// Fraction of operand re-read traffic served by the infinity cache
/// rather than HBM, per grid mapping.
pub fn l2_hit_fraction(g: &KernelGenome, cfg: &GemmConfig, arch: &GpuArch) -> f64 {
    // Working set of one "row" of output tiles: the A stripe plus all
    // B tiles it touches. If it fits in L2, re-reads hit.
    let elt = GpuArch::operand_elt_bytes(g) as f64;
    let a_stripe = g.block_m as f64 * cfg.k as f64 * elt;
    let b_full = cfg.k as f64 * cfg.n as f64 * elt;
    let ws_mib = (a_stripe + b_full) / (1024.0 * 1024.0);
    let base = if ws_mib <= arch.l2_mib { 0.85 } else { arch.l2_mib / ws_mib * 0.85 };
    match g.grid_mapping {
        GridMapping::RowMajor => base,
        GridMapping::ColMajor => base * 0.92,
        GridMapping::TileSwizzled => (base * 1.15).min(0.95),
    }
}

/// Total operand bytes that leave HBM (after cache), one kernel run.
pub fn hbm_operand_traffic(g: &KernelGenome, cfg: &GemmConfig, arch: &GpuArch) -> f64 {
    let elt = GpuArch::operand_elt_bytes(g) as f64;
    let (m, k, n) = (cfg.m as f64, cfg.k as f64, cfg.n as f64);
    let tiles_n = (cfg.n / g.block_n).max(1) as f64;
    let tiles_m = (cfg.m / g.block_m).max(1) as f64;
    // Tiled re-read traffic (LDS staging makes each element of a tile
    // loaded exactly once per owning workgroup).
    let mut a_traffic = m * k * elt * tiles_n;
    let mut b_traffic = k * n * elt * tiles_m;
    if !g.lds_staging {
        // Without staging each lane re-fetches operands itself; caches
        // absorb some but redundancy is large.
        a_traffic *= 2.0;
        b_traffic *= 2.0;
    }
    let hit = l2_hit_fraction(g, cfg, arch);
    // Cold capacity misses: each matrix must leave HBM at least once.
    let cold = (m * k + k * n) * elt;
    ((a_traffic + b_traffic) * (1.0 - hit)).max(cold)
}

/// Scale-vector traffic (per-row A scales + per-col B scales, f32).
pub fn scale_traffic(g: &KernelGenome, cfg: &GemmConfig) -> f64 {
    let per_tile = (g.block_m + g.block_n) as f64 * 4.0;
    let tiles = (cfg.m / g.block_m).max(1) as f64 * (cfg.n / g.block_n).max(1) as f64;
    match g.scale_cache {
        // Re-read on every k-step of every tile: pure waste.
        ScaleCache::GlobalReload => {
            let k_steps = (cfg.k / g.block_k).max(1) as f64;
            per_tile * tiles * k_steps
        }
        // Loaded once per tile into (dedicated or re-purposed) LDS.
        ScaleCache::Lds | ScaleCache::LdsRepurposed => per_tile * tiles,
    }
}

/// Output writeback time, microseconds. Single-wave writeback leaves
/// (waves-1)/waves of the block's store bandwidth idle (App. A.3
/// trades this for race-freedom; the A.2 experiment makes it
/// cooperative).
pub fn writeback_us(g: &KernelGenome, cfg: &GemmConfig, arch: &GpuArch) -> f64 {
    let bytes = cfg.output_bytes();
    let eff = match g.writeback {
        Writeback::Cooperative => 0.95,
        Writeback::SingleWave => 0.95 / g.waves_per_block as f64,
    };
    bytes / (arch.hbm_tbps * 1e6 * eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome};
    use crate::gpu::MI300;

    const CFG: GemmConfig = GemmConfig::new(4096, 1024, 4096);

    #[test]
    fn coalescing_monotone() {
        let widths = [1, 2, 4, 8, 16];
        for w in widths.windows(2) {
            assert!(coalescing_efficiency(w[0]) < coalescing_efficiency(w[1]));
        }
    }

    #[test]
    fn bigger_tiles_less_traffic() {
        let small = KernelGenome {
            block_m: 32,
            block_n: 32,
            ..seeds::human_oracle()
        };
        let big = KernelGenome {
            block_m: 256,
            block_n: 128,
            ..seeds::human_oracle()
        };
        assert!(
            hbm_operand_traffic(&big, &CFG, &MI300)
                < hbm_operand_traffic(&small, &CFG, &MI300)
        );
    }

    #[test]
    fn no_staging_multiplies_traffic() {
        let staged = seeds::mfma_seed();
        let unstaged = KernelGenome {
            lds_staging: false,
            double_buffer: false,
            scale_cache: ScaleCache::GlobalReload,
            ..staged.clone()
        };
        assert!(
            hbm_operand_traffic(&unstaged, &CFG, &MI300)
                >= 1.9 * hbm_operand_traffic(&staged, &CFG, &MI300)
        );
    }

    #[test]
    fn traffic_at_least_cold_misses() {
        let g = seeds::human_oracle();
        let elt = GpuArch::operand_elt_bytes(&g) as f64;
        let cold = (CFG.m as f64 * CFG.k as f64 + CFG.k as f64 * CFG.n as f64) * elt;
        assert!(hbm_operand_traffic(&g, &CFG, &MI300) >= cold);
    }

    #[test]
    fn tile_swizzle_improves_l2() {
        let row = KernelGenome {
            grid_mapping: GridMapping::RowMajor,
            ..seeds::human_oracle()
        };
        let swz = KernelGenome {
            grid_mapping: GridMapping::TileSwizzled,
            ..seeds::human_oracle()
        };
        assert!(l2_hit_fraction(&swz, &CFG, &MI300) > l2_hit_fraction(&row, &CFG, &MI300));
    }

    #[test]
    fn scale_reload_costs_more() {
        let reload = KernelGenome {
            scale_cache: ScaleCache::GlobalReload,
            ..seeds::human_oracle()
        };
        let cached = KernelGenome {
            scale_cache: ScaleCache::LdsRepurposed,
            ..seeds::human_oracle()
        };
        assert!(scale_traffic(&reload, &CFG) > scale_traffic(&cached, &CFG));
    }

    #[test]
    fn single_wave_writeback_slower() {
        let single = KernelGenome {
            writeback: Writeback::SingleWave,
            waves_per_block: 4,
            ..seeds::human_oracle()
        };
        let coop = KernelGenome {
            writeback: Writeback::Cooperative,
            waves_per_block: 4,
            ..seeds::human_oracle()
        };
        assert!(writeback_us(&single, &CFG, &MI300) > writeback_us(&coop, &CFG, &MI300));
    }
}
