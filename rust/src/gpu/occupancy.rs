//! Workgroup occupancy: how many waves a CU can keep resident for a
//! given genome, and the latency-hiding efficiency that follows.
//!
//! Mirrors the standard CDNA occupancy calculation: residency is the
//! min over LDS-capacity, VGPR-budget, and wave-slot limits. The
//! paper's Experiment Designer proposes occupancy experiments
//! ("Increase Thread Block Occupancy: explore larger TBLOCK_X_DIM
//! values", App. A.2) — this model is what makes those experiments
//! *mean* something in the simulator.

use super::GpuArch;
use crate::genome::KernelGenome;

/// Occupancy summary for one genome on one architecture.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    /// Co-resident workgroups per CU.
    pub workgroups_per_cu: u32,
    /// Resident waves per CU (workgroups x waves/block, capped).
    pub waves_per_cu: u32,
    /// Which resource bound: "lds" | "vgpr" | "slots".
    pub limiter: &'static str,
}

/// Compute occupancy for a genome.
pub fn occupancy(arch: &GpuArch, g: &KernelGenome) -> Occupancy {
    let lds = g.lds_bytes();
    let by_lds = if lds == 0 {
        u32::MAX
    } else {
        arch.lds_bytes / lds.max(1)
    };
    let vgprs = g.vgprs_per_lane().max(1);
    let by_vgpr = arch.vgprs_per_lane / vgprs;
    let by_slots = arch.wave_slots_per_cu / g.waves_per_block;
    let wg = by_lds.min(by_vgpr).min(by_slots).max(0);
    let limiter = if wg == by_lds && lds > 0 {
        "lds"
    } else if wg == by_vgpr {
        "vgpr"
    } else {
        "slots"
    };
    let wg = wg.min(16); // hardware workgroup-residency cap
    let waves = (wg * g.waves_per_block).min(arch.wave_slots_per_cu);
    Occupancy {
        workgroups_per_cu: wg,
        waves_per_cu: waves,
        limiter,
    }
}

/// Memory-latency-hiding efficiency from resident waves: one wave
/// hides almost nothing; ~16 waves hide essentially all HBM latency.
pub fn memory_latency_efficiency(occ: &Occupancy) -> f64 {
    let w = occ.waves_per_cu as f64;
    (0.30 + 0.70 * (w / 16.0).min(1.0)).min(1.0)
}

/// Compute-issue efficiency from resident waves: the matrix/vector
/// pipes need ~4 waves to stay fed through LDS/issue stalls.
pub fn compute_issue_efficiency(occ: &Occupancy) -> f64 {
    let w = occ.waves_per_cu as f64;
    (0.55 + 0.45 * (w / 4.0).min(1.0)).min(1.0)
}

/// Grid-level utilization: fraction of CUs doing useful work, with a
/// tail-quantization penalty when the workgroup count barely exceeds a
/// multiple of the machine width.
pub fn grid_utilization(arch: &GpuArch, occ: &Occupancy, total_workgroups: u64) -> f64 {
    let width = (arch.num_cus as u64 * occ.workgroups_per_cu.max(1) as u64).max(1);
    if total_workgroups == 0 {
        return 1.0;
    }
    if total_workgroups < width {
        return total_workgroups as f64 / width as f64;
    }
    let full_rounds = total_workgroups / width;
    let tail = total_workgroups % width;
    let rounds = full_rounds as f64 + if tail > 0 { tail as f64 / width as f64 } else { 0.0 };
    let ceil_rounds = full_rounds as f64 + if tail > 0 { 1.0 } else { 0.0 };
    rounds / ceil_rounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome, ScaleCache};
    use crate::gpu::MI300;

    #[test]
    fn naive_kernel_not_lds_limited() {
        let occ = occupancy(&MI300, &seeds::naive_hip());
        assert_ne!(occ.limiter, "lds");
        assert!(occ.workgroups_per_cu >= 1);
    }

    #[test]
    fn bigger_lds_reduces_workgroups() {
        let single = KernelGenome {
            double_buffer: false,
            scale_cache: ScaleCache::GlobalReload,
            ..seeds::human_oracle()
        };
        let double = KernelGenome {
            double_buffer: true,
            ..single.clone()
        };
        let o1 = occupancy(&MI300, &single);
        let o2 = occupancy(&MI300, &double);
        assert!(o2.workgroups_per_cu <= o1.workgroups_per_cu);
    }

    #[test]
    fn more_waves_hide_more_latency() {
        let low = Occupancy {
            workgroups_per_cu: 1,
            waves_per_cu: 1,
            limiter: "slots",
        };
        let high = Occupancy {
            workgroups_per_cu: 4,
            waves_per_cu: 16,
            limiter: "slots",
        };
        assert!(memory_latency_efficiency(&high) > memory_latency_efficiency(&low));
        assert!(compute_issue_efficiency(&high) > compute_issue_efficiency(&low));
        assert!(memory_latency_efficiency(&high) <= 1.0);
    }

    #[test]
    fn grid_utilization_small_grid_penalized() {
        let occ = Occupancy {
            workgroups_per_cu: 2,
            waves_per_cu: 8,
            limiter: "slots",
        };
        let small = grid_utilization(&MI300, &occ, 100);
        let large = grid_utilization(&MI300, &occ, 1_000_000);
        assert!(small < 0.25);
        assert!(large > 0.99);
    }

    #[test]
    fn grid_utilization_tail_quantization() {
        let occ = Occupancy {
            workgroups_per_cu: 1,
            waves_per_cu: 4,
            limiter: "slots",
        };
        // exactly one round vs one round + 1 workgroup
        let exact = grid_utilization(&MI300, &occ, MI300.num_cus as u64);
        let tail = grid_utilization(&MI300, &occ, MI300.num_cus as u64 + 1);
        assert!((exact - 1.0).abs() < 1e-9);
        assert!(tail < exact);
    }

    #[test]
    fn occupancy_deterministic() {
        let g = seeds::mfma_seed();
        assert_eq!(occupancy(&MI300, &g), occupancy(&MI300, &g));
    }
}
