//! Deterministic, seedable PRNG used everywhere randomness is needed.
//!
//! The paper's system has two stochastic elements we must model: LLM
//! sampling temperature (selector/designer/writer variation between
//! runs) and benchmark measurement noise on the evaluation platform.
//! Reproducibility of a whole scientist run from a single seed is a
//! hard requirement for the ablation benches, so we use a small,
//! dependency-free xoshiro256++ implementation with splitmix64 seeding
//! rather than a global RNG.

/// xoshiro256++ PRNG (Blackman & Vigna). Deterministic across
/// platforms; passes BigCrush; more than adequate for simulation noise
/// and surrogate-agent sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Construct from a 64-bit seed (expanded via splitmix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Snapshot the generator state (for run-store checkpoints:
    /// restoring it resumes the stream mid-sequence, bit for bit).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent child stream (for per-agent / per-iteration
    /// decorrelation without consuming the parent stream's sequence).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let base = self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::seed_from_u64(base)
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for our n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a reference to a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal multiplicative noise factor with geometric sigma
    /// `sigma` (e.g. 0.02 for ±2% jitter). Mean-one-ish for small sigma.
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::seed_from_u64(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_factor_centered_on_one() {
        let mut r = Rng::seed_from_u64(11);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.lognormal_factor(0.02)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn state_snapshot_resumes_mid_sequence() {
        let mut a = Rng::seed_from_u64(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let replay: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_eq!(tail, replay, "restored stream continues bit-identically");
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut parent = Rng::seed_from_u64(1234);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(17);
        let mut xs: Vec<u32> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<u32>>());
    }
}
