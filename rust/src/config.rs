//! Run configuration: every knob of the scientist loop, with a small
//! TOML-subset loader for config files (offline build — no toml crate;
//! the subset covers flat `key = value` pairs and `[section]` headers,
//! which is all our config files use).

use crate::agents::{ExperimentRule, KnowledgeProfile, LlmConfig, SelectionPolicy};

/// Full configuration of a scientist run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Registry key of the workload to optimize (`workload::lookup`);
    /// the paper's fp8 GEMM by default.
    pub workload: String,
    /// Master seed: agents, simulator noise, everything.
    pub seed: u64,
    /// Total submission budget (the competition quota). The paper's
    /// sequential good-citizen mode processed roughly this many.
    pub max_submissions: u64,
    /// Timing repetitions per config on the platform.
    pub reps_per_config: u32,
    /// Submission lanes (1 = the paper's sequential mode). Above 1,
    /// each iteration's children are evaluated concurrently on real
    /// executor threads (paper §5.1's counterfactual).
    pub eval_parallelism: u32,
    /// Serve duplicate genomes from the platform's eval-result cache
    /// (keyed by genome content hash) without consuming submission
    /// quota or platform time.
    pub eval_cache: bool,
    /// Drive the run with the steady-state experiment pipeline
    /// (DESIGN.md §8): planning refills evaluation lanes the moment
    /// they free instead of waiting at the lockstep batch barrier.
    /// At `eval_parallelism = 1` the pipeline trajectory is
    /// bit-identical to lockstep (`tests/pipeline.rs`).
    pub pipeline: bool,
    /// Pipeline depth per lane: how many submissions the scheduler may
    /// keep queued-or-running per evaluation lane (total in-flight cap
    /// = `eval_parallelism x inflight_per_lane`). 1 — the default —
    /// plans against the freshest possible ledger; higher values plan
    /// further ahead on staler results.
    pub inflight_per_lane: u32,
    /// Simulator measurement noise (lognormal sigma).
    pub noise_sigma: f64,
    pub selection_policy: SelectionPolicy,
    pub experiment_rule: ExperimentRule,
    pub knowledge: KnowledgeProfile,
    pub llm: LlmConfig,
    /// Re-derive the findings document by probing the platform before
    /// the loop (costs submissions), instead of assuming the paper's
    /// distilled bootstrap findings.
    pub bootstrap_probing: bool,
    /// Include the Matrix-Core seed kernel (§3). The MFMA seed is
    /// itself a product of the bootstrap deep-dive; the no-bootstrap
    /// counterfactual drops it along with the findings.
    pub include_mfma_seed: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workload: crate::workload::DEFAULT_WORKLOAD.to_string(),
            seed: 0,
            max_submissions: 120,
            reps_per_config: 3,
            eval_parallelism: 1,
            eval_cache: true,
            pipeline: false,
            inflight_per_lane: 1,
            noise_sigma: 0.02,
            selection_policy: SelectionPolicy::PaperLlm,
            experiment_rule: ExperimentRule::Paper,
            knowledge: KnowledgeProfile::Full,
            llm: LlmConfig::default(),
            bootstrap_probing: false,
            include_mfma_seed: true,
        }
    }
}

impl RunConfig {
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Target a different registered workload (see `workload::registry`).
    pub fn with_workload(mut self, name: &str) -> Self {
        self.workload = name.to_string();
        self
    }

    pub fn with_budget(mut self, max_submissions: u64) -> Self {
        self.max_submissions = max_submissions;
        self
    }

    /// Set the evaluation lane count (`platform.parallelism`).
    pub fn with_parallelism(mut self, lanes: u32) -> Self {
        self.eval_parallelism = lanes;
        self
    }

    /// Toggle the steady-state pipeline scheduler (`platform.pipeline`).
    pub fn with_pipeline(mut self, pipeline: bool) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Parse from the TOML subset (see module docs). Unknown keys are
    /// errors — config typos should not fail silently.
    pub fn from_toml(text: &str) -> Result<RunConfig, String> {
        let mut cfg = RunConfig::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                if !matches!(section.as_str(), "run" | "platform" | "agents" | "llm") {
                    return Err(format!("line {}: unknown section [{section}]", lineno + 1));
                }
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let value = value.trim().trim_matches('"');
            let qualified = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            cfg.set(&qualified, value)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        Ok(cfg)
    }

    fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let parse_u64 =
            |v: &str| v.parse::<u64>().map_err(|_| format!("bad integer '{v}'"));
        let parse_f64 =
            |v: &str| v.parse::<f64>().map_err(|_| format!("bad float '{v}'"));
        match key {
            "run.workload" | "workload" => {
                if crate::workload::lookup(value).is_none() {
                    let known: Vec<&str> =
                        crate::workload::registry().iter().map(|w| w.name()).collect();
                    return Err(format!(
                        "unknown workload '{value}' (registered: {})",
                        known.join(", ")
                    ));
                }
                self.workload = value.to_string();
            }
            "run.seed" | "seed" => self.seed = parse_u64(value)?,
            "run.max_submissions" | "max_submissions" => {
                self.max_submissions = parse_u64(value)?
            }
            "platform.reps_per_config" => self.reps_per_config = parse_u64(value)? as u32,
            "platform.parallelism" => self.eval_parallelism = parse_u64(value)? as u32,
            "platform.cache" => {
                self.eval_cache = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad cache '{value}'")),
                }
            }
            "platform.pipeline" => {
                self.pipeline = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad pipeline '{value}'")),
                }
            }
            "platform.inflight_per_lane" => {
                let depth = parse_u64(value)? as u32;
                if depth == 0 {
                    return Err("inflight_per_lane must be >= 1".into());
                }
                self.inflight_per_lane = depth;
            }
            "platform.noise_sigma" => self.noise_sigma = parse_f64(value)?,
            "agents.selection_policy" => {
                self.selection_policy = match value {
                    "paper" => SelectionPolicy::PaperLlm,
                    "random" => SelectionPolicy::Random,
                    "greedy" => SelectionPolicy::GreedyBest,
                    _ => return Err(format!("bad selection_policy '{value}'")),
                }
            }
            "agents.experiment_rule" => {
                self.experiment_rule = match value {
                    "paper" => ExperimentRule::Paper,
                    "top_max" => ExperimentRule::TopMax,
                    "random3" => ExperimentRule::Random3,
                    _ => return Err(format!("bad experiment_rule '{value}'")),
                }
            }
            "agents.bootstrap_probing" => {
                self.bootstrap_probing = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad bootstrap_probing '{value}'")),
                }
            }
            "agents.knowledge" => {
                self.knowledge = match value {
                    "full" => KnowledgeProfile::Full,
                    "generic" => KnowledgeProfile::GenericOnly,
                    "minimal" => KnowledgeProfile::Minimal,
                    _ => return Err(format!("bad knowledge '{value}'")),
                }
            }
            "llm.temperature" => self.llm.temperature = parse_f64(value)?,
            "llm.estimate_sigma" => self.llm.estimate_sigma = parse_f64(value)?,
            "llm.rubric_infidelity" => self.llm.rubric_infidelity = parse_f64(value)?,
            _ => return Err(format!("unknown key '{key}'")),
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // naive: '#' outside quotes starts a comment (our values never
    // contain '#')
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let c = RunConfig::default();
        assert_eq!(c.workload, "fp8-gemm", "the paper's task is the default");
        assert_eq!(c.eval_parallelism, 1, "sequential good-citizen mode");
        assert!(c.eval_cache, "duplicate submissions are free by default");
        assert_eq!(c.selection_policy, SelectionPolicy::PaperLlm);
        assert_eq!(c.experiment_rule, ExperimentRule::Paper);
        assert_eq!(c.knowledge, KnowledgeProfile::Full);
    }

    #[test]
    fn toml_platform_cache_knob() {
        let c = RunConfig::from_toml("[platform]\ncache = false\n").unwrap();
        assert!(!c.eval_cache);
        assert!(RunConfig::from_toml("[platform]\ncache = maybe\n").is_err());
    }

    #[test]
    fn toml_full_document() {
        let text = r#"
# scientist run config
[run]
seed = 7
max_submissions = 50

[platform]
reps_per_config = 5
parallelism = 3
noise_sigma = 0.05

[agents]
selection_policy = "greedy"
experiment_rule = "top_max"
knowledge = "generic"

[llm]
temperature = 1.2
estimate_sigma = 0.4
rubric_infidelity = 0.2
"#;
        let c = RunConfig::from_toml(text).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.max_submissions, 50);
        assert_eq!(c.reps_per_config, 5);
        assert_eq!(c.eval_parallelism, 3);
        assert_eq!(c.noise_sigma, 0.05);
        assert_eq!(c.selection_policy, SelectionPolicy::GreedyBest);
        assert_eq!(c.experiment_rule, ExperimentRule::TopMax);
        assert_eq!(c.knowledge, KnowledgeProfile::GenericOnly);
        assert_eq!(c.llm.temperature, 1.2);
        assert_eq!(c.llm.rubric_infidelity, 0.2);
    }

    #[test]
    fn toml_pipeline_knobs() {
        let c = RunConfig::from_toml(
            "[platform]\nparallelism = 4\npipeline = true\ninflight_per_lane = 2\n",
        )
        .unwrap();
        assert!(c.pipeline);
        assert_eq!(c.eval_parallelism, 4);
        assert_eq!(c.inflight_per_lane, 2);
        assert!(!RunConfig::default().pipeline, "lockstep is the default");
        assert_eq!(RunConfig::default().inflight_per_lane, 1);
        assert!(RunConfig::from_toml("[platform]\npipeline = maybe\n").is_err());
        assert!(RunConfig::from_toml("[platform]\ninflight_per_lane = 0\n").is_err());
    }

    #[test]
    fn builders_set_pipeline_and_parallelism() {
        let c = RunConfig::default().with_parallelism(4).with_pipeline(true);
        assert_eq!(c.eval_parallelism, 4);
        assert!(c.pipeline);
    }

    #[test]
    fn toml_partial_keeps_defaults() {
        let c = RunConfig::from_toml("[run]\nseed = 3\n").unwrap();
        assert_eq!(c.seed, 3);
        assert_eq!(c.max_submissions, RunConfig::default().max_submissions);
    }

    #[test]
    fn toml_workload_key() {
        let c = RunConfig::from_toml("[run]\nworkload = \"row-softmax\"\n").unwrap();
        assert_eq!(c.workload, "row-softmax");
        let c = RunConfig::from_toml("workload = \"bf16-gemm\"\n").unwrap();
        assert_eq!(c.workload, "bf16-gemm");
        // unknown workloads fail fast with the registry listing
        let err = RunConfig::from_toml("[run]\nworkload = \"tf32-gemm\"\n").unwrap_err();
        assert!(err.contains("unknown workload"), "{err}");
        assert!(err.contains("fp8-gemm"), "{err}");
    }

    #[test]
    fn builder_sets_workload() {
        let c = RunConfig::default().with_workload("row-softmax");
        assert_eq!(c.workload, "row-softmax");
    }

    #[test]
    fn toml_unknown_key_rejected() {
        assert!(RunConfig::from_toml("[run]\nspeed = 3\n").is_err());
        assert!(RunConfig::from_toml("[warp]\nseed = 3\n").is_err());
    }

    #[test]
    fn toml_bad_values_rejected() {
        assert!(RunConfig::from_toml("[run]\nseed = fast\n").is_err());
        assert!(RunConfig::from_toml("[agents]\nknowledge = \"psychic\"\n").is_err());
    }

    #[test]
    fn builders() {
        let c = RunConfig::default().with_seed(9).with_budget(10);
        assert_eq!(c.seed, 9);
        assert_eq!(c.max_submissions, 10);
    }
}
