//! Run configuration: every knob of the scientist loop, with a small
//! TOML-subset loader for config files (offline build — no toml crate;
//! the subset covers flat `key = value` pairs and `[section]` headers,
//! which is all our config files use).

use crate::agents::{ExperimentRule, KnowledgeProfile, LlmConfig, SelectionPolicy};
use crate::eval::FaultConfig;

/// Full configuration of a scientist run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Registry key of the workload to optimize (`workload::lookup`);
    /// the paper's fp8 GEMM by default.
    pub workload: String,
    /// Master seed: agents, simulator noise, everything.
    pub seed: u64,
    /// Total submission budget (the competition quota). The paper's
    /// sequential good-citizen mode processed roughly this many.
    pub max_submissions: u64,
    /// Timing repetitions per config on the platform.
    pub reps_per_config: u32,
    /// Submission lanes (1 = the paper's sequential mode). Above 1,
    /// each iteration's children are evaluated concurrently on real
    /// executor threads (paper §5.1's counterfactual).
    pub eval_parallelism: u32,
    /// Serve duplicate genomes from the platform's eval-result cache
    /// (keyed by genome content hash) without consuming submission
    /// quota or platform time.
    pub eval_cache: bool,
    /// Drive the run with the steady-state experiment pipeline
    /// (DESIGN.md §8): planning refills evaluation lanes the moment
    /// they free instead of waiting at the lockstep batch barrier.
    /// At `eval_parallelism = 1` the pipeline trajectory is
    /// bit-identical to lockstep (`tests/pipeline.rs`).
    pub pipeline: bool,
    /// Pipeline depth per lane: how many submissions the scheduler may
    /// keep queued-or-running per evaluation lane (total in-flight cap
    /// = `eval_parallelism x inflight_per_lane`). 1 — the default —
    /// plans against the freshest possible ledger; higher values plan
    /// further ahead on staler results.
    pub inflight_per_lane: u32,
    /// Simulator measurement noise (lognormal sigma).
    pub noise_sigma: f64,
    /// Enable the analytic pre-screen tier (`[screen] enabled`,
    /// DESIGN.md §10): planned candidates are scored with the
    /// workload's cost model and only the top `screen_keep` fraction
    /// of each rung is promoted into the expensive platform. Disabled
    /// by default — an off run takes no screen code path, so its
    /// trajectory is bit-identical to a build without the tier
    /// (`tests/screen.rs`).
    pub screen_enabled: bool,
    /// Screen rung size (`[screen] rung`): candidates accumulated per
    /// promotion decision in the pipeline scheduler. Lockstep screens
    /// each planned batch as its own rung, ignoring this knob.
    pub screen_rung: u32,
    /// Fraction of each rung promoted (`[screen] keep_fraction`),
    /// in (0, 1].
    pub screen_keep: f64,
    pub selection_policy: SelectionPolicy,
    pub experiment_rule: ExperimentRule,
    pub knowledge: KnowledgeProfile,
    pub llm: LlmConfig,
    /// Re-derive the findings document by probing the platform before
    /// the loop (costs submissions), instead of assuming the paper's
    /// distilled bootstrap findings.
    pub bootstrap_probing: bool,
    /// Include the Matrix-Core seed kernel (§3). The MFMA seed is
    /// itself a product of the bootstrap deep-dive; the no-bootstrap
    /// counterfactual drops it along with the findings.
    pub include_mfma_seed: bool,
    /// Durable run store directory (`[store] dir`, DESIGN.md §9). When
    /// set, every experiment is journaled to
    /// `<dir>/journal.jsonl` and the run checkpoints periodically to
    /// `<dir>/checkpoint.json`; `resume`/`replay` reconstruct from it.
    /// `None` (the default) keeps the run in-memory only.
    pub store_dir: Option<String>,
    /// Completed scheduler steps between checkpoints (`[store]
    /// checkpoint_every`): lockstep iterations, or drained pipeline
    /// completions. 1 — the default — checkpoints after every step.
    pub checkpoint_every: u64,
    /// Testing/CI knob (CLI `--halt-after N`, never persisted): abort
    /// the scheduler — **without** a final checkpoint, simulating a
    /// crash — once the platform has committed `N` submissions. The
    /// resume-equivalence suite and CI smoke are built on it.
    pub halt_after: Option<u64>,
    /// Profile-guided experiment design (`[profile] guided`,
    /// DESIGN.md §11): the base kernel's bottleneck classification
    /// conditions the designer's avenue priors, and run outcomes /
    /// reports surface the bottleneck mix. Off by default — a disabled
    /// run takes no guided code path (the designer sees `None`, no
    /// extra RNG draws), so its trajectory and reports are
    /// bit-identical to a build without the profile layer
    /// (`tests/determinism.rs`). Per-experiment `ProfileReport`s are
    /// journaled regardless: the profile is a pure recomputation from
    /// the cost model, so attaching it never perturbs a run.
    pub profile_guided: bool,
    /// Federated archive directory (`[federation] dir`, DESIGN.md §12):
    /// a cross-run store of evaluated (genome, workload, config-digest)
    /// results. When set, the run consults it before burning a
    /// submission on any genome a prior campaign already evaluated
    /// under an identical eval-relevant config, and registers its own
    /// results there on successful completion. `None` (the default)
    /// takes no federation code path at all, so the trajectory is
    /// bit-identical to a build without the layer (`tests/federation.rs`).
    pub federation_dir: Option<String>,
    /// Warm-start seeding (`[federation] warm_start_k`): inject up to
    /// this many prior-campaign elites — mined across workloads and
    /// filtered through the target workload's `admits` gate — as extra
    /// seed candidates. 0 (the default) injects nothing.
    pub federation_warm_start_k: u32,
    /// Consult the federated store but never write to it
    /// (`[federation] read_only`) — e.g. CI runs against a curated
    /// archive.
    pub federation_read_only: bool,
    /// Static lint gate (`[lint] gate`, DESIGN.md §13): planned
    /// children carrying an `Error` diagnostic (exactly the
    /// `validate`/`admits` reject set) are rejected before they occupy
    /// a lane, releasing their reservation like a screen reject. Off by
    /// default — a disabled run takes no lint code path, so its
    /// trajectory is bit-identical to a build without the analyzer
    /// (`tests/lint.rs`).
    pub lint_gate: bool,
    /// Lint-guided experiment design (`[lint] guided`, DESIGN.md §13):
    /// the base kernel's warn diagnostics and its lint-rejected
    /// children's error diagnostics feed the designer's avenue priors
    /// through `Avenue::attacks()`, PR 7-style. Off by default with the
    /// same bit-identity guarantee as `lint_gate`.
    pub lint_guided: bool,
    /// Fault injection + recovery (`[faults]`, DESIGN.md §14): a
    /// deterministic fault model over the eval backend (transient
    /// errors, stragglers, corrupted timings, lane death) plus the
    /// recovery policy (backoff retries, timeout-requeue, outlier
    /// confirmation, lane quarantine). Disabled by default — an off
    /// run takes no fault code path and draws no fault RNG, so its
    /// trajectory is bit-identical to a build without the layer
    /// (`tests/faults.rs`).
    pub faults: FaultConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            workload: crate::workload::DEFAULT_WORKLOAD.to_string(),
            seed: 0,
            max_submissions: 120,
            reps_per_config: 3,
            eval_parallelism: 1,
            eval_cache: true,
            pipeline: false,
            inflight_per_lane: 1,
            noise_sigma: 0.02,
            screen_enabled: false,
            screen_rung: 8,
            screen_keep: 0.5,
            selection_policy: SelectionPolicy::PaperLlm,
            experiment_rule: ExperimentRule::Paper,
            knowledge: KnowledgeProfile::Full,
            llm: LlmConfig::default(),
            bootstrap_probing: false,
            include_mfma_seed: true,
            store_dir: None,
            checkpoint_every: 1,
            halt_after: None,
            profile_guided: false,
            federation_dir: None,
            federation_warm_start_k: 0,
            federation_read_only: false,
            lint_gate: false,
            lint_guided: false,
            faults: FaultConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Target a different registered workload (see `workload::registry`).
    pub fn with_workload(mut self, name: &str) -> Self {
        self.workload = name.to_string();
        self
    }

    pub fn with_budget(mut self, max_submissions: u64) -> Self {
        self.max_submissions = max_submissions;
        self
    }

    /// Set the evaluation lane count (`platform.parallelism`).
    pub fn with_parallelism(mut self, lanes: u32) -> Self {
        self.eval_parallelism = lanes;
        self
    }

    /// Toggle the steady-state pipeline scheduler (`platform.pipeline`).
    pub fn with_pipeline(mut self, pipeline: bool) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Enable the analytic pre-screen tier with the given rung size and
    /// keep fraction (`[screen]`, DESIGN.md §10).
    pub fn with_screen(mut self, rung: u32, keep_fraction: f64) -> Self {
        self.screen_enabled = true;
        self.screen_rung = rung;
        self.screen_keep = keep_fraction;
        self
    }

    /// Toggle profile-guided experiment design (`[profile] guided`,
    /// DESIGN.md §11).
    pub fn with_profile_guided(mut self, guided: bool) -> Self {
        self.profile_guided = guided;
        self
    }

    /// Point the run at a federated archive directory (`[federation]`,
    /// DESIGN.md §12).
    pub fn with_federation(mut self, dir: &str) -> Self {
        self.federation_dir = Some(dir.to_string());
        self
    }

    /// Set the warm-start elite count (`[federation] warm_start_k`).
    pub fn with_warm_start_k(mut self, k: u32) -> Self {
        self.federation_warm_start_k = k;
        self
    }

    /// Toggle the static lint gate (`[lint] gate`, DESIGN.md §13).
    pub fn with_lint_gate(mut self, gate: bool) -> Self {
        self.lint_gate = gate;
        self
    }

    /// Toggle lint-guided experiment design (`[lint] guided`,
    /// DESIGN.md §13).
    pub fn with_lint_guided(mut self, guided: bool) -> Self {
        self.lint_guided = guided;
        self
    }

    /// Enable deterministic fault injection with the layer's default
    /// rates (`[faults] enabled`, DESIGN.md §14).
    pub fn with_faults(mut self, enabled: bool) -> Self {
        self.faults.enabled = enabled;
        self
    }

    /// Replace the whole fault model + recovery policy (`[faults]`,
    /// DESIGN.md §14).
    pub fn with_fault_config(mut self, faults: FaultConfig) -> Self {
        self.faults = faults;
        self
    }

    /// Parse from the TOML subset (see module docs). Unknown keys are
    /// errors — config typos should not fail silently.
    pub fn from_toml(text: &str) -> Result<RunConfig, String> {
        let mut cfg = RunConfig::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                if !matches!(
                    section.as_str(),
                    "run" | "platform" | "agents" | "llm" | "store" | "screen" | "profile"
                        | "federation" | "lint" | "faults"
                ) {
                    return Err(format!("line {}: unknown section [{section}]", lineno + 1));
                }
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            let value = value.trim().trim_matches('"');
            let qualified = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            cfg.set(&qualified, value)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        Ok(cfg)
    }

    fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let parse_u64 =
            |v: &str| v.parse::<u64>().map_err(|_| format!("bad integer '{v}'"));
        let parse_f64 =
            |v: &str| v.parse::<f64>().map_err(|_| format!("bad float '{v}'"));
        match key {
            "run.workload" | "workload" => {
                if crate::workload::lookup(value).is_none() {
                    let known: Vec<&str> =
                        crate::workload::registry().iter().map(|w| w.name()).collect();
                    return Err(format!(
                        "unknown workload '{value}' (registered: {})",
                        known.join(", ")
                    ));
                }
                self.workload = value.to_string();
            }
            "run.seed" | "seed" => self.seed = parse_u64(value)?,
            "run.max_submissions" | "max_submissions" => {
                self.max_submissions = parse_u64(value)?
            }
            "platform.reps_per_config" => self.reps_per_config = parse_u64(value)? as u32,
            "platform.parallelism" => self.eval_parallelism = parse_u64(value)? as u32,
            "platform.cache" => {
                self.eval_cache = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad cache '{value}'")),
                }
            }
            "platform.pipeline" => {
                self.pipeline = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad pipeline '{value}'")),
                }
            }
            "platform.inflight_per_lane" => {
                let depth = parse_u64(value)? as u32;
                if depth == 0 {
                    return Err("inflight_per_lane must be >= 1".into());
                }
                self.inflight_per_lane = depth;
            }
            "platform.noise_sigma" => self.noise_sigma = parse_f64(value)?,
            "screen.enabled" => {
                self.screen_enabled = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad screen enabled '{value}'")),
                }
            }
            "screen.rung" => {
                let rung = parse_u64(value)? as u32;
                if rung == 0 {
                    return Err("screen rung must be >= 1".into());
                }
                self.screen_rung = rung;
            }
            "screen.keep_fraction" => {
                let keep = parse_f64(value)?;
                if !(keep > 0.0 && keep <= 1.0) {
                    return Err(format!(
                        "screen keep_fraction must be in (0, 1], got '{value}'"
                    ));
                }
                self.screen_keep = keep;
            }
            "profile.guided" => {
                self.profile_guided = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad profile guided '{value}'")),
                }
            }
            "agents.selection_policy" => {
                self.selection_policy = parse_selection_policy(value)?
            }
            "agents.experiment_rule" => self.experiment_rule = parse_experiment_rule(value)?,
            "agents.bootstrap_probing" => {
                self.bootstrap_probing = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad bootstrap_probing '{value}'")),
                }
            }
            "agents.knowledge" => self.knowledge = parse_knowledge(value)?,
            "llm.temperature" => self.llm.temperature = parse_f64(value)?,
            "llm.estimate_sigma" => self.llm.estimate_sigma = parse_f64(value)?,
            "llm.rubric_infidelity" => self.llm.rubric_infidelity = parse_f64(value)?,
            "store.dir" => {
                if value.is_empty() {
                    return Err("store.dir must not be empty".into());
                }
                self.store_dir = Some(value.to_string());
            }
            "store.checkpoint_every" => {
                let every = parse_u64(value)?;
                if every == 0 {
                    return Err("checkpoint_every must be >= 1".into());
                }
                self.checkpoint_every = every;
            }
            "federation.dir" => {
                if value.is_empty() {
                    return Err("federation.dir must not be empty".into());
                }
                self.federation_dir = Some(value.to_string());
            }
            "federation.warm_start_k" => {
                self.federation_warm_start_k = parse_u64(value)? as u32
            }
            "federation.read_only" => {
                self.federation_read_only = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad federation read_only '{value}'")),
                }
            }
            "lint.gate" => {
                self.lint_gate = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad lint gate '{value}'")),
                }
            }
            "lint.guided" => {
                self.lint_guided = match value {
                    "true" => true,
                    "false" => false,
                    _ => return Err(format!("bad lint guided '{value}'")),
                }
            }
            _ if key.starts_with("faults.") => {
                let parse_bool = |v: &str| match v {
                    "true" => Ok(true),
                    "false" => Ok(false),
                    _ => Err(format!("bad bool '{v}'")),
                };
                let parse_prob = |v: &str| -> Result<f64, String> {
                    let p = parse_f64(v)?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("probability must be in [0, 1], got '{v}'"));
                    }
                    Ok(p)
                };
                let f = &mut self.faults;
                match key {
                    "faults.enabled" => f.enabled = parse_bool(value)?,
                    "faults.transient" => f.transient = parse_prob(value)?,
                    "faults.straggler" => f.straggler = parse_prob(value)?,
                    "faults.straggler_factor" => f.straggler_factor = parse_f64(value)?,
                    "faults.straggler_timeout" => f.straggler_timeout = parse_f64(value)?,
                    "faults.corrupt" => f.corrupt = parse_prob(value)?,
                    "faults.corrupt_factor" => f.corrupt_factor = parse_f64(value)?,
                    "faults.lane_death" => f.lane_death = parse_prob(value)?,
                    "faults.recovery" => f.recovery = parse_bool(value)?,
                    "faults.max_retries" => f.max_retries = parse_u64(value)? as u32,
                    "faults.backoff_base_s" => f.backoff_base_s = parse_f64(value)?,
                    "faults.backoff_cap_s" => f.backoff_cap_s = parse_f64(value)?,
                    "faults.confirm_outliers" => f.confirm_outliers = parse_bool(value)?,
                    "faults.outlier_threshold" => f.outlier_threshold = parse_f64(value)?,
                    "faults.quarantine_after" => {
                        let k = parse_u64(value)? as u32;
                        if k == 0 {
                            return Err("quarantine_after must be >= 1".into());
                        }
                        f.quarantine_after = k;
                    }
                    "faults.probation_s" => f.probation_s = parse_f64(value)?,
                    _ => return Err(format!("unknown key '{key}'")),
                }
            }
            _ => return Err(format!("unknown key '{key}'")),
        }
        Ok(())
    }
}

impl RunConfig {
    /// Serialize every persistent knob for the run-store checkpoint
    /// (DESIGN.md §9) so `resume` is self-contained — no config file
    /// needed. Tokens match the TOML vocabulary; `store_dir` and the
    /// `halt_after` test knob are runtime-local and not persisted (the
    /// resume CLI re-derives the directory from its argument).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut pairs = vec![
            ("workload", Json::Str(self.workload.clone())),
            // hex: the seed derives every RNG stream and Json::Num is
            // f64-backed — a seed >= 2^53 must round-trip exactly or
            // the resumed lane forks diverge
            ("seed", crate::util::json::u64_hex(self.seed)),
            ("max_submissions", Json::Num(self.max_submissions as f64)),
            ("reps_per_config", Json::Num(self.reps_per_config as f64)),
            ("parallelism", Json::Num(self.eval_parallelism as f64)),
            ("cache", Json::Bool(self.eval_cache)),
            ("pipeline", Json::Bool(self.pipeline)),
            (
                "inflight_per_lane",
                Json::Num(self.inflight_per_lane as f64),
            ),
            ("noise_sigma", Json::Num(self.noise_sigma)),
            ("screen_enabled", Json::Bool(self.screen_enabled)),
            ("screen_rung", Json::Num(self.screen_rung as f64)),
            ("screen_keep", Json::Num(self.screen_keep)),
            (
                "selection_policy",
                Json::Str(selection_policy_token(self.selection_policy).into()),
            ),
            (
                "experiment_rule",
                Json::Str(experiment_rule_token(self.experiment_rule).into()),
            ),
            ("knowledge", Json::Str(knowledge_token(self.knowledge).into())),
            ("temperature", Json::Num(self.llm.temperature)),
            ("estimate_sigma", Json::Num(self.llm.estimate_sigma)),
            ("rubric_infidelity", Json::Num(self.llm.rubric_infidelity)),
            ("bootstrap_probing", Json::Bool(self.bootstrap_probing)),
            ("include_mfma_seed", Json::Bool(self.include_mfma_seed)),
            ("checkpoint_every", Json::Num(self.checkpoint_every as f64)),
            ("profile_guided", Json::Bool(self.profile_guided)),
        ];
        // emitted only when federation is on, keeping federation-off
        // checkpoints byte-identical to pre-federation ones. Unlike
        // `store_dir`, the federation dir IS persisted: a resumed run
        // must re-attach the same archive or its trajectory diverges.
        if let Some(dir) = &self.federation_dir {
            pairs.push(("federation_dir", Json::Str(dir.clone())));
            pairs.push((
                "federation_warm_start_k",
                Json::Num(self.federation_warm_start_k as f64),
            ));
            pairs.push((
                "federation_read_only",
                Json::Bool(self.federation_read_only),
            ));
        }
        // same only-when-on rule: lint-off checkpoints stay
        // byte-identical to pre-lint ones
        if self.lint_gate {
            pairs.push(("lint_gate", Json::Bool(true)));
        }
        if self.lint_guided {
            pairs.push(("lint_guided", Json::Bool(true)));
        }
        // same only-when-on rule: faults-off checkpoints stay
        // byte-identical to pre-faults ones. The whole model is
        // persisted when on — a resumed chaos run must replay the
        // exact same rates or its fault draws diverge.
        if self.faults.enabled {
            pairs.push(("faults", self.faults.to_json()));
        }
        Json::obj(pairs)
    }

    /// Rebuild from a [`RunConfig::to_json`] checkpoint entry.
    pub fn from_json(v: &crate::util::json::Json) -> Result<RunConfig, String> {
        use crate::util::json::{parse_u64_hex, req_bool, req_f64, req_str, req_u64};
        // same rule as genome::persist: a corrupted checkpoint must not
        // narrow into a valid-looking config via `as u32`
        let u32_field = |k: &str| -> Result<u32, String> {
            let raw = req_u64(v, k)?;
            u32::try_from(raw).map_err(|_| format!("config: {k} out of u32 range: {raw}"))
        };
        let workload = req_str(v, "workload")?.to_string();
        if crate::workload::lookup(&workload).is_none() {
            return Err(format!("config: unknown workload '{workload}'"));
        }
        Ok(RunConfig {
            workload,
            seed: parse_u64_hex(v.get("seed").ok_or("config: missing seed")?)
                .map_err(|e| format!("config seed: {e}"))?,
            max_submissions: req_u64(v, "max_submissions")?,
            reps_per_config: u32_field("reps_per_config")?,
            eval_parallelism: u32_field("parallelism")?,
            eval_cache: req_bool(v, "cache")?,
            pipeline: req_bool(v, "pipeline")?,
            inflight_per_lane: u32_field("inflight_per_lane")?,
            noise_sigma: req_f64(v, "noise_sigma")?,
            screen_enabled: req_bool(v, "screen_enabled")?,
            screen_rung: u32_field("screen_rung")?,
            screen_keep: req_f64(v, "screen_keep")?,
            selection_policy: parse_selection_policy(req_str(v, "selection_policy")?)?,
            experiment_rule: parse_experiment_rule(req_str(v, "experiment_rule")?)?,
            knowledge: parse_knowledge(req_str(v, "knowledge")?)?,
            llm: LlmConfig {
                temperature: req_f64(v, "temperature")?,
                estimate_sigma: req_f64(v, "estimate_sigma")?,
                rubric_infidelity: req_f64(v, "rubric_infidelity")?,
            },
            bootstrap_probing: req_bool(v, "bootstrap_probing")?,
            include_mfma_seed: req_bool(v, "include_mfma_seed")?,
            store_dir: None,
            checkpoint_every: req_u64(v, "checkpoint_every")?,
            halt_after: None,
            profile_guided: req_bool(v, "profile_guided")?,
            // tolerant: pre-federation checkpoints carry none of these
            federation_dir: match v.get("federation_dir") {
                None | Some(crate::util::json::Json::Null) => None,
                Some(s) => Some(
                    s.as_str()
                        .ok_or("config: bad federation_dir")?
                        .to_string(),
                ),
            },
            federation_warm_start_k: match v.get("federation_warm_start_k") {
                None | Some(crate::util::json::Json::Null) => 0,
                Some(x) => {
                    let raw = x.as_f64().ok_or("config: bad federation_warm_start_k")? as u64;
                    u32::try_from(raw).map_err(|_| {
                        format!("config: federation_warm_start_k out of u32 range: {raw}")
                    })?
                }
            },
            federation_read_only: match v.get("federation_read_only") {
                None | Some(crate::util::json::Json::Null) => false,
                Some(x) => x.as_bool().ok_or("config: bad federation_read_only")?,
            },
            // tolerant: pre-lint checkpoints carry neither key
            lint_gate: match v.get("lint_gate") {
                None | Some(crate::util::json::Json::Null) => false,
                Some(x) => x.as_bool().ok_or("config: bad lint_gate")?,
            },
            lint_guided: match v.get("lint_guided") {
                None | Some(crate::util::json::Json::Null) => false,
                Some(x) => x.as_bool().ok_or("config: bad lint_guided")?,
            },
            // tolerant: pre-faults (and every faults-off) checkpoint
            // carries no faults object
            faults: match v.get("faults") {
                None | Some(crate::util::json::Json::Null) => FaultConfig::default(),
                Some(f) => FaultConfig::from_json(f)
                    .map_err(|e| format!("config faults: {e}"))?,
            },
        })
    }
}

fn selection_policy_token(p: SelectionPolicy) -> &'static str {
    match p {
        SelectionPolicy::PaperLlm => "paper",
        SelectionPolicy::Random => "random",
        SelectionPolicy::GreedyBest => "greedy",
    }
}

fn parse_selection_policy(value: &str) -> Result<SelectionPolicy, String> {
    match value {
        "paper" => Ok(SelectionPolicy::PaperLlm),
        "random" => Ok(SelectionPolicy::Random),
        "greedy" => Ok(SelectionPolicy::GreedyBest),
        _ => Err(format!("bad selection_policy '{value}'")),
    }
}

fn experiment_rule_token(r: ExperimentRule) -> &'static str {
    match r {
        ExperimentRule::Paper => "paper",
        ExperimentRule::TopMax => "top_max",
        ExperimentRule::Random3 => "random3",
    }
}

fn parse_experiment_rule(value: &str) -> Result<ExperimentRule, String> {
    match value {
        "paper" => Ok(ExperimentRule::Paper),
        "top_max" => Ok(ExperimentRule::TopMax),
        "random3" => Ok(ExperimentRule::Random3),
        _ => Err(format!("bad experiment_rule '{value}'")),
    }
}

fn knowledge_token(k: KnowledgeProfile) -> &'static str {
    match k {
        KnowledgeProfile::Full => "full",
        KnowledgeProfile::GenericOnly => "generic",
        KnowledgeProfile::Minimal => "minimal",
    }
}

fn parse_knowledge(value: &str) -> Result<KnowledgeProfile, String> {
    match value {
        "full" => Ok(KnowledgeProfile::Full),
        "generic" => Ok(KnowledgeProfile::GenericOnly),
        "minimal" => Ok(KnowledgeProfile::Minimal),
        _ => Err(format!("bad knowledge '{value}'")),
    }
}

fn strip_comment(line: &str) -> &str {
    // naive: '#' outside quotes starts a comment (our values never
    // contain '#')
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let c = RunConfig::default();
        assert_eq!(c.workload, "fp8-gemm", "the paper's task is the default");
        assert_eq!(c.eval_parallelism, 1, "sequential good-citizen mode");
        assert!(c.eval_cache, "duplicate submissions are free by default");
        assert_eq!(c.selection_policy, SelectionPolicy::PaperLlm);
        assert_eq!(c.experiment_rule, ExperimentRule::Paper);
        assert_eq!(c.knowledge, KnowledgeProfile::Full);
    }

    #[test]
    fn toml_platform_cache_knob() {
        let c = RunConfig::from_toml("[platform]\ncache = false\n").unwrap();
        assert!(!c.eval_cache);
        assert!(RunConfig::from_toml("[platform]\ncache = maybe\n").is_err());
    }

    #[test]
    fn toml_full_document() {
        let text = r#"
# scientist run config
[run]
seed = 7
max_submissions = 50

[platform]
reps_per_config = 5
parallelism = 3
noise_sigma = 0.05

[agents]
selection_policy = "greedy"
experiment_rule = "top_max"
knowledge = "generic"

[llm]
temperature = 1.2
estimate_sigma = 0.4
rubric_infidelity = 0.2
"#;
        let c = RunConfig::from_toml(text).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.max_submissions, 50);
        assert_eq!(c.reps_per_config, 5);
        assert_eq!(c.eval_parallelism, 3);
        assert_eq!(c.noise_sigma, 0.05);
        assert_eq!(c.selection_policy, SelectionPolicy::GreedyBest);
        assert_eq!(c.experiment_rule, ExperimentRule::TopMax);
        assert_eq!(c.knowledge, KnowledgeProfile::GenericOnly);
        assert_eq!(c.llm.temperature, 1.2);
        assert_eq!(c.llm.rubric_infidelity, 0.2);
    }

    #[test]
    fn toml_pipeline_knobs() {
        let c = RunConfig::from_toml(
            "[platform]\nparallelism = 4\npipeline = true\ninflight_per_lane = 2\n",
        )
        .unwrap();
        assert!(c.pipeline);
        assert_eq!(c.eval_parallelism, 4);
        assert_eq!(c.inflight_per_lane, 2);
        assert!(!RunConfig::default().pipeline, "lockstep is the default");
        assert_eq!(RunConfig::default().inflight_per_lane, 1);
        assert!(RunConfig::from_toml("[platform]\npipeline = maybe\n").is_err());
        assert!(RunConfig::from_toml("[platform]\ninflight_per_lane = 0\n").is_err());
    }

    #[test]
    fn toml_screen_knobs() {
        let c = RunConfig::from_toml(
            "[screen]\nenabled = true\nrung = 5\nkeep_fraction = 0.4\n",
        )
        .unwrap();
        assert!(c.screen_enabled);
        assert_eq!(c.screen_rung, 5);
        assert_eq!(c.screen_keep, 0.4);
        let d = RunConfig::default();
        assert!(!d.screen_enabled, "screening is opt-in");
        assert_eq!(d.screen_rung, 8);
        assert_eq!(d.screen_keep, 0.5);
        assert!(RunConfig::from_toml("[screen]\nenabled = maybe\n").is_err());
        assert!(RunConfig::from_toml("[screen]\nrung = 0\n").is_err());
        assert!(RunConfig::from_toml("[screen]\nkeep_fraction = 0.0\n").is_err());
        assert!(RunConfig::from_toml("[screen]\nkeep_fraction = 1.5\n").is_err());
        assert!(RunConfig::from_toml("[screen]\nkeep_fraction = nan\n").is_err());
    }

    #[test]
    fn toml_profile_knob() {
        let c = RunConfig::from_toml("[profile]\nguided = true\n").unwrap();
        assert!(c.profile_guided);
        assert!(
            !RunConfig::default().profile_guided,
            "profile guidance is opt-in"
        );
        assert!(RunConfig::from_toml("[profile]\nguided = maybe\n").is_err());
        assert!(RunConfig::from_toml("[profile]\nsteered = true\n").is_err());
    }

    #[test]
    fn builder_sets_profile_guided() {
        let c = RunConfig::default().with_profile_guided(true);
        assert!(c.profile_guided);
    }

    #[test]
    fn toml_federation_knobs() {
        let c = RunConfig::from_toml(
            "[federation]\ndir = \"fed/store\"\nwarm_start_k = 3\nread_only = true\n",
        )
        .unwrap();
        assert_eq!(c.federation_dir.as_deref(), Some("fed/store"));
        assert_eq!(c.federation_warm_start_k, 3);
        assert!(c.federation_read_only);
        let d = RunConfig::default();
        assert!(d.federation_dir.is_none(), "federation is opt-in");
        assert_eq!(d.federation_warm_start_k, 0);
        assert!(!d.federation_read_only);
        assert!(RunConfig::from_toml("[federation]\ndir = \"\"\n").is_err());
        assert!(RunConfig::from_toml("[federation]\nread_only = maybe\n").is_err());
        assert!(RunConfig::from_toml("[federation]\nshare = true\n").is_err());
    }

    #[test]
    fn config_json_carries_federation_only_when_on() {
        // off: no federation keys at all — checkpoints stay
        // byte-identical to pre-federation ones
        let off = RunConfig::default().to_json().to_string();
        assert!(!off.contains("federation"), "{off}");
        // on: all three knobs round-trip (resume must re-attach the
        // same archive)
        let mut c = RunConfig::default().with_federation("fed/x").with_warm_start_k(2);
        c.federation_read_only = true;
        let back =
            RunConfig::from_json(&crate::util::json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.federation_dir.as_deref(), Some("fed/x"));
        assert_eq!(back.federation_warm_start_k, 2);
        assert!(back.federation_read_only);
    }

    #[test]
    fn toml_lint_knobs() {
        let c = RunConfig::from_toml("[lint]\ngate = true\nguided = true\n").unwrap();
        assert!(c.lint_gate);
        assert!(c.lint_guided);
        let d = RunConfig::default();
        assert!(!d.lint_gate, "the lint gate is opt-in");
        assert!(!d.lint_guided, "lint guidance is opt-in");
        assert!(RunConfig::from_toml("[lint]\ngate = maybe\n").is_err());
        assert!(RunConfig::from_toml("[lint]\nstrict = true\n").is_err());
    }

    #[test]
    fn builders_set_lint() {
        let c = RunConfig::default().with_lint_gate(true).with_lint_guided(true);
        assert!(c.lint_gate);
        assert!(c.lint_guided);
    }

    #[test]
    fn config_json_carries_lint_only_when_on() {
        // off: no lint keys at all — checkpoints stay byte-identical
        // to pre-lint ones
        let off = RunConfig::default().to_json().to_string();
        assert!(!off.contains("lint"), "{off}");
        // on: both knobs round-trip
        let c = RunConfig::default().with_lint_gate(true).with_lint_guided(true);
        let back =
            RunConfig::from_json(&crate::util::json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert!(back.lint_gate);
        assert!(back.lint_guided);
    }

    #[test]
    fn toml_faults_knobs() {
        let c = RunConfig::from_toml(
            "[faults]\nenabled = true\ntransient = 0.1\nmax_retries = 5\n\
             quarantine_after = 2\nrecovery = false\nstraggler_factor = 6.0\n",
        )
        .unwrap();
        assert!(c.faults.enabled);
        assert_eq!(c.faults.transient, 0.1);
        assert_eq!(c.faults.max_retries, 5);
        assert_eq!(c.faults.quarantine_after, 2);
        assert!(!c.faults.recovery);
        assert_eq!(c.faults.straggler_factor, 6.0);
        assert!(!RunConfig::default().faults.enabled, "fault injection is opt-in");
        assert!(RunConfig::from_toml("[faults]\nenabled = maybe\n").is_err());
        assert!(RunConfig::from_toml("[faults]\ntransient = 1.5\n").is_err());
        assert!(RunConfig::from_toml("[faults]\nlane_death = -0.1\n").is_err());
        assert!(RunConfig::from_toml("[faults]\nquarantine_after = 0\n").is_err());
        assert!(RunConfig::from_toml("[faults]\nchaos = true\n").is_err());
    }

    #[test]
    fn builders_set_faults() {
        let c = RunConfig::default().with_faults(true);
        assert!(c.faults.enabled);
        let mut custom = crate::eval::FaultConfig::default();
        custom.enabled = true;
        custom.max_retries = 9;
        let c = RunConfig::default().with_fault_config(custom);
        assert_eq!(c.faults.max_retries, 9);
    }

    #[test]
    fn config_json_carries_faults_only_when_on() {
        // off: no faults object at all — checkpoints stay
        // byte-identical to pre-faults ones
        let off = RunConfig::default().to_json().to_string();
        assert!(!off.contains("faults"), "{off}");
        // on: the whole model round-trips (a resumed chaos run must
        // replay the same rates)
        let mut c = RunConfig::default().with_faults(true);
        c.faults.transient = 0.2;
        c.faults.max_retries = 7;
        c.faults.recovery = false;
        let back =
            RunConfig::from_json(&crate::util::json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert!(back.faults.enabled);
        assert_eq!(back.faults.transient, 0.2);
        assert_eq!(back.faults.max_retries, 7);
        assert!(!back.faults.recovery);
    }

    #[test]
    fn builder_sets_screen() {
        let c = RunConfig::default().with_screen(6, 0.25);
        assert!(c.screen_enabled);
        assert_eq!(c.screen_rung, 6);
        assert_eq!(c.screen_keep, 0.25);
    }

    #[test]
    fn builders_set_pipeline_and_parallelism() {
        let c = RunConfig::default().with_parallelism(4).with_pipeline(true);
        assert_eq!(c.eval_parallelism, 4);
        assert!(c.pipeline);
    }

    #[test]
    fn toml_partial_keeps_defaults() {
        let c = RunConfig::from_toml("[run]\nseed = 3\n").unwrap();
        assert_eq!(c.seed, 3);
        assert_eq!(c.max_submissions, RunConfig::default().max_submissions);
    }

    #[test]
    fn toml_workload_key() {
        let c = RunConfig::from_toml("[run]\nworkload = \"row-softmax\"\n").unwrap();
        assert_eq!(c.workload, "row-softmax");
        let c = RunConfig::from_toml("workload = \"bf16-gemm\"\n").unwrap();
        assert_eq!(c.workload, "bf16-gemm");
        // unknown workloads fail fast with the registry listing
        let err = RunConfig::from_toml("[run]\nworkload = \"tf32-gemm\"\n").unwrap_err();
        assert!(err.contains("unknown workload"), "{err}");
        assert!(err.contains("fp8-gemm"), "{err}");
    }

    #[test]
    fn builder_sets_workload() {
        let c = RunConfig::default().with_workload("row-softmax");
        assert_eq!(c.workload, "row-softmax");
    }

    #[test]
    fn toml_store_section() {
        let c = RunConfig::from_toml(
            "[store]\ndir = \"runs/a\"\ncheckpoint_every = 5\n",
        )
        .unwrap();
        assert_eq!(c.store_dir.as_deref(), Some("runs/a"));
        assert_eq!(c.checkpoint_every, 5);
        let d = RunConfig::default();
        assert!(d.store_dir.is_none(), "persistence is opt-in");
        assert_eq!(d.checkpoint_every, 1);
        assert!(d.halt_after.is_none());
        assert!(RunConfig::from_toml("[store]\ncheckpoint_every = 0\n").is_err());
        assert!(RunConfig::from_toml("[store]\ndir = \"\"\n").is_err());
    }

    #[test]
    fn config_json_roundtrip_preserves_every_persistent_knob() {
        let mut c = RunConfig::from_toml(
            r#"
[run]
workload = "row-softmax"
seed = 11
max_submissions = 77
[platform]
reps_per_config = 2
parallelism = 3
pipeline = true
inflight_per_lane = 2
noise_sigma = 0.035
cache = false
[screen]
enabled = true
rung = 6
keep_fraction = 0.3
[agents]
selection_policy = "greedy"
experiment_rule = "random3"
knowledge = "minimal"
[llm]
temperature = 1.25
estimate_sigma = 0.4
rubric_infidelity = 0.11
[store]
dir = "runs/x"
checkpoint_every = 3
[profile]
guided = true
"#,
        )
        .unwrap();
        c.include_mfma_seed = false;
        let s = c.to_json().to_string();
        let back =
            RunConfig::from_json(&crate::util::json::parse(&s).unwrap()).unwrap();
        assert_eq!(back.workload, c.workload);
        assert_eq!(back.seed, c.seed);
        assert_eq!(back.max_submissions, c.max_submissions);
        assert_eq!(back.reps_per_config, c.reps_per_config);
        assert_eq!(back.eval_parallelism, c.eval_parallelism);
        assert_eq!(back.eval_cache, c.eval_cache);
        assert_eq!(back.pipeline, c.pipeline);
        assert_eq!(back.inflight_per_lane, c.inflight_per_lane);
        assert_eq!(back.noise_sigma, c.noise_sigma);
        assert_eq!(back.screen_enabled, c.screen_enabled);
        assert_eq!(back.screen_rung, c.screen_rung);
        assert_eq!(back.screen_keep, c.screen_keep);
        assert_eq!(back.selection_policy, c.selection_policy);
        assert_eq!(back.experiment_rule, c.experiment_rule);
        assert_eq!(back.knowledge, c.knowledge);
        assert_eq!(back.llm.temperature, c.llm.temperature);
        assert_eq!(back.llm.estimate_sigma, c.llm.estimate_sigma);
        assert_eq!(back.llm.rubric_infidelity, c.llm.rubric_infidelity);
        assert_eq!(back.bootstrap_probing, c.bootstrap_probing);
        assert_eq!(back.include_mfma_seed, c.include_mfma_seed);
        assert_eq!(back.checkpoint_every, c.checkpoint_every);
        assert_eq!(back.profile_guided, c.profile_guided);
        // runtime-local knobs are deliberately not persisted
        assert!(back.store_dir.is_none());
        assert!(back.halt_after.is_none());
    }

    #[test]
    fn config_json_seed_is_full_width() {
        // the seed derives every RNG stream: a value past 2^53 must
        // round-trip exactly (hex encoding), never via f64
        let c = RunConfig::default().with_seed(u64::MAX - 12345);
        let back =
            RunConfig::from_json(&crate::util::json::parse(&c.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.seed, u64::MAX - 12345);
    }

    #[test]
    fn config_from_json_rejects_out_of_u32_range() {
        // same rule as genome::persist — a corrupted checkpoint must
        // not narrow into a valid-looking config
        let mut j = RunConfig::default().to_json();
        if let crate::util::json::Json::Obj(ref mut m) = j {
            m.insert(
                "parallelism".into(),
                crate::util::json::Json::Num(4294967297.0),
            );
        }
        let err = RunConfig::from_json(&j).unwrap_err();
        assert!(err.contains("out of u32 range"), "{err}");
    }

    #[test]
    fn toml_unknown_key_rejected() {
        assert!(RunConfig::from_toml("[run]\nspeed = 3\n").is_err());
        assert!(RunConfig::from_toml("[warp]\nseed = 3\n").is_err());
    }

    #[test]
    fn toml_bad_values_rejected() {
        assert!(RunConfig::from_toml("[run]\nseed = fast\n").is_err());
        assert!(RunConfig::from_toml("[agents]\nknowledge = \"psychic\"\n").is_err());
    }

    #[test]
    fn builders() {
        let c = RunConfig::default().with_seed(9).with_budget(10);
        assert_eq!(c.seed, 9);
        assert_eq!(c.max_submissions, 10);
    }
}
