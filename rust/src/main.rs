//! `kernel-scientist` — the leader entrypoint.
//!
//! Subcommands:
//!   run         run the scientist loop on the simulated MI300 platform
//!   campaign    run several workloads' loops concurrently
//!   resume      continue a crashed/halted run (or campaign) from its
//!               `--store` directory, bit-identically (DESIGN.md §9)
//!   replay      re-render a persisted run's transcripts/curve from its
//!               journal without evaluating anything
//!   workloads   list the workload registry
//!   table1      regenerate the paper's Table 1 comparison
//!   leaderboard score the canonical genomes on the 18-size suite
//!   baseline    run a baseline tuner (random | hillclimb | anneal)
//!   inspect     print a genome's HIP-like sketch + simulator breakdown
//!   lint        run the static diagnostic engine (DESIGN.md §13) over
//!               a genome JSON file (`--genome`), a persisted run's
//!               ledger (`--store`), or a workload's seed kernels
//!   eval-pjrt   check + time the compiled artifact catalog over PJRT
//!   compact     rewrite JSONL journals (run, campaign, or federated
//!               store) into indexed binary segments (DESIGN.md §12)
//!
//! `run`, `campaign`, `baseline`, and `inspect` accept `--workload
//! <name>` (any registry key from `workloads`); the default is the
//! paper's fp8 GEMM. `run` and `campaign` also accept
//! `--parallelism <lanes>` (overrides `platform.parallelism`),
//! `--pipeline true|false` (the steady-state scheduler, DESIGN.md §8),
//! `--profile-guided true|false` (bottleneck-conditioned experiment
//! design, DESIGN.md §11), `--store <dir>` (the durable run ledger,
//! `[store] dir`), and
//! `--halt-after <N>` (testing: simulate a crash after N submissions),
//! the federated-archive knobs `--federation-dir <dir>`,
//! `--warm-start-k <N>`, `--federation-read-only true|false`
//! (`[federation]`, DESIGN.md §12), the lint knobs
//! `--lint-gate true|false` / `--lint-guided true|false` (`[lint]`,
//! DESIGN.md §13), and the fault-injection knobs
//! `--faults true|false` / `--fault-recovery true|false` (`[faults]`,
//! DESIGN.md §14 — `--faults true` enables the deterministic chaos
//! model at its default rates);
//! like `--workload`, the flags win over the config file.
//!
//! Arguments use `--key value` pairs (offline build: no clap; parsing
//! is in-tree).

use std::collections::HashMap;
use std::path::Path;

use gpu_kernel_scientist::baselines::{Annealer, HillClimber, RandomSearch, Tuner};
use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
use gpu_kernel_scientist::genome::seeds;
use gpu_kernel_scientist::gpu::MI300;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::report;
use gpu_kernel_scientist::runtime::PjrtBackend;
use gpu_kernel_scientist::sim::calibration;
use gpu_kernel_scientist::genome::render;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let value = args.get(i + 1).cloned().unwrap_or_default();
            map.insert(key.to_string(), value);
            i += 2;
        } else {
            i += 1;
        }
    }
    map
}

fn load_config(flags: &HashMap<String, String>) -> Result<RunConfig, String> {
    let mut cfg = match flags.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            RunConfig::from_toml(&text)?
        }
        None => RunConfig::default(),
    };
    if let Some(seed) = flags.get("seed") {
        cfg.seed = seed.parse().map_err(|_| "bad --seed")?;
    }
    if let Some(budget) = flags.get("budget") {
        cfg.max_submissions = budget.parse().map_err(|_| "bad --budget")?;
    }
    if let Some(workload) = flags.get("workload") {
        if gpu_kernel_scientist::workload::lookup(workload).is_none() {
            return Err(format!(
                "unknown --workload '{workload}' (see the `workloads` subcommand)"
            ));
        }
        cfg.workload = workload.clone();
    }
    // like --workload, the CLI flags win over the config file
    if let Some(lanes) = flags.get("parallelism") {
        cfg.eval_parallelism = lanes
            .parse::<u32>()
            .ok()
            .filter(|&p| p >= 1)
            .ok_or("bad --parallelism (want an integer >= 1)")?;
    }
    if let Some(pipeline) = flags.get("pipeline") {
        cfg.pipeline = match pipeline.as_str() {
            // a bare trailing `--pipeline` parses as an empty value
            "true" | "" => true,
            "false" => false,
            other => return Err(format!("bad --pipeline '{other}' (want true|false)")),
        };
    }
    if let Some(dir) = flags.get("store") {
        if dir.is_empty() {
            return Err("bad --store (want a directory)".into());
        }
        cfg.store_dir = Some(dir.clone());
    }
    if let Some(halt) = flags.get("halt-after") {
        cfg.halt_after = Some(
            halt.parse()
                .map_err(|_| "bad --halt-after (want a submission count)")?,
        );
    }
    if let Some(guided) = flags.get("profile-guided") {
        cfg.profile_guided = match guided.as_str() {
            // a bare trailing `--profile-guided` parses as an empty value
            "true" | "" => true,
            "false" => false,
            other => {
                return Err(format!("bad --profile-guided '{other}' (want true|false)"))
            }
        };
    }
    if let Some(dir) = flags.get("federation-dir") {
        if dir.is_empty() {
            return Err("bad --federation-dir (want a directory)".into());
        }
        cfg.federation_dir = Some(dir.clone());
    }
    if let Some(k) = flags.get("warm-start-k") {
        cfg.federation_warm_start_k = k
            .parse()
            .map_err(|_| "bad --warm-start-k (want an elite count)")?;
    }
    if let Some(ro) = flags.get("federation-read-only") {
        cfg.federation_read_only = match ro.as_str() {
            // a bare trailing `--federation-read-only` parses as empty
            "true" | "" => true,
            "false" => false,
            other => {
                return Err(format!(
                    "bad --federation-read-only '{other}' (want true|false)"
                ))
            }
        };
    }
    if let Some(gate) = flags.get("lint-gate") {
        cfg.lint_gate = match gate.as_str() {
            // a bare trailing `--lint-gate` parses as an empty value
            "true" | "" => true,
            "false" => false,
            other => return Err(format!("bad --lint-gate '{other}' (want true|false)")),
        };
    }
    if let Some(guided) = flags.get("lint-guided") {
        cfg.lint_guided = match guided.as_str() {
            // a bare trailing `--lint-guided` parses as an empty value
            "true" | "" => true,
            "false" => false,
            other => return Err(format!("bad --lint-guided '{other}' (want true|false)")),
        };
    }
    if let Some(faults) = flags.get("faults") {
        cfg.faults.enabled = match faults.as_str() {
            // a bare trailing `--faults` parses as an empty value
            "true" | "" => true,
            "false" => false,
            other => return Err(format!("bad --faults '{other}' (want true|false)")),
        };
    }
    if let Some(recovery) = flags.get("fault-recovery") {
        cfg.faults.recovery = match recovery.as_str() {
            // a bare trailing `--fault-recovery` parses as an empty value
            "true" | "" => true,
            "false" => false,
            other => {
                return Err(format!("bad --fault-recovery '{other}' (want true|false)"))
            }
        };
    }
    Ok(cfg)
}

fn print_run_header(cfg: &RunConfig) {
    println!(
        "scientist run: workload={} seed={} budget={} lanes={} scheduler={} backend=mi300-sim",
        cfg.workload,
        cfg.seed,
        cfg.max_submissions,
        cfg.eval_parallelism,
        if cfg.pipeline { "pipeline" } else { "lockstep" }
    );
}

fn print_run_report<B: gpu_kernel_scientist::eval::EvalBackend>(
    run: &gpu_kernel_scientist::scientist::ScientistRun<B>,
    outcome: &gpu_kernel_scientist::scientist::RunOutcome,
    flags: &HashMap<String, String>,
) -> Result<(), String> {
    for log in &run.logs {
        println!("{}", report::render_iteration(log));
    }
    println!(
        "\nbest kernel {}: feedback geomean {:.1} us (leaderboard {:.1} us) \
         after {} submissions ({:.0} simulated-minutes of platform time)",
        outcome.best_id,
        outcome.best_geomean_us,
        outcome.leaderboard_us.unwrap_or(f64::NAN),
        outcome.submissions,
        outcome.wall_clock_s / 60.0
    );
    println!("{}", report::render_pipeline(&outcome.pipeline));
    // empty unless `[profile] guided` produced a mix: an unguided run's
    // report stays byte-identical to pre-profile output
    let profiles = report::render_profiles(outcome.profile_mix.as_ref());
    if !profiles.is_empty() {
        print!("{profiles}");
    }
    // empty unless the federated archive contributed: an off run's
    // report stays byte-identical to pre-federation output
    let federation = report::render_federation(outcome.federation.as_ref());
    if !federation.is_empty() {
        print!("{federation}");
    }
    // empty unless `[faults]` injected something: a faults-off run's
    // report stays byte-identical to pre-faults output
    let faults = report::render_faults(outcome.faults.as_ref());
    if !faults.is_empty() {
        print!("{faults}");
    }
    println!("{}", report::render_convergence("scientist", &outcome.curve));
    if flags.contains_key("lineage") {
        println!("== lineage ==\n{}", report::lineage::render_tree(&run.population));
    }
    let d = report::lineage::diversity(&run.population);
    println!(
        "population diversity: {:.0}% unique, mean pairwise distance {:.1} axes, \
         {} axes explored, max lineage depth {}",
        d.unique_fraction * 100.0,
        d.mean_hamming,
        d.axes_explored,
        d.max_depth
    );
    if let Some(path) = flags.get("save-population") {
        run.population
            .save(Path::new(path))
            .map_err(|e| e.to_string())?;
        println!("population saved to {path}");
    }
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = load_config(flags)?;
    print_run_header(&cfg);
    let mut run = ScientistRun::new(cfg)?;
    let outcome = run.run_to_completion()?;
    if run.halted() {
        // only point at `resume` when something was actually persisted
        let hint = match &run.config.store_dir {
            Some(dir) => format!("; continue with `resume --store {dir}`"),
            None => "; nothing was persisted (no --store)".into(),
        };
        println!(
            "run halted after {} submissions (simulated crash — no final checkpoint){hint}",
            outcome.submissions
        );
        return Ok(());
    }
    print_run_report(&run, &outcome, flags)
}

fn cmd_resume(flags: &HashMap<String, String>) -> Result<(), String> {
    use gpu_kernel_scientist::scientist::campaign::resume_campaign;
    let dir = flags
        .get("store")
        .ok_or("resume requires --store <dir>")?;
    let halt_after = match flags.get("halt-after") {
        Some(halt) => Some(
            halt.parse::<u64>()
                .map_err(|_| "bad --halt-after (want a submission count)")?,
        ),
        None => None,
    };
    let path = Path::new(dir);
    if gpu_kernel_scientist::store::read_campaign_manifest(path)?.is_some() {
        println!("resuming campaign from {dir}");
        let outcome = resume_campaign(path, halt_after)?;
        println!("{}", report::render_campaign(&outcome));
        return Ok(());
    }
    let mut run = ScientistRun::resume(path)?;
    // --halt-after applies to the resumed leg too (halt_after is never
    // persisted): crash-recovery of a resumed run is itself testable
    run.config.halt_after = halt_after;
    // one provenance line, then output identical to an uninterrupted
    // `run` (the CI resume-equivalence smoke diffs the two)
    println!(
        "resumed from {dir}: {} ledger entries, {} submissions committed",
        run.population.len(),
        run.platform.submissions()
    );
    print_run_header(&run.config);
    let outcome = run.run_to_completion()?;
    if run.halted() {
        println!(
            "run halted again after {} submissions; continue with `resume --store {dir}`",
            outcome.submissions
        );
        return Ok(());
    }
    print_run_report(&run, &outcome, flags)
}

fn print_replay(dir: &Path, flags: &HashMap<String, String>) -> Result<(), String> {
    let r = gpu_kernel_scientist::store::replay(dir)?;
    println!(
        "replay of {}: workload={} seed={} | {} ledger entries over {} committed submissions{}",
        dir.display(),
        r.workload,
        r.config.seed,
        r.population.len(),
        r.submissions,
        if r.torn_tail {
            " (torn final journal line dropped)"
        } else {
            ""
        }
    );
    for log in &r.logs {
        println!("{}", report::render_iteration(log));
    }
    match r.population.best() {
        Some(best) => println!(
            "\nbest kernel {}: feedback geomean {:.1} us",
            best.id,
            best.score().unwrap_or(f64::NAN)
        ),
        None => println!("\nno successful kernel in the ledger"),
    }
    println!("{}", report::render_convergence("replay", &r.curve));
    if flags.contains_key("lineage") {
        println!("== lineage ==\n{}", report::lineage::render_tree(&r.population));
    }
    Ok(())
}

fn cmd_replay(flags: &HashMap<String, String>) -> Result<(), String> {
    let dir = flags
        .get("store")
        .ok_or("replay requires --store <dir>")?;
    let path = Path::new(dir);
    if let Some(workloads) = gpu_kernel_scientist::store::read_campaign_manifest(path)? {
        for w in &workloads {
            print_replay(&path.join(w), flags)?;
        }
        return Ok(());
    }
    print_replay(path, flags)
}

fn cmd_workloads() -> Result<(), String> {
    println!("registered workloads:");
    for w in gpu_kernel_scientist::workload::registry() {
        let fb = w.feedback_suite();
        let lb = w.leaderboard_suite();
        let seeds: Vec<&str> = w.starting_population().iter().map(|(n, _)| *n).collect();
        println!("  {:12} {}", w.name(), w.description());
        println!(
            "  {:12}   feedback {} configs | leaderboard {} | seeds: {}",
            "",
            fb.configs.len(),
            lb.configs.len(),
            seeds.join(", ")
        );
    }
    Ok(())
}

fn cmd_campaign(flags: &HashMap<String, String>) -> Result<(), String> {
    use gpu_kernel_scientist::scientist::campaign::{run_campaign, CampaignConfig};
    let base = load_config(flags)?;
    let config = match flags.get("workloads") {
        Some(list) => CampaignConfig {
            workloads: list.split(',').map(|s| s.trim().to_string()).collect(),
            base,
        },
        // a singular --workload means a one-entry campaign, not "all"
        None if flags.contains_key("workload") => CampaignConfig {
            workloads: vec![base.workload.clone()],
            base,
        },
        None => CampaignConfig::all_workloads(base),
    };
    println!(
        "campaign over {} workloads ({}), seed={} budget={} lanes={} scheduler={} per workload",
        config.workloads.len(),
        config.workloads.join(", "),
        config.base.seed,
        config.base.max_submissions,
        config.base.eval_parallelism,
        if config.base.pipeline { "pipeline" } else { "lockstep" }
    );
    let outcome = run_campaign(&config)?;
    println!("{}", report::render_campaign(&outcome));
    Ok(())
}

fn cmd_table1(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = load_config(flags)?;
    if cfg.workload != gpu_kernel_scientist::workload::DEFAULT_WORKLOAD {
        return Err(format!(
            "table1 reproduces the paper's fp8 competition table; '{}' has no Table-1 rows \
             (use `run --workload {}` instead)",
            cfg.workload, cfg.workload
        ));
    }
    let mut rows: Vec<report::TableRow> = calibration::table1_rows(&MI300)
        .into_iter()
        .filter(|(l, _, _)| !l.starts_with("This work"))
        .map(|(label, paper, sim)| report::TableRow {
            label: label.to_string(),
            paper_us: Some(paper),
            measured_us: sim,
            comment: "canonical genome on mi300-sim".into(),
        })
        .collect();
    println!("running the scientist loop for the 'This work' row...");
    let mut run = ScientistRun::new(cfg)?;
    let outcome = run.run_to_completion()?;
    rows.push(report::TableRow {
        label: "This work (scientist run)".into(),
        paper_us: Some(450.0),
        measured_us: outcome.leaderboard_us.unwrap_or(outcome.best_geomean_us),
        comment: format!("LLM-only, {} submissions", outcome.submissions),
    });
    println!(
        "{}",
        report::render_table("Table 1 — AMD Developer Challenge summary", &rows)
    );
    Ok(())
}

fn cmd_leaderboard() -> Result<(), String> {
    println!("18-size leaderboard geomeans (noiseless mi300-sim):");
    for (name, g) in seeds::all_seeds() {
        let score = calibration::leaderboard_geomean(&MI300, &g);
        println!("  {name:20} {score:10.1} us");
    }
    Ok(())
}

fn cmd_baseline(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = load_config(flags)?;
    let which = flags.get("tuner").map(String::as_str).unwrap_or("random");
    let workload = gpu_kernel_scientist::workload::lookup(&cfg.workload)
        .ok_or_else(|| format!("unknown workload '{}'", cfg.workload))?;
    // honor the config/flag platform knobs (--parallelism included);
    // quota stays None — the tuners enforce `budget` themselves
    let mut platform = EvalPlatform::new(
        SimBackend::new(cfg.seed)
            .with_noise(cfg.noise_sigma)
            .with_workload(workload.clone()),
        PlatformConfig {
            reps_per_config: cfg.reps_per_config,
            parallelism: cfg.eval_parallelism,
            submission_quota: None,
            cache_results: cfg.eval_cache,
        },
    )
    .with_feedback_suite(workload.feedback_suite());
    let outcome = match which {
        "random" => RandomSearch { seed: cfg.seed }.run(&mut platform, cfg.max_submissions),
        "hillclimb" => HillClimber {
            seed: cfg.seed,
            ..Default::default()
        }
        .run(&mut platform, cfg.max_submissions),
        "anneal" => Annealer {
            seed: cfg.seed,
            ..Default::default()
        }
        .run(&mut platform, cfg.max_submissions),
        other => return Err(format!("unknown --tuner '{other}'")),
    };
    println!(
        "{}: best {:.1} us in {} submissions",
        outcome.name, outcome.best_geomean_us, outcome.submissions
    );
    println!("{}", report::render_convergence(outcome.name, &outcome.curve));
    Ok(())
}

fn cmd_inspect(flags: &HashMap<String, String>) -> Result<(), String> {
    let workload_name = flags
        .get("workload")
        .map(String::as_str)
        .unwrap_or(gpu_kernel_scientist::workload::DEFAULT_WORKLOAD);
    let workload = gpu_kernel_scientist::workload::lookup(workload_name)
        .ok_or_else(|| format!("unknown --workload '{workload_name}'"))?;
    // the fp8 family also exposes the Table-1 comparison genomes
    let candidates: Vec<(&'static str, _)> =
        if workload_name == gpu_kernel_scientist::workload::DEFAULT_WORKLOAD {
            seeds::all_seeds()
        } else {
            workload.starting_population()
        };
    let default_kernel = if workload_name == gpu_kernel_scientist::workload::DEFAULT_WORKLOAD {
        "mfma-seed"
    } else {
        // each family lists its bootstrap fast-path seed last
        candidates.last().map(|(n, _)| *n).unwrap_or("mfma-seed")
    };
    let which = flags
        .get("seed-kernel")
        .map(String::as_str)
        .unwrap_or(default_kernel);
    let genome = candidates
        .into_iter()
        .find(|(n, _)| *n == which)
        .map(|(_, g)| g)
        .ok_or_else(|| format!("unknown seed kernel '{which}' for workload {workload_name}"))?;
    println!("{}", render::render_hip_sketch(&genome));
    println!("{workload_name} breakdown on the feedback configs:");
    let mut timings = Vec::new();
    for cfg in &workload.feedback_suite().configs {
        let t = workload
            .estimate(&MI300, &genome, cfg)
            .map_err(|e| e.to_string())?;
        println!(
            "  {cfg}: {:9.1} us (compute {:8.1}, mem {:8.1}, wb {:6.1}, eff {:.3})",
            t.total_us, t.compute_us, t.mem_us, t.writeback_us, t.compute_efficiency
        );
        timings.push(t);
    }
    let profile = gpu_kernel_scientist::sim::ProfileReport::from_timings(&timings);
    println!("profile: {}", profile.render());
    Ok(())
}

/// The `lint` subcommand (DESIGN.md §13): run the static diagnostic
/// engine over a genome JSON file, a persisted run's ledger, or —
/// absent both — the workload's seed kernels. Pure reporting: the
/// process exits 0 even when errors are found (the gate lives inside
/// the schedulers, not here).
fn cmd_lint(flags: &HashMap<String, String>) -> Result<(), String> {
    use gpu_kernel_scientist::analysis;
    let named_workload = |flags: &HashMap<String, String>| {
        let name = flags
            .get("workload")
            .map(String::as_str)
            .unwrap_or(gpu_kernel_scientist::workload::DEFAULT_WORKLOAD);
        gpu_kernel_scientist::workload::lookup(name)
            .ok_or_else(|| format!("unknown --workload '{name}'"))
    };
    match (flags.get("genome"), flags.get("store")) {
        (Some(_), Some(_)) => Err("lint takes --genome OR --store, not both".into()),
        (Some(path), None) => {
            let workload = named_workload(flags)?;
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let v = gpu_kernel_scientist::util::json::parse(&text)
                .map_err(|e| format!("{path}: {e}"))?;
            let genome = KernelGenome::from_json(&v)?;
            print!(
                "{}",
                report::render_lint(path, &analysis::lint(&genome, &MI300, workload.as_ref()))
            );
            Ok(())
        }
        (None, Some(dir)) => {
            // every distinct ledger genome, against the run's own
            // workload (persisted in its checkpoint — --workload is
            // ignored here)
            let r = gpu_kernel_scientist::store::replay(Path::new(dir))?;
            let workload = gpu_kernel_scientist::workload::lookup(&r.workload)
                .ok_or_else(|| format!("unknown workload '{}' in store", r.workload))?;
            let mut seen = std::collections::HashSet::new();
            let mut with_errors = 0usize;
            for m in r.population.members() {
                if !seen.insert(m.genome.fingerprint_hash()) {
                    continue;
                }
                let diags = analysis::lint(&m.genome, &MI300, workload.as_ref());
                if analysis::has_error(&diags) {
                    with_errors += 1;
                }
                print!("{}", report::render_lint(&m.id, &diags));
            }
            println!(
                "{dir}: {} distinct genome(s), {with_errors} with error(s)",
                seen.len()
            );
            Ok(())
        }
        (None, None) => {
            let workload = named_workload(flags)?;
            for (name, genome) in workload.starting_population() {
                print!(
                    "{}",
                    report::render_lint(name, &analysis::lint(&genome, &MI300, workload.as_ref()))
                );
            }
            Ok(())
        }
    }
}

fn cmd_compact(flags: &HashMap<String, String>) -> Result<(), String> {
    use gpu_kernel_scientist::store;
    match (flags.get("store"), flags.get("federation-dir")) {
        (Some(dir), None) => {
            let path = Path::new(dir);
            // campaign stores compact every member ledger
            if let Some(workloads) = store::read_campaign_manifest(path)? {
                for w in &workloads {
                    let member = path.join(w);
                    let did = store::compact_run_store(&member)?;
                    println!(
                        "{}: {}",
                        member.display(),
                        if did { "compacted" } else { "already segment-only" }
                    );
                }
                return Ok(());
            }
            let did = store::compact_run_store(path)?;
            println!(
                "{dir}: {}",
                if did { "compacted" } else { "already segment-only" }
            );
            Ok(())
        }
        (None, Some(dir)) => {
            let n = store::federation::compact_dir(Path::new(dir))?;
            println!("{dir}: {n} federation file(s) compacted");
            Ok(())
        }
        (Some(_), Some(_)) => Err("compact takes --store OR --federation-dir, not both".into()),
        (None, None) => Err("compact requires --store <dir> or --federation-dir <dir>".into()),
    }
}

fn cmd_eval_pjrt(flags: &HashMap<String, String>) -> Result<(), String> {
    let dir = flags
        .get("artifacts")
        .map(String::as_str)
        .unwrap_or("artifacts");
    let mut backend = PjrtBackend::open(Path::new(dir)).map_err(|e| e.to_string())?;
    let shapes = backend.shapes();
    println!(
        "catalog: {} entries over {} shapes",
        backend.catalog().entries.len(),
        shapes.len()
    );
    for cfg in &shapes {
        let names: Vec<String> = backend
            .catalog()
            .variants_for(cfg)
            .iter()
            .map(|e| e.name.clone())
            .collect();
        for name in names {
            match backend.verify(&name, cfg) {
                Ok(()) => {
                    let us = backend.time_entry(&name, cfg).map_err(|e| e.to_string())?;
                    println!("  {name:45} OK   {us:10.1} us");
                }
                Err(e) => println!("  {name:45} FAIL {e}"),
            }
        }
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    let result = match cmd {
        "run" => cmd_run(&flags),
        "campaign" => cmd_campaign(&flags),
        "resume" => cmd_resume(&flags),
        "replay" => cmd_replay(&flags),
        "workloads" => cmd_workloads(),
        "table1" => cmd_table1(&flags),
        "leaderboard" => cmd_leaderboard(),
        "baseline" => cmd_baseline(&flags),
        "inspect" => cmd_inspect(&flags),
        "lint" => cmd_lint(&flags),
        "eval-pjrt" => cmd_eval_pjrt(&flags),
        "compact" => cmd_compact(&flags),
        _ => {
            eprintln!(
                "usage: kernel-scientist <run|campaign|resume|replay|workloads|table1|leaderboard|baseline|inspect|lint|eval-pjrt|compact> \
                 [--workload name] [--workloads a,b,c] [--lineage true] \
                 [--seed N] [--budget N] [--parallelism N] [--pipeline true|false] \
                 [--profile-guided true|false] [--store dir] [--halt-after N] \
                 [--federation-dir dir] [--warm-start-k N] [--federation-read-only true|false] \
                 [--lint-gate true|false] [--lint-guided true|false] \
                 [--faults true|false] [--fault-recovery true|false] [--genome file.json] \
                 [--config file.toml] [--tuner random|hillclimb|anneal] \
                 [--seed-kernel name] [--artifacts dir] [--save-population file.jsonl]"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
