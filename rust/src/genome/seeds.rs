//! Canonical seed genomes — the paper's §3 starting population plus
//! the two comparison rows of Table 1.
//!
//! The paper seeds its loop with: (1) the provided PyTorch
//! implementation, (2) a direct HIP translation (~6x slower than
//! PyTorch), and (3) a Matrix-Core HIP kernel co-created with the LLM
//! during the bootstrap "findings" phase. The Table-1 comparison also
//! needs the human-expert 1st-place kernel as an oracle bound.

use super::*;

/// The provided PyTorch baseline: a library fp16 GEMM. Not a HIP
/// kernel at all — the simulator times it through a library-efficiency
/// model — but it participates in the population as an individual the
/// selector can see (the paper lists it as a seed).
pub fn pytorch_reference() -> KernelGenome {
    KernelGenome {
        block_m: 128,
        block_n: 128,
        block_k: 32,
        compute: ComputePath::Vectorized,
        precision: Precision::Fp16,
        unroll_k: 4,
        lds_staging: true,
        double_buffer: true,
        lds_pad: 0,
        swizzle: Swizzle::Xor,
        vector_width: 16,
        waves_per_block: 4,
        writeback: Writeback::Cooperative,
        scale_cache: ScaleCache::Lds,
        grid_mapping: GridMapping::RowMajor,
        acc_in_regs: true,
        k_innermost: true,
        isa_scheduling: false,
    }
}

/// Direct line-by-line HIP translation of the PyTorch code: scalar f32
/// math, one wave, no LDS staging, element-wise global loads. The
/// paper reports it ~6x slower than the PyTorch library call.
pub fn naive_hip() -> KernelGenome {
    KernelGenome {
        block_m: 16,
        block_n: 16,
        block_k: 16,
        compute: ComputePath::Scalar,
        precision: Precision::Fp32,
        unroll_k: 1,
        lds_staging: false,
        double_buffer: false,
        lds_pad: 0,
        swizzle: Swizzle::None,
        vector_width: 4,
        waves_per_block: 1,
        writeback: Writeback::SingleWave,
        scale_cache: ScaleCache::GlobalReload,
        grid_mapping: GridMapping::RowMajor,
        acc_in_regs: true,
        k_innermost: true,
        isa_scheduling: false,
    }
}

/// The first working Matrix-Core kernel from the bootstrap deep-dive:
/// fp8 MFMA with small tiles, single buffering, single-wave writeback.
/// Functional but far from tuned — the evolutionary loop's real
/// starting point for the fast path.
pub fn mfma_seed() -> KernelGenome {
    KernelGenome {
        block_m: 32,
        block_n: 32,
        block_k: 16,
        compute: ComputePath::Mfma,
        precision: Precision::Fp8,
        unroll_k: 1,
        lds_staging: true,
        double_buffer: false,
        lds_pad: 0,
        swizzle: Swizzle::None,
        vector_width: 4,
        waves_per_block: 2,
        writeback: Writeback::SingleWave,
        scale_cache: ScaleCache::GlobalReload,
        grid_mapping: GridMapping::RowMajor,
        acc_in_regs: true,
        k_innermost: true,
        isa_scheduling: false,
    }
}

/// Oracle bound: the human 1st-place kernel (105 us geomean, built
/// *with* MI300 access). Every App.-A.3 feature enabled with tuned
/// tiles. The scientist never sees this genome; it exists for the
/// Table-1 row and as the target of the exhaustive baseline search.
pub fn human_oracle() -> KernelGenome {
    KernelGenome {
        block_m: 128,
        block_n: 128,
        block_k: 64,
        compute: ComputePath::Mfma,
        precision: Precision::Fp8,
        unroll_k: 4,
        lds_staging: true,
        double_buffer: true,
        lds_pad: 0,
        swizzle: Swizzle::Xor,
        vector_width: 16,
        waves_per_block: 8,
        writeback: Writeback::Cooperative,
        scale_cache: ScaleCache::LdsRepurposed,
        grid_mapping: GridMapping::RowMajor,
        acc_in_regs: true,
        k_innermost: true,
        isa_scheduling: true,
    }
}

/// A representative genome of what the paper's LLM-only loop reached
/// (~450 us): strong MFMA kernel, most A.3 features, but not the
/// oracle's tuned tile/occupancy sweet spot. Used by calibration tests
/// (the scientist should *find* something comparable, not be given it).
pub fn paper_evolved() -> KernelGenome {
    KernelGenome {
        block_m: 64,
        block_n: 64,
        block_k: 16,
        compute: ComputePath::Mfma,
        precision: Precision::Fp8,
        unroll_k: 1,
        lds_staging: true,
        double_buffer: false,
        lds_pad: 0,
        swizzle: Swizzle::None,
        vector_width: 4,
        waves_per_block: 2,
        writeback: Writeback::SingleWave,
        scale_cache: ScaleCache::Lds,
        grid_mapping: GridMapping::RowMajor,
        acc_in_regs: true,
        k_innermost: true,
        isa_scheduling: false,
    }
}

/// The seeds the scientist run starts from, in paper order.
pub fn starting_population() -> Vec<(&'static str, KernelGenome)> {
    vec![
        ("pytorch-reference", pytorch_reference()),
        ("naive-hip", naive_hip()),
        ("mfma-seed", mfma_seed()),
    ]
}

/// Every canonical genome (for tests / calibration).
pub fn all_seeds() -> Vec<(&'static str, KernelGenome)> {
    vec![
        ("pytorch-reference", pytorch_reference()),
        ("naive-hip", naive_hip()),
        ("mfma-seed", mfma_seed()),
        ("human-oracle", human_oracle()),
        ("paper-evolved", paper_evolved()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starting_population_is_three_seeds() {
        let seeds = starting_population();
        assert_eq!(seeds.len(), 3);
        assert_eq!(seeds[0].0, "pytorch-reference");
    }

    #[test]
    fn oracle_uses_every_a3_feature() {
        let g = human_oracle();
        assert_eq!(g.compute, ComputePath::Mfma);
        assert_eq!(g.precision, Precision::Fp8);
        assert!(g.lds_staging && g.double_buffer);
        assert_eq!(g.scale_cache, ScaleCache::LdsRepurposed);
        assert!(g.waves_per_block > 1);
        assert!(g.acc_in_regs);
    }

    #[test]
    fn naive_uses_none() {
        let g = naive_hip();
        assert_eq!(g.compute, ComputePath::Scalar);
        assert_eq!(g.precision, Precision::Fp32);
        assert!(!g.lds_staging && !g.double_buffer);
    }

    #[test]
    fn seeds_have_distinct_fingerprints() {
        let fps: Vec<String> = all_seeds().iter().map(|(_, g)| g.fingerprint()).collect();
        let mut dedup = fps.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(fps.len(), dedup.len());
    }
}
