//! JSON (de)serialization for genomes — used by population persistence
//! and by the PJRT artifact catalog (whose `variant` objects are the
//! python `GemmVariant` projection of these genomes).

use super::*;
use crate::util::json::Json;

fn enum_str<T: std::fmt::Debug>(v: &T) -> Json {
    Json::Str(format!("{v:?}"))
}

impl KernelGenome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("block_m", Json::Num(self.block_m as f64)),
            ("block_n", Json::Num(self.block_n as f64)),
            ("block_k", Json::Num(self.block_k as f64)),
            ("compute", enum_str(&self.compute)),
            ("precision", enum_str(&self.precision)),
            ("unroll_k", Json::Num(self.unroll_k as f64)),
            ("lds_staging", Json::Bool(self.lds_staging)),
            ("double_buffer", Json::Bool(self.double_buffer)),
            ("lds_pad", Json::Num(self.lds_pad as f64)),
            ("swizzle", enum_str(&self.swizzle)),
            ("vector_width", Json::Num(self.vector_width as f64)),
            ("waves_per_block", Json::Num(self.waves_per_block as f64)),
            ("writeback", enum_str(&self.writeback)),
            ("scale_cache", enum_str(&self.scale_cache)),
            ("grid_mapping", enum_str(&self.grid_mapping)),
            ("acc_in_regs", Json::Bool(self.acc_in_regs)),
            ("k_innermost", Json::Bool(self.k_innermost)),
            ("isa_scheduling", Json::Bool(self.isa_scheduling)),
        ])
    }

    /// Stream the [`Self::to_json`] object into `out`, byte-identical
    /// to `self.to_json().to_string()` (keys in the emitter's sorted
    /// order) but with no intermediate tree — the run-store journal's
    /// per-entry hot path (§Perf). Enum variants are plain ASCII
    /// identifiers, so their `Debug` names need no escaping.
    pub fn write_json(&self, out: &mut String) {
        use std::fmt::Write as _;
        fn num(out: &mut String, key: &str, v: u32) {
            use std::fmt::Write as _;
            let _ = write!(out, "\"{key}\":{v},");
        }
        fn boolean(out: &mut String, key: &str, v: bool) {
            use std::fmt::Write as _;
            let _ = write!(out, "\"{key}\":{v},");
        }
        fn variant<T: std::fmt::Debug>(out: &mut String, key: &str, v: &T) {
            use std::fmt::Write as _;
            let _ = write!(out, "\"{key}\":\"{v:?}\",");
        }
        out.push('{');
        boolean(out, "acc_in_regs", self.acc_in_regs);
        num(out, "block_k", self.block_k);
        num(out, "block_m", self.block_m);
        num(out, "block_n", self.block_n);
        variant(out, "compute", &self.compute);
        boolean(out, "double_buffer", self.double_buffer);
        variant(out, "grid_mapping", &self.grid_mapping);
        boolean(out, "isa_scheduling", self.isa_scheduling);
        boolean(out, "k_innermost", self.k_innermost);
        num(out, "lds_pad", self.lds_pad);
        boolean(out, "lds_staging", self.lds_staging);
        variant(out, "precision", &self.precision);
        variant(out, "scale_cache", &self.scale_cache);
        variant(out, "swizzle", &self.swizzle);
        num(out, "unroll_k", self.unroll_k);
        num(out, "vector_width", self.vector_width);
        num(out, "waves_per_block", self.waves_per_block);
        let _ = write!(out, "\"writeback\":\"{:?}\"", self.writeback);
        out.push('}');
    }

    pub fn from_json(v: &Json) -> Result<KernelGenome, String> {
        let u32_field = |k: &str| -> Result<u32, String> {
            let raw = v
                .get(k)
                .and_then(|x| x.as_u64())
                .ok_or_else(|| format!("missing/invalid field {k}"))?;
            // a hand-edited/corrupted ledger must not narrow into a
            // valid-looking genome: out-of-range values are errors
            u32::try_from(raw).map_err(|_| format!("field {k} out of u32 range: {raw}"))
        };
        let bool_field = |k: &str| -> Result<bool, String> {
            v.get(k)
                .and_then(|x| x.as_bool())
                .ok_or_else(|| format!("missing/invalid field {k}"))
        };
        let str_field = |k: &str| -> Result<&str, String> {
            v.get(k)
                .and_then(|x| x.as_str())
                .ok_or_else(|| format!("missing/invalid field {k}"))
        };
        Ok(KernelGenome {
            block_m: u32_field("block_m")?,
            block_n: u32_field("block_n")?,
            block_k: u32_field("block_k")?,
            compute: match str_field("compute")? {
                "Scalar" => ComputePath::Scalar,
                "Vectorized" => ComputePath::Vectorized,
                "Mfma" => ComputePath::Mfma,
                other => return Err(format!("bad compute '{other}'")),
            },
            precision: match str_field("precision")? {
                "Fp32" => Precision::Fp32,
                "Fp16" => Precision::Fp16,
                "Fp8" => Precision::Fp8,
                other => return Err(format!("bad precision '{other}'")),
            },
            unroll_k: u32_field("unroll_k")?,
            lds_staging: bool_field("lds_staging")?,
            double_buffer: bool_field("double_buffer")?,
            lds_pad: u32_field("lds_pad")?,
            swizzle: match str_field("swizzle")? {
                "None" => Swizzle::None,
                "Xor" => Swizzle::Xor,
                other => return Err(format!("bad swizzle '{other}'")),
            },
            vector_width: u32_field("vector_width")?,
            waves_per_block: u32_field("waves_per_block")?,
            writeback: match str_field("writeback")? {
                "SingleWave" => Writeback::SingleWave,
                "Cooperative" => Writeback::Cooperative,
                other => return Err(format!("bad writeback '{other}'")),
            },
            scale_cache: match str_field("scale_cache")? {
                "GlobalReload" => ScaleCache::GlobalReload,
                "Lds" => ScaleCache::Lds,
                "LdsRepurposed" => ScaleCache::LdsRepurposed,
                other => return Err(format!("bad scale_cache '{other}'")),
            },
            grid_mapping: match str_field("grid_mapping")? {
                "RowMajor" => GridMapping::RowMajor,
                "ColMajor" => GridMapping::ColMajor,
                "TileSwizzled" => GridMapping::TileSwizzled,
                other => return Err(format!("bad grid_mapping '{other}'")),
            },
            acc_in_regs: bool_field("acc_in_regs")?,
            k_innermost: bool_field("k_innermost")?,
            // absent in older ledgers: default false (LLM-reachable space)
            isa_scheduling: v
                .get("isa_scheduling")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn json_roundtrip_all_seeds() {
        for (name, g) in seeds::all_seeds() {
            let s = g.to_json().to_string();
            let back = KernelGenome::from_json(&json::parse(&s).unwrap()).unwrap();
            assert_eq!(g, back, "{name}");
        }
    }

    #[test]
    fn streamed_json_matches_tree_emitter() {
        use crate::rng::Rng;
        use crate::test_support::random_genome;
        let mut rng = Rng::seed_from_u64(42);
        for _ in 0..50 {
            let g = random_genome(&mut rng);
            let mut streamed = String::new();
            g.write_json(&mut streamed);
            assert_eq!(streamed, g.to_json().to_string(), "{g:?}");
        }
        for (name, g) in seeds::all_seeds() {
            let mut streamed = String::new();
            g.write_json(&mut streamed);
            assert_eq!(streamed, g.to_json().to_string(), "{name}");
        }
    }

    #[test]
    fn from_json_rejects_missing_field() {
        let v = json::parse(r#"{"block_m": 32}"#).unwrap();
        assert!(KernelGenome::from_json(&v).is_err());
    }

    #[test]
    fn from_json_rejects_out_of_range_u32() {
        // 2^32 used to truncate to block_m = 0 via `as u32`; now it is
        // a hard error (the ledger makes corrupted JSON a real input)
        let mut j = seeds::naive_hip().to_json();
        if let Json::Obj(ref mut m) = j {
            m.insert("block_m".into(), Json::Num(4294967296.0));
        }
        let err = KernelGenome::from_json(&j).unwrap_err();
        assert!(err.contains("out of u32 range"), "{err}");
        // u32::MAX itself still round-trips (range check, not a clamp)
        let mut j = seeds::naive_hip().to_json();
        if let Json::Obj(ref mut m) = j {
            m.insert("lds_pad".into(), Json::Num(4294967295.0));
        }
        assert_eq!(
            KernelGenome::from_json(&j).unwrap().lds_pad,
            u32::MAX
        );
    }

    #[test]
    fn from_json_rejects_bad_enum() {
        let mut j = seeds::naive_hip().to_json();
        if let Json::Obj(ref mut m) = j {
            m.insert("compute".into(), Json::Str("Quantum".into()));
        }
        let err = KernelGenome::from_json(&j).unwrap_err();
        assert!(err.contains("Quantum"));
    }
}
