//! Render a genome as a HIP-like kernel sketch — "the code listing".
//!
//! The paper's agents exchange *source code*; our agents exchange
//! genomes, but their prompts, rationales, and writer reports embed
//! this rendering so run transcripts read like the paper's appendices.

use super::*;

/// A short, diff-friendly, HIP-flavoured sketch of the kernel a genome
/// describes. Deterministic: equal genomes render identically.
pub fn render_hip_sketch(g: &KernelGenome) -> String {
    let mut s = String::new();
    let elt = match g.precision {
        Precision::Fp32 => "float",
        Precision::Fp16 => "__half",
        Precision::Fp8 => "__hip_fp8_e4m3_fnuz",
    };
    let lanes = g.waves_per_block * limits::WAVE_SIZE;
    s.push_str(&format!(
        "// fingerprint: {}\n#define TB_M {}\n#define TB_N {}\n#define TB_K {}\n",
        g.fingerprint(),
        g.block_m,
        g.block_n,
        g.block_k
    ));
    s.push_str(&format!(
        "#define TBLOCK_X_DIM {}u  // {} wave(s)\n",
        lanes, g.waves_per_block
    ));
    s.push_str(&format!(
        "__global__ void scaled_gemm_kernel(const {elt}* A, const {elt}* B,\n\
         \x20                                  const float* a_scale, const float* b_scale,\n\
         \x20                                  __hip_bfloat16* C, int M, int K, int N) {{\n"
    ));
    if g.lds_staging {
        let bufs = if g.double_buffer { "_ping, _pong" } else { "" };
        let pad = if g.lds_pad > 0 {
            format!(" + {}", g.lds_pad)
        } else {
            String::new()
        };
        s.push_str(&format!(
            "  __shared__ {elt} lds_a{bufs}[TB_M][TB_K{pad}];\n\
             \x20 __shared__ {elt} lds_b{bufs}[TB_K][TB_N{pad}];\n"
        ));
        if g.swizzle == Swizzle::Xor {
            s.push_str("  // XOR-swizzled LDS column indexing\n");
        }
    } else {
        s.push_str("  // no LDS staging: operands read directly from global\n");
    }
    match g.scale_cache {
        ScaleCache::GlobalReload => {
            s.push_str("  // scales re-read from global memory per tile\n")
        }
        ScaleCache::Lds => s.push_str("  __shared__ float lds_scales[TB_M + TB_N];\n"),
        ScaleCache::LdsRepurposed => s.push_str(
            "  // scales overlaid on consumed A/B LDS buffers (cast fp8*->float*)\n",
        ),
    }
    if g.acc_in_regs {
        s.push_str("  float acc[TB_M * TB_N / TBLOCK_X_DIM] = {0.f};\n");
    } else {
        s.push_str("  // accumulate via global C read-modify-write\n");
    }
    let loop_order = if g.k_innermost {
        "for (k_tile inner)"
    } else {
        "for (k_tile OUTER)"
    };
    s.push_str(&format!(
        "  {loop_order} {{  // unroll {}x, {}-byte vector loads\n",
        g.unroll_k, g.vector_width
    ));
    if g.double_buffer {
        s.push_str("    // ping-pong: load next tile while computing current\n");
    }
    match g.compute {
        ComputePath::Scalar => s.push_str("    acc[..] += (float)a * (float)b;  // scalar FMA\n"),
        ComputePath::Vectorized => {
            s.push_str("    acc[..] += packed_fma(a_vec, b_vec);  // vector FMA\n")
        }
        ComputePath::Mfma => {
            if g.isa_scheduling {
                s.push_str(
                    "    // hand-scheduled MFMA assembly (software-pipelined dual issue)\n",
                );
            }
            s.push_str(
                "    rocwmma::mma_sync(acc_frag, a_frag, b_frag, acc_frag);  // MFMA 32x32x16\n",
            )
        }
    }
    if g.lds_staging {
        s.push_str("    __syncthreads();\n");
    }
    s.push_str("  }\n");
    match g.writeback {
        Writeback::SingleWave => s.push_str(
            "  if (wave_id_in_block == 0) store_tile(C, acc, a_scale, b_scale);\n",
        ),
        Writeback::Cooperative => {
            s.push_str("  cooperative_store_tile(C, acc, a_scale, b_scale);  // all waves\n")
        }
    }
    s.push_str(&format!(
        "}}\n// grid mapping: {:?}; launch {}x{} output tiles\n",
        g.grid_mapping, g.block_m, g.block_n
    ));
    s
}

/// Line-level diff between two renderings (the writer's "diff through
/// which the output HIP code is produced").
pub fn diff_sketches(base: &KernelGenome, child: &KernelGenome) -> String {
    let a = render_hip_sketch(base);
    let b = render_hip_sketch(child);
    let a_lines: Vec<&str> = a.lines().collect();
    let b_lines: Vec<&str> = b.lines().collect();
    let mut out = String::new();
    let max = a_lines.len().max(b_lines.len());
    for i in 0..max {
        let la = a_lines.get(i).copied().unwrap_or("");
        let lb = b_lines.get(i).copied().unwrap_or("");
        if la != lb {
            if !la.is_empty() {
                out.push_str(&format!("- {la}\n"));
            }
            if !lb.is_empty() {
                out.push_str(&format!("+ {lb}\n"));
            }
        }
    }
    if out.is_empty() {
        out.push_str("(no structural change)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;

    #[test]
    fn render_is_deterministic() {
        let g = seeds::human_oracle();
        assert_eq!(render_hip_sketch(&g), render_hip_sketch(&g));
    }

    #[test]
    fn render_reflects_features() {
        let s = render_hip_sketch(&seeds::human_oracle());
        assert!(s.contains("rocwmma::mma_sync"));
        assert!(s.contains("_ping, _pong"));
        assert!(s.contains("cooperative_store_tile"));
        assert!(s.contains("__hip_fp8_e4m3_fnuz"));
        let n = render_hip_sketch(&seeds::naive_hip());
        assert!(n.contains("scalar FMA"));
        assert!(n.contains("no LDS staging"));
    }

    #[test]
    fn diff_empty_for_identical() {
        let g = seeds::mfma_seed();
        assert_eq!(diff_sketches(&g, &g), "(no structural change)\n");
    }

    #[test]
    fn diff_marks_changes() {
        let base = seeds::mfma_seed();
        let mut child = base.clone();
        child.block_m = 64;
        let d = diff_sketches(&base, &child);
        assert!(d.contains("- #define TB_M 32"));
        assert!(d.contains("+ #define TB_M 64"));
    }
}
