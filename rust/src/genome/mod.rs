//! The kernel genome: a typed parameterization of the FP8 block-scaled
//! GEMM kernel design space.
//!
//! The paper evolves free-form HIP source; the features its evolved
//! kernels actually vary (App. A.3's breakdown + the avenue list in
//! App. A.2) are exactly the axes encoded here: tile sizes, compute
//! path (scalar vs vectorized vs Matrix Core), LDS staging / ping-pong
//! double buffering / padding / swizzling, global-load vector width,
//! waves per block, writeback strategy, scale caching, grid mapping,
//! and precision path. A genome is "the code listing" in this
//! reproduction (see `DESIGN.md` §2 for the substitution argument);
//! [`render`] pretty-prints it in a HIP-like sketch so agent prompts
//! and reports stay human-readable.
//!
//! Hard validity (would not compile / exceeds hardware limits) lives in
//! [`KernelGenome::validate`]; *semantic* correctness hazards (races
//! the evaluation platform catches at runtime, like multi-wave
//! read-modify-write to global C) are modeled in
//! [`KernelGenome::correctness_hazard`] — the scientist only learns
//! about those from failed submissions, as in the paper.

pub mod edit;
pub mod persist;
pub mod render;
pub mod seeds;


pub use edit::{GenomeEdit, Param};

/// Compute inner-loop implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ComputePath {
    /// Straight-line scalar FMAs (the naive HIP translation).
    Scalar,
    /// Packed vector FMAs (e.g. `v_dot2`/f32 vector ops).
    Vectorized,
    /// MFMA Matrix Core ops (32x32x16 fp8) — the rocWMMA path.
    Mfma,
}

/// Numeric path through the kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// f32 in, f32 math (naive translation; no quantization win).
    Fp32,
    /// fp16 library-style path (what `torch.matmul` uses on MI300).
    Fp16,
    /// fp8-e4m3 in, f32 accumulate, bf16 out — the competition task's
    /// intended fast path (App. A.3 "mixed-precision arithmetic").
    Fp8,
}

/// How the final C tile reaches global memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Writeback {
    /// Only wave 0 stores the block's tile (App. A.3: avoids
    /// cross-wave write conflicts, at the cost of idle waves).
    SingleWave,
    /// All waves cooperate in the store (the A.2 experiment-2 rubric);
    /// requires a private accumulator to be race-free.
    Cooperative,
}

/// Where per-row/col dequant scales are kept.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScaleCache {
    /// Re-read from global memory every time they're needed.
    GlobalReload,
    /// Dedicated LDS buffer (costs LDS capacity -> occupancy).
    Lds,
    /// Re-purpose the already-consumed A/B LDS tiles for the scales
    /// (App. A.3 "LDS re-purposing for scale caching": zero extra LDS).
    LdsRepurposed,
}

/// LDS address swizzling for bank-conflict avoidance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Swizzle {
    None,
    /// XOR-swizzle of the LDS column index.
    Xor,
}

/// Workgroup-to-output-tile mapping over the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GridMapping {
    RowMajor,
    ColMajor,
    /// Block-swizzled mapping that improves L2 reuse across
    /// neighbouring workgroups.
    TileSwizzled,
}

/// A complete kernel configuration — one individual in the population.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelGenome {
    /// Output tile height per workgroup (pow2, 16..=256).
    pub block_m: u32,
    /// Output tile width per workgroup (pow2, 16..=256).
    pub block_n: u32,
    /// Reduction-step depth per LDS stage (pow2, 16..=256).
    pub block_k: u32,
    pub compute: ComputePath,
    pub precision: Precision,
    /// Inner k-loop unroll factor (1, 2, 4, 8).
    pub unroll_k: u32,
    /// Stage A/B tiles in LDS (vs. direct-from-global loads).
    pub lds_staging: bool,
    /// Ping-pong double buffering of the LDS tiles (needs staging).
    pub double_buffer: bool,
    /// Extra padding elements per LDS row (bank-conflict mitigation).
    pub lds_pad: u32,
    pub swizzle: Swizzle,
    /// Global-load width in bytes per lane (1, 2, 4, 8, 16).
    pub vector_width: u32,
    /// Waves (64 lanes each) per workgroup: 1, 2, 4, 8.
    pub waves_per_block: u32,
    pub writeback: Writeback,
    pub scale_cache: ScaleCache,
    pub grid_mapping: GridMapping,
    /// Keep the accumulator in private registers (vs re-reading C).
    pub acc_in_regs: bool,
    /// Finish a tile's k-reduction before moving on (loop order).
    pub k_innermost: bool,
    /// Hand-scheduled MFMA assembly (software-pipelined dual-issue at
    /// the ISA level). **Not reachable by the scientist or any tuner**:
    /// there is no `GenomeEdit` for this axis and no avenue proposes
    /// it — it models what the competition's top humans extracted with
    /// actual-MI300 access, ISA docs, and profiling (Table 1 comment:
    /// "top-8 had access to actual MI300"). Only the human-oracle
    /// genome sets it. See DESIGN.md §2.
    pub isa_scheduling: bool,
}

impl Default for KernelGenome {
    /// The default is the *naive HIP translation* seed — evolution
    /// starts from the bottom, as in the paper.
    fn default() -> Self {
        seeds::naive_hip()
    }
}

/// MI300-class hardware limits the genome must respect (`gpu::MI300`
/// holds the performance-model constants; these are the hard caps).
pub mod limits {
    /// LDS bytes per workgroup.
    pub const LDS_BYTES: u32 = 64 * 1024;
    /// VGPR budget per lane (f32 registers).
    pub const VGPRS_PER_LANE: u32 = 512;
    /// Lanes per wave.
    pub const WAVE_SIZE: u32 = 64;
    /// Max lanes per workgroup.
    pub const MAX_BLOCK_LANES: u32 = 1024;
}

/// Why a genome is rejected before it ever runs ("does not compile /
/// launch"). The evaluation platform reports these immediately, unlike
/// [`Hazard`]s which surface as wrong results.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Invalid {
    NonPow2Block(&'static str, u32),
    BlockOutOfRange(&'static str, u32),
    LdsOverflow { need: u32, have: u32 },
    RegisterOverflow { need: u32, have: u32 },
    TooManyLanes(u32),
    BadUnroll(u32),
    BadVectorWidth(u32),
    BadWaves(u32),
    DoubleBufferWithoutStaging,
    ScaleLdsWithoutStaging,
    SwizzleWithPadding,
    MfmaRequiresLowPrecision,
}

impl Invalid {
    /// Stable lint-code string for this rejection (DESIGN.md §13).
    /// `analysis::lint` re-emits every [`KernelGenome::validate`]
    /// verdict under exactly this code, so the diagnostic engine and
    /// the legacy error type cannot drift. Codes are part of the
    /// journal wire format: never renumber an existing one.
    pub fn code(&self) -> &'static str {
        match self {
            Invalid::LdsOverflow { .. } => "L001-lds-over-budget",
            Invalid::RegisterOverflow { .. } => "L002-vgpr-over-budget",
            Invalid::NonPow2Block(..) => "L010-block-not-pow2",
            Invalid::BlockOutOfRange(..) => "L011-block-out-of-range",
            Invalid::BadUnroll(_) => "L012-bad-unroll",
            Invalid::BadVectorWidth(_) => "L013-bad-vector-width",
            Invalid::BadWaves(_) => "L014-bad-waves",
            Invalid::TooManyLanes(_) => "L015-too-many-lanes",
            Invalid::DoubleBufferWithoutStaging => "L020-double-buffer-without-staging",
            Invalid::ScaleLdsWithoutStaging => "L021-scale-lds-without-staging",
            Invalid::SwizzleWithPadding => "L022-swizzle-with-padding",
            Invalid::MfmaRequiresLowPrecision => "L023-mfma-requires-low-precision",
        }
    }
}

impl std::fmt::Display for Invalid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Invalid::NonPow2Block(d, v) => write!(f, "block_{d}={v} is not a power of two"),
            Invalid::BlockOutOfRange(d, v) => write!(f, "block_{d}={v} outside [16, 256]"),
            Invalid::LdsOverflow { need, have } => {
                write!(f, "LDS overflow: need {need} B > {have} B per workgroup")
            }
            Invalid::RegisterOverflow { need, have } => {
                write!(f, "VGPR overflow: need {need} > {have} per lane")
            }
            Invalid::TooManyLanes(n) => write!(f, "{n} lanes exceeds workgroup limit"),
            Invalid::BadUnroll(u) => write!(f, "unroll_k={u} not in {{1,2,4,8}}"),
            Invalid::BadVectorWidth(w) => write!(f, "vector_width={w} not in {{1,2,4,8,16}}"),
            Invalid::BadWaves(w) => write!(f, "waves_per_block={w} not in {{1,2,4,8}}"),
            Invalid::DoubleBufferWithoutStaging => {
                write!(f, "double buffering requires LDS staging")
            }
            Invalid::ScaleLdsWithoutStaging => {
                write!(f, "LDS scale caching requires LDS staging")
            }
            Invalid::SwizzleWithPadding => {
                write!(f, "XOR swizzle and row padding are mutually exclusive")
            }
            Invalid::MfmaRequiresLowPrecision => {
                write!(f, "MFMA path requires fp8/fp16 operands")
            }
        }
    }
}

/// A *semantic* defect: the kernel launches but produces wrong numbers.
/// These are only discoverable through the evaluation platform's
/// correctness gate — exactly the black-box constraint of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hazard {
    /// Multiple waves read-modify-write the same C tile without a
    /// private accumulator (the race App. A.3's single-wave writeback
    /// exists to avoid).
    MultiWaveAccumulationRace,
    /// Scales read from re-purposed LDS before the A/B data there was
    /// consumed — needs double buffering to be safe.
    ScaleRepurposeOverlap,
}

impl KernelGenome {
    fn lds_tile_bytes(&self) -> u32 {
        if !self.lds_staging {
            return 0;
        }
        let elt = match self.precision {
            Precision::Fp32 => 4,
            Precision::Fp16 => 2,
            Precision::Fp8 => 1,
        };
        let pad = self.lds_pad * elt;
        let a = self.block_m * (self.block_k * elt + pad);
        let b = self.block_k * (self.block_n * elt + pad);
        let bufs = if self.double_buffer { 2 } else { 1 };
        let scales = match self.scale_cache {
            ScaleCache::Lds => (self.block_m + self.block_n) * 4,
            _ => 0,
        };
        (a + b) * bufs + scales
    }

    /// Estimated f32-register pressure per lane: accumulator fragment +
    /// staging buffers + unroll temporaries.
    pub fn vgprs_per_lane(&self) -> u32 {
        let lanes = self.waves_per_block * limits::WAVE_SIZE;
        let acc = if self.acc_in_regs {
            // Each lane holds its slice of the block_m x block_n f32
            // accumulator. With MFMA the fragment is spread over the
            // wave; scalar paths need the same count of live values.
            (self.block_m * self.block_n).div_ceil(lanes)
        } else {
            4
        };
        let staging = if self.lds_staging { 8 } else { 16 };
        let unroll_tmp = 4 * self.unroll_k;
        let vec_tmp = self.vector_width.div_ceil(4) * 2;
        acc + staging + unroll_tmp + vec_tmp + 24 // ABI/addressing overhead
    }

    /// Hard validity: does this genome compile and launch at all?
    pub fn validate(&self) -> Result<(), Invalid> {
        for (name, v) in [("m", self.block_m), ("n", self.block_n), ("k", self.block_k)] {
            if !v.is_power_of_two() {
                return Err(Invalid::NonPow2Block(name, v));
            }
            if !(16..=256).contains(&v) {
                return Err(Invalid::BlockOutOfRange(name, v));
            }
        }
        if ![1, 2, 4, 8].contains(&self.unroll_k) {
            return Err(Invalid::BadUnroll(self.unroll_k));
        }
        if ![1, 2, 4, 8, 16].contains(&self.vector_width) {
            return Err(Invalid::BadVectorWidth(self.vector_width));
        }
        if ![1, 2, 4, 8].contains(&self.waves_per_block) {
            return Err(Invalid::BadWaves(self.waves_per_block));
        }
        let lanes = self.waves_per_block * limits::WAVE_SIZE;
        if lanes > limits::MAX_BLOCK_LANES {
            return Err(Invalid::TooManyLanes(lanes));
        }
        if self.double_buffer && !self.lds_staging {
            return Err(Invalid::DoubleBufferWithoutStaging);
        }
        if matches!(self.scale_cache, ScaleCache::Lds | ScaleCache::LdsRepurposed)
            && !self.lds_staging
        {
            return Err(Invalid::ScaleLdsWithoutStaging);
        }
        if self.swizzle == Swizzle::Xor && self.lds_pad > 0 {
            return Err(Invalid::SwizzleWithPadding);
        }
        if self.compute == ComputePath::Mfma && self.precision == Precision::Fp32 {
            return Err(Invalid::MfmaRequiresLowPrecision);
        }
        let lds = self.lds_tile_bytes();
        if lds > limits::LDS_BYTES {
            return Err(Invalid::LdsOverflow {
                need: lds,
                have: limits::LDS_BYTES,
            });
        }
        let vgprs = self.vgprs_per_lane();
        if vgprs > limits::VGPRS_PER_LANE {
            return Err(Invalid::RegisterOverflow {
                need: vgprs,
                have: limits::VGPRS_PER_LANE,
            });
        }
        Ok(())
    }

    /// Semantic correctness hazard, if any. `None` means the kernel
    /// produces correct results.
    pub fn correctness_hazard(&self) -> Option<Hazard> {
        if self.waves_per_block > 1
            && !self.acc_in_regs
            && self.writeback == Writeback::Cooperative
        {
            return Some(Hazard::MultiWaveAccumulationRace);
        }
        if self.scale_cache == ScaleCache::LdsRepurposed && !self.double_buffer {
            return Some(Hazard::ScaleRepurposeOverlap);
        }
        None
    }

    /// Total LDS bytes consumed per workgroup (0 without staging).
    pub fn lds_bytes(&self) -> u32 {
        self.lds_tile_bytes()
    }

    /// A short, stable fingerprint used for display and persistence.
    /// Hot paths (dedup sets, the eval cache, in-flight alias maps) key
    /// on [`KernelGenome::fingerprint_hash`] instead — rendering this
    /// string per probe was the dominant per-submission allocation
    /// (§Perf). String equality here is exactly genome equality: every
    /// axis is rendered unambiguously.
    pub fn fingerprint(&self) -> String {
        format!(
            "{}x{}x{}-{:?}-{:?}-u{}-s{}{}p{}-{:?}-v{}-w{}-{:?}-{:?}-{:?}-a{}-k{}",
            self.block_m,
            self.block_n,
            self.block_k,
            self.compute,
            self.precision,
            self.unroll_k,
            self.lds_staging as u8,
            self.double_buffer as u8,
            self.lds_pad,
            self.swizzle,
            self.vector_width,
            self.waves_per_block,
            self.writeback,
            self.scale_cache,
            self.grid_mapping,
            self.acc_in_regs as u8,
            (self.k_innermost as u8) + 2 * (self.isa_scheduling as u8),
        )
    }

    /// 64-bit content hash over the same axes [`Self::fingerprint`]
    /// renders — the allocation-free dedup/cache key (§Perf). Stable
    /// across runs and platforms: a fixed splitmix64-style finalizer
    /// folded over every field in declaration order, no `RandomState`
    /// anywhere, so trajectories and persisted caches stay
    /// reproducible. Collisions are theoretically possible (the u32
    /// axes alone exceed 64 bits); callers whose *semantics* depend on
    /// exact identity (e.g. [`crate::population::Population`]'s
    /// duplicate probe) confirm with genome equality on the positive
    /// path — `tests/prop_invariants.rs` checks hash/string agreement.
    pub fn fingerprint_hash(&self) -> u64 {
        #[inline]
        fn mix(h: u64, v: u64) -> u64 {
            let mut x = (h ^ v).wrapping_add(0x9E37_79B9_7F4A_7C15);
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            x ^ (x >> 31)
        }
        let mut h = 0x6b73_2d66_7036_3401u64;
        h = mix(h, self.block_m as u64);
        h = mix(h, self.block_n as u64);
        h = mix(h, self.block_k as u64);
        h = mix(h, self.compute as u64);
        h = mix(h, self.precision as u64);
        h = mix(h, self.unroll_k as u64);
        h = mix(h, self.lds_staging as u64);
        h = mix(h, self.double_buffer as u64);
        h = mix(h, self.lds_pad as u64);
        h = mix(h, self.swizzle as u64);
        h = mix(h, self.vector_width as u64);
        h = mix(h, self.waves_per_block as u64);
        h = mix(h, self.writeback as u64);
        h = mix(h, self.scale_cache as u64);
        h = mix(h, self.grid_mapping as u64);
        h = mix(h, self.acc_in_regs as u64);
        h = mix(h, self.k_innermost as u64);
        mix(h, self.isa_scheduling as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_are_valid() {
        for (name, g) in seeds::all_seeds() {
            assert!(g.validate().is_ok(), "{name}: {:?}", g.validate());
        }
    }

    #[test]
    fn seeds_are_correct() {
        for (name, g) in seeds::all_seeds() {
            assert!(g.correctness_hazard().is_none(), "{name} has a hazard");
        }
    }

    #[test]
    fn naive_is_default() {
        assert_eq!(KernelGenome::default(), seeds::naive_hip());
    }

    #[test]
    fn non_pow2_block_rejected() {
        let g = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        assert!(matches!(g.validate(), Err(Invalid::NonPow2Block("m", 48))));
    }

    #[test]
    fn block_range_enforced() {
        let g = KernelGenome {
            block_n: 512,
            ..seeds::naive_hip()
        };
        assert!(matches!(g.validate(), Err(Invalid::BlockOutOfRange("n", 512))));
        let g = KernelGenome {
            block_k: 8,
            ..seeds::naive_hip()
        };
        assert!(matches!(g.validate(), Err(Invalid::BlockOutOfRange("k", 8))));
    }

    #[test]
    fn lds_overflow_detected() {
        let g = KernelGenome {
            block_m: 256,
            block_n: 256,
            block_k: 256,
            lds_staging: true,
            double_buffer: true,
            precision: Precision::Fp32,
            compute: ComputePath::Vectorized,
            acc_in_regs: false,
            writeback: Writeback::SingleWave,
            waves_per_block: 8,
            ..seeds::naive_hip()
        };
        assert!(matches!(g.validate(), Err(Invalid::LdsOverflow { .. })));
    }

    #[test]
    fn register_overflow_detected() {
        let g = KernelGenome {
            block_m: 256,
            block_n: 256,
            block_k: 16,
            waves_per_block: 1,
            acc_in_regs: true,
            lds_staging: false,
            double_buffer: false,
            scale_cache: ScaleCache::GlobalReload,
            ..seeds::naive_hip()
        };
        // 256*256/64 = 1024 accumulator registers per lane >> 512.
        assert!(matches!(g.validate(), Err(Invalid::RegisterOverflow { .. })));
    }

    #[test]
    fn double_buffer_needs_staging() {
        let g = KernelGenome {
            lds_staging: false,
            double_buffer: true,
            scale_cache: ScaleCache::GlobalReload,
            ..seeds::naive_hip()
        };
        assert_eq!(g.validate(), Err(Invalid::DoubleBufferWithoutStaging));
    }

    #[test]
    fn mfma_needs_low_precision() {
        let g = KernelGenome {
            compute: ComputePath::Mfma,
            precision: Precision::Fp32,
            ..seeds::mfma_seed()
        };
        assert_eq!(g.validate(), Err(Invalid::MfmaRequiresLowPrecision));
    }

    #[test]
    fn swizzle_pad_exclusive() {
        let g = KernelGenome {
            swizzle: Swizzle::Xor,
            lds_pad: 4,
            ..seeds::human_oracle()
        };
        assert_eq!(g.validate(), Err(Invalid::SwizzleWithPadding));
    }

    #[test]
    fn multiwave_race_detected() {
        let g = KernelGenome {
            waves_per_block: 4,
            acc_in_regs: false,
            writeback: Writeback::Cooperative,
            ..seeds::mfma_seed()
        };
        assert_eq!(
            g.correctness_hazard(),
            Some(Hazard::MultiWaveAccumulationRace)
        );
    }

    #[test]
    fn scale_repurpose_needs_double_buffer() {
        let g = KernelGenome {
            lds_staging: true,
            double_buffer: false,
            scale_cache: ScaleCache::LdsRepurposed,
            ..seeds::mfma_seed()
        };
        assert_eq!(g.correctness_hazard(), Some(Hazard::ScaleRepurposeOverlap));
    }

    #[test]
    fn lds_bytes_double_buffer_doubles_tiles() {
        let base = KernelGenome {
            lds_staging: true,
            double_buffer: false,
            lds_pad: 0,
            scale_cache: ScaleCache::GlobalReload,
            ..seeds::mfma_seed()
        };
        let db = KernelGenome {
            double_buffer: true,
            ..base.clone()
        };
        assert_eq!(db.lds_bytes(), base.lds_bytes() * 2);
    }

    #[test]
    fn fingerprint_distinguishes() {
        let a = seeds::naive_hip();
        let b = seeds::human_oracle();
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), seeds::naive_hip().fingerprint());
    }

    #[test]
    fn fingerprint_hash_agrees_with_string_form() {
        // hash equality must track string equality (distinct seeds
        // hash apart, identical genomes hash together) and be a pure
        // function of the genome
        let all = seeds::all_seeds();
        for (na, a) in &all {
            for (nb, b) in &all {
                assert_eq!(
                    a.fingerprint() == b.fingerprint(),
                    a.fingerprint_hash() == b.fingerprint_hash(),
                    "{na} vs {nb}"
                );
            }
        }
        let g = seeds::human_oracle();
        assert_eq!(g.fingerprint_hash(), g.clone().fingerprint_hash());
        // single-axis flips change the hash
        let flipped = KernelGenome {
            k_innermost: !g.k_innermost,
            ..g.clone()
        };
        assert_ne!(g.fingerprint_hash(), flipped.fingerprint_hash());
    }

    #[test]
    fn invalid_codes_are_stable_and_distinct() {
        let variants = [
            Invalid::NonPow2Block("m", 48),
            Invalid::BlockOutOfRange("n", 512),
            Invalid::LdsOverflow { need: 1, have: 0 },
            Invalid::RegisterOverflow { need: 1, have: 0 },
            Invalid::TooManyLanes(2048),
            Invalid::BadUnroll(3),
            Invalid::BadVectorWidth(5),
            Invalid::BadWaves(7),
            Invalid::DoubleBufferWithoutStaging,
            Invalid::ScaleLdsWithoutStaging,
            Invalid::SwizzleWithPadding,
            Invalid::MfmaRequiresLowPrecision,
        ];
        let mut codes: Vec<&str> = variants.iter().map(|v| v.code()).collect();
        // ISSUE 9's canonical example code must exist verbatim
        assert!(codes.contains(&"L001-lds-over-budget"));
        codes.sort_unstable();
        let before = codes.len();
        codes.dedup();
        assert_eq!(codes.len(), before, "duplicate lint codes");
        for v in &variants {
            assert!(v.code().starts_with('L'), "{}", v.code());
            // codes are wire-format identifiers: lowercase kebab + digits
            assert!(
                v.code()[1..]
                    .chars()
                    .all(|c| c.is_ascii_digit() || c.is_ascii_lowercase() || c == '-'),
                "{}",
                v.code()
            );
        }
    }

    #[test]
    fn json_roundtrip() {
        let g = seeds::human_oracle();
        let s = g.to_json().to_string();
        let back =
            KernelGenome::from_json(&crate::util::json::parse(&s).unwrap()).unwrap();
        assert_eq!(g, back);
    }
}
