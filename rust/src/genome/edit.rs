//! Genome edit operators — the unit of change an experiment rubric
//! prescribes and the Kernel Writer applies.
//!
//! An experiment plan (paper §3.2) is a description plus a rubric of
//! concrete changes; in this reproduction a rubric is a list of
//! [`GenomeEdit`]s. The baseline tuners (`baselines/`) share the same
//! operators, so the scientist-vs-tuner comparison is apples-to-apples
//! over an identical search space.

use super::*;
use crate::rng::Rng;

/// Identifies one evolvable axis of the genome.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Param {
    BlockM,
    BlockN,
    BlockK,
    Compute,
    Precision,
    UnrollK,
    LdsStaging,
    DoubleBuffer,
    LdsPad,
    Swizzle,
    VectorWidth,
    WavesPerBlock,
    Writeback,
    ScaleCache,
    GridMapping,
    AccInRegs,
    KInnermost,
}

impl Param {
    pub const ALL: [Param; 17] = [
        Param::BlockM,
        Param::BlockN,
        Param::BlockK,
        Param::Compute,
        Param::Precision,
        Param::UnrollK,
        Param::LdsStaging,
        Param::DoubleBuffer,
        Param::LdsPad,
        Param::Swizzle,
        Param::VectorWidth,
        Param::WavesPerBlock,
        Param::Writeback,
        Param::ScaleCache,
        Param::GridMapping,
        Param::AccInRegs,
        Param::KInnermost,
    ];
}

/// One concrete change to a genome.
#[derive(Debug, Clone, PartialEq)]
pub enum GenomeEdit {
    SetBlockM(u32),
    SetBlockN(u32),
    SetBlockK(u32),
    SetCompute(ComputePath),
    SetPrecision(Precision),
    SetUnrollK(u32),
    SetLdsStaging(bool),
    SetDoubleBuffer(bool),
    SetLdsPad(u32),
    SetSwizzle(Swizzle),
    SetVectorWidth(u32),
    SetWavesPerBlock(u32),
    SetWriteback(Writeback),
    SetScaleCache(ScaleCache),
    SetGridMapping(GridMapping),
    SetAccInRegs(bool),
    SetKInnermost(bool),
}

impl GenomeEdit {
    /// Apply the edit in place.
    pub fn apply(&self, g: &mut KernelGenome) {
        match *self {
            GenomeEdit::SetBlockM(v) => g.block_m = v,
            GenomeEdit::SetBlockN(v) => g.block_n = v,
            GenomeEdit::SetBlockK(v) => g.block_k = v,
            GenomeEdit::SetCompute(v) => g.compute = v,
            GenomeEdit::SetPrecision(v) => g.precision = v,
            GenomeEdit::SetUnrollK(v) => g.unroll_k = v,
            GenomeEdit::SetLdsStaging(v) => g.lds_staging = v,
            GenomeEdit::SetDoubleBuffer(v) => g.double_buffer = v,
            GenomeEdit::SetLdsPad(v) => g.lds_pad = v,
            GenomeEdit::SetSwizzle(v) => g.swizzle = v,
            GenomeEdit::SetVectorWidth(v) => g.vector_width = v,
            GenomeEdit::SetWavesPerBlock(v) => g.waves_per_block = v,
            GenomeEdit::SetWriteback(v) => g.writeback = v,
            GenomeEdit::SetScaleCache(v) => g.scale_cache = v,
            GenomeEdit::SetGridMapping(v) => g.grid_mapping = v,
            GenomeEdit::SetAccInRegs(v) => g.acc_in_regs = v,
            GenomeEdit::SetKInnermost(v) => g.k_innermost = v,
        }
    }

    /// Which axis this edit touches.
    pub fn param(&self) -> Param {
        match self {
            GenomeEdit::SetBlockM(_) => Param::BlockM,
            GenomeEdit::SetBlockN(_) => Param::BlockN,
            GenomeEdit::SetBlockK(_) => Param::BlockK,
            GenomeEdit::SetCompute(_) => Param::Compute,
            GenomeEdit::SetPrecision(_) => Param::Precision,
            GenomeEdit::SetUnrollK(_) => Param::UnrollK,
            GenomeEdit::SetLdsStaging(_) => Param::LdsStaging,
            GenomeEdit::SetDoubleBuffer(_) => Param::DoubleBuffer,
            GenomeEdit::SetLdsPad(_) => Param::LdsPad,
            GenomeEdit::SetSwizzle(_) => Param::Swizzle,
            GenomeEdit::SetVectorWidth(_) => Param::VectorWidth,
            GenomeEdit::SetWavesPerBlock(_) => Param::WavesPerBlock,
            GenomeEdit::SetWriteback(_) => Param::Writeback,
            GenomeEdit::SetScaleCache(_) => Param::ScaleCache,
            GenomeEdit::SetGridMapping(_) => Param::GridMapping,
            GenomeEdit::SetAccInRegs(_) => Param::AccInRegs,
            GenomeEdit::SetKInnermost(_) => Param::KInnermost,
        }
    }

    /// Whether applying this edit would change `g` at all.
    pub fn is_noop(&self, g: &KernelGenome) -> bool {
        let mut copy = g.clone();
        self.apply(&mut copy);
        copy == *g
    }

    /// Human-readable description (used in rubrics and writer reports).
    pub fn describe(&self) -> String {
        match self {
            GenomeEdit::SetBlockM(v) => format!("set TB_M tile to {v}"),
            GenomeEdit::SetBlockN(v) => format!("set TB_N tile to {v}"),
            GenomeEdit::SetBlockK(v) => format!("set TB_K tile to {v}"),
            GenomeEdit::SetCompute(v) => format!("switch compute path to {v:?}"),
            GenomeEdit::SetPrecision(v) => format!("switch numeric path to {v:?}"),
            GenomeEdit::SetUnrollK(v) => format!("unroll the k-loop {v}x"),
            GenomeEdit::SetLdsStaging(true) => "stage A/B tiles through LDS".into(),
            GenomeEdit::SetLdsStaging(false) => "load A/B directly from global".into(),
            GenomeEdit::SetDoubleBuffer(true) => {
                "add ping-pong LDS double buffering".into()
            }
            GenomeEdit::SetDoubleBuffer(false) => "drop to single LDS buffer".into(),
            GenomeEdit::SetLdsPad(v) => format!("pad LDS rows by {v} elements"),
            GenomeEdit::SetSwizzle(v) => format!("set LDS swizzle to {v:?}"),
            GenomeEdit::SetVectorWidth(v) => {
                format!("use {v}-byte vectorized global loads")
            }
            GenomeEdit::SetWavesPerBlock(v) => format!("run {v} waves per block"),
            GenomeEdit::SetWriteback(v) => format!("use {v:?} writeback"),
            GenomeEdit::SetScaleCache(v) => format!("cache scales via {v:?}"),
            GenomeEdit::SetGridMapping(v) => format!("map grid {v:?}"),
            GenomeEdit::SetAccInRegs(true) => "keep accumulator in registers".into(),
            GenomeEdit::SetAccInRegs(false) => {
                "accumulate via global read-modify-write".into()
            }
            GenomeEdit::SetKInnermost(true) => "make k the innermost loop".into(),
            GenomeEdit::SetKInnermost(false) => "hoist k to the outer loop".into(),
        }
    }

    /// All candidate values on one axis (the discretized search space).
    pub fn candidates(param: Param) -> Vec<GenomeEdit> {
        let pow2 = [16u32, 32, 64, 128, 256];
        match param {
            Param::BlockM => pow2.iter().map(|&v| GenomeEdit::SetBlockM(v)).collect(),
            Param::BlockN => pow2.iter().map(|&v| GenomeEdit::SetBlockN(v)).collect(),
            Param::BlockK => pow2.iter().map(|&v| GenomeEdit::SetBlockK(v)).collect(),
            Param::Compute => vec![
                GenomeEdit::SetCompute(ComputePath::Scalar),
                GenomeEdit::SetCompute(ComputePath::Vectorized),
                GenomeEdit::SetCompute(ComputePath::Mfma),
            ],
            Param::Precision => vec![
                GenomeEdit::SetPrecision(Precision::Fp32),
                GenomeEdit::SetPrecision(Precision::Fp16),
                GenomeEdit::SetPrecision(Precision::Fp8),
            ],
            Param::UnrollK => [1u32, 2, 4, 8]
                .iter()
                .map(|&v| GenomeEdit::SetUnrollK(v))
                .collect(),
            Param::LdsStaging => vec![
                GenomeEdit::SetLdsStaging(false),
                GenomeEdit::SetLdsStaging(true),
            ],
            Param::DoubleBuffer => vec![
                GenomeEdit::SetDoubleBuffer(false),
                GenomeEdit::SetDoubleBuffer(true),
            ],
            Param::LdsPad => [0u32, 1, 2, 4, 8]
                .iter()
                .map(|&v| GenomeEdit::SetLdsPad(v))
                .collect(),
            Param::Swizzle => vec![
                GenomeEdit::SetSwizzle(Swizzle::None),
                GenomeEdit::SetSwizzle(Swizzle::Xor),
            ],
            Param::VectorWidth => [1u32, 2, 4, 8, 16]
                .iter()
                .map(|&v| GenomeEdit::SetVectorWidth(v))
                .collect(),
            Param::WavesPerBlock => [1u32, 2, 4, 8]
                .iter()
                .map(|&v| GenomeEdit::SetWavesPerBlock(v))
                .collect(),
            Param::Writeback => vec![
                GenomeEdit::SetWriteback(Writeback::SingleWave),
                GenomeEdit::SetWriteback(Writeback::Cooperative),
            ],
            Param::ScaleCache => vec![
                GenomeEdit::SetScaleCache(ScaleCache::GlobalReload),
                GenomeEdit::SetScaleCache(ScaleCache::Lds),
                GenomeEdit::SetScaleCache(ScaleCache::LdsRepurposed),
            ],
            Param::GridMapping => vec![
                GenomeEdit::SetGridMapping(GridMapping::RowMajor),
                GenomeEdit::SetGridMapping(GridMapping::ColMajor),
                GenomeEdit::SetGridMapping(GridMapping::TileSwizzled),
            ],
            Param::AccInRegs => vec![
                GenomeEdit::SetAccInRegs(false),
                GenomeEdit::SetAccInRegs(true),
            ],
            Param::KInnermost => vec![
                GenomeEdit::SetKInnermost(false),
                GenomeEdit::SetKInnermost(true),
            ],
        }
    }

    /// A uniformly random edit (baseline tuners' mutation operator).
    pub fn random(rng: &mut Rng) -> GenomeEdit {
        let param = *rng.choose(&Param::ALL);
        let cands = GenomeEdit::candidates(param);
        cands[rng.below(cands.len())].clone()
    }
}

/// Apply a rubric (edit list) to a base genome, returning the child.
/// Invalid children are *not* repaired here — the Writer owns repair
/// policy, the tuners own rejection policy.
pub fn apply_edits(base: &KernelGenome, edits: &[GenomeEdit]) -> KernelGenome {
    let mut g = base.clone();
    for e in edits {
        e.apply(&mut g);
    }
    g
}

/// All single-edit neighbours of a genome that change it and validate
/// (the hill-climber's move set).
pub fn valid_neighbors(g: &KernelGenome) -> Vec<(GenomeEdit, KernelGenome)> {
    let mut out = Vec::new();
    for p in Param::ALL {
        for e in GenomeEdit::candidates(p) {
            if e.is_noop(g) {
                continue;
            }
            let child = apply_edits(g, std::slice::from_ref(&e));
            if child.validate().is_ok() {
                out.push((e, child));
            }
        }
    }
    out
}

/// Uniform crossover: each axis from one parent or the other. The
/// paper frames the LLM as the crossover operator (it sees Base and
/// Reference); this is the corresponding mechanical operator used by
/// baseline tuners and as a fallback in the writer.
pub fn crossover(a: &KernelGenome, b: &KernelGenome, rng: &mut Rng) -> KernelGenome {
    let mut g = a.clone();
    if rng.chance(0.5) {
        g.block_m = b.block_m;
    }
    if rng.chance(0.5) {
        g.block_n = b.block_n;
    }
    if rng.chance(0.5) {
        g.block_k = b.block_k;
    }
    if rng.chance(0.5) {
        g.compute = b.compute;
        g.precision = b.precision; // coupled: compute path implies dtype family
    }
    if rng.chance(0.5) {
        g.unroll_k = b.unroll_k;
    }
    if rng.chance(0.5) {
        g.lds_staging = b.lds_staging;
        g.double_buffer = b.double_buffer;
        g.scale_cache = b.scale_cache;
    }
    if rng.chance(0.5) {
        g.lds_pad = b.lds_pad;
        g.swizzle = b.swizzle;
    }
    if rng.chance(0.5) {
        g.vector_width = b.vector_width;
    }
    if rng.chance(0.5) {
        g.waves_per_block = b.waves_per_block;
        g.writeback = b.writeback;
        g.acc_in_regs = b.acc_in_regs;
    }
    if rng.chance(0.5) {
        g.grid_mapping = b.grid_mapping;
    }
    if rng.chance(0.5) {
        g.k_innermost = b.k_innermost;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;

    #[test]
    fn apply_single_edit() {
        let base = seeds::naive_hip();
        let child = apply_edits(&base, &[GenomeEdit::SetBlockM(64)]);
        assert_eq!(child.block_m, 64);
        assert_eq!(child.block_n, base.block_n);
    }

    #[test]
    fn edits_cover_all_params() {
        use std::collections::HashSet;
        let mut seen = HashSet::new();
        for p in Param::ALL {
            for e in GenomeEdit::candidates(p) {
                assert_eq!(e.param(), p);
                seen.insert(p);
            }
        }
        assert_eq!(seen.len(), Param::ALL.len());
    }

    #[test]
    fn noop_detection() {
        let g = seeds::naive_hip();
        assert!(GenomeEdit::SetBlockM(g.block_m).is_noop(&g));
        assert!(!GenomeEdit::SetBlockM(g.block_m * 2).is_noop(&g));
    }

    #[test]
    fn neighbors_are_valid_and_distinct() {
        let g = seeds::mfma_seed();
        let ns = valid_neighbors(&g);
        assert!(ns.len() > 20, "expected a rich neighbourhood, got {}", ns.len());
        for (_, child) in &ns {
            assert!(child.validate().is_ok());
            assert_ne!(child, &g);
        }
    }

    #[test]
    fn random_edit_deterministic_per_seed() {
        let mut r1 = Rng::seed_from_u64(5);
        let mut r2 = Rng::seed_from_u64(5);
        for _ in 0..50 {
            assert_eq!(GenomeEdit::random(&mut r1), GenomeEdit::random(&mut r2));
        }
    }

    #[test]
    fn crossover_mixes_parents() {
        let a = seeds::naive_hip();
        let b = seeds::human_oracle();
        let mut rng = Rng::seed_from_u64(3);
        let mut saw_a = false;
        let mut saw_b = false;
        for _ in 0..50 {
            let c = crossover(&a, &b, &mut rng);
            if c.block_m == a.block_m {
                saw_a = true;
            }
            if c.block_m == b.block_m {
                saw_b = true;
            }
        }
        assert!(saw_a && saw_b);
    }

    #[test]
    fn describe_is_nonempty_for_all() {
        for p in Param::ALL {
            for e in GenomeEdit::candidates(p) {
                assert!(!e.describe().is_empty());
            }
        }
    }
}
