//! The PJRT runtime: the *real* evaluation backend.
//!
//! `make artifacts` has the build-time python layer lower every kernel
//! variant (L1 Pallas fp8 GEMM inside the L2 JAX graph) to HLO text
//! plus a `catalog.json`. This module loads those artifacts over the
//! `xla` PJRT surface (C API, CPU plugin), compiles them once, and
//! then checks + times them from the rust hot path — python is never
//! involved at runtime.
//!
//! The offline workspace cannot vendor the real `xla` crate, so the
//! `xla::` paths below resolve to the API-identical in-tree
//! [`xla_shim`] (see its docs and DESIGN.md §5 for the swap-back
//! instructions); `PjrtBackend::open` then reports PJRT as
//! unavailable and the PJRT integration tests skip.
//!
//! [`PjrtBackend`] implements [`crate::eval::EvalBackend`], so the
//! identical scientist loop that drives the MI300 simulator can drive
//! real compiled kernels (at CPU-testbed shapes). The genome axes that
//! survive the Pallas projection are the tile sizes and the
//! scale-fusion / accumulator-placement / loop-order structure; the
//! remaining axes (LDS padding, wave counts, ...) exist only on the
//! simulated MI300 (see DESIGN.md §2).

pub mod catalog;
pub mod xla_shim;

use self::xla_shim as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::eval::{EvalBackend, EvalError};
use crate::genome::KernelGenome;
use crate::rng::Rng;
use crate::workload::GemmConfig;

pub use catalog::{Catalog, CatalogEntry, VariantKey};

/// Deterministic pseudo-random input set for one GEMM shape.
struct ShapeInputs {
    a: xla::Literal,
    b: xla::Literal,
}

fn make_inputs(cfg: &GemmConfig, seed: u64) -> Result<ShapeInputs, EvalError> {
    let mut rng = Rng::seed_from_u64(
        seed ^ ((cfg.m as u64) << 32) ^ ((cfg.k as u64) << 16) ^ cfg.n as u64,
    );
    let gen = |rows: u32, cols: u32, rng: &mut Rng| -> Result<xla::Literal, EvalError> {
        let data: Vec<f32> = (0..(rows as usize * cols as usize))
            .map(|_| (rng.normal() as f32) * 0.5)
            .collect();
        xla::Literal::vec1(&data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| EvalError::Unsupported(format!("literal reshape: {e}")))
    };
    Ok(ShapeInputs {
        a: gen(cfg.m, cfg.k, &mut rng)?,
        b: gen(cfg.k, cfg.n, &mut rng)?,
    })
}

/// The PJRT evaluation backend over the AOT artifact catalog.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    catalog: Catalog,
    dir: PathBuf,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Reference outputs per shape (from the `ref_*` artifacts).
    ref_outputs: HashMap<GemmConfig, Vec<f32>>,
    inputs: HashMap<GemmConfig, ShapeInputs>,
    input_seed: u64,
    /// Wall-clock timing repetitions inside one `measure` call.
    pub inner_reps: u32,
}

impl PjrtBackend {
    /// Open the artifact directory (default `artifacts/`).
    pub fn open(dir: &Path) -> Result<Self, EvalError> {
        let catalog = Catalog::load(&dir.join("catalog.json"))
            .map_err(|e| EvalError::Unsupported(format!("catalog: {e}")))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| EvalError::Unsupported(format!("pjrt client: {e}")))?;
        Ok(PjrtBackend {
            client,
            catalog,
            dir: dir.to_path_buf(),
            compiled: HashMap::new(),
            ref_outputs: HashMap::new(),
            inputs: HashMap::new(),
            input_seed: 0xa0_7a11,
            inner_reps: 3,
        })
    }

    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// Shapes the catalog covers (the feedback suite for PJRT runs).
    pub fn shapes(&self) -> Vec<GemmConfig> {
        self.catalog.shapes()
    }

    fn compile_entry(&mut self, name: &str) -> Result<(), EvalError> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .catalog
            .by_name(name)
            .ok_or_else(|| EvalError::Unsupported(format!("no artifact '{name}'")))?
            .clone();
        let path = self.dir.join(&entry.artifact);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| EvalError::Unsupported("bad path".into()))?,
        )
        .map_err(|e| EvalError::Compile(format!("hlo parse {name}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| EvalError::Compile(format!("pjrt compile {name}: {e}")))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    fn inputs_for(&mut self, cfg: &GemmConfig) -> Result<(), EvalError> {
        if !self.inputs.contains_key(cfg) {
            let ins = make_inputs(cfg, self.input_seed)?;
            self.inputs.insert(*cfg, ins);
        }
        Ok(())
    }

    /// Execute one compiled variant on the shape's inputs, returning
    /// the flattened f32 output.
    fn run(&mut self, name: &str, cfg: &GemmConfig) -> Result<Vec<f32>, EvalError> {
        self.compile_entry(name)?;
        self.inputs_for(cfg)?;
        let ins = &self.inputs[cfg];
        let exe = &self.compiled[name];
        let result = exe
            .execute::<xla::Literal>(&[ins.a.clone(), ins.b.clone()])
            .map_err(|e| EvalError::Incorrect(format!("execute {name}: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| EvalError::Incorrect(format!("sync {name}: {e}")))?;
        let out = result
            .to_tuple1()
            .map_err(|e| EvalError::Incorrect(format!("tuple {name}: {e}")))?;
        out.to_vec::<f32>()
            .map_err(|e| EvalError::Incorrect(format!("to_vec {name}: {e}")))
    }

    /// Reference output (the library path) for a shape, cached.
    fn reference_output(&mut self, cfg: &GemmConfig) -> Result<Vec<f32>, EvalError> {
        if let Some(out) = self.ref_outputs.get(cfg) {
            return Ok(out.clone());
        }
        let ref_name = self
            .catalog
            .reference_for(cfg)
            .ok_or_else(|| EvalError::Unsupported(format!("no reference artifact for {cfg}")))?
            .name
            .clone();
        let out = self.run(&ref_name, cfg)?;
        self.ref_outputs.insert(*cfg, out.clone());
        Ok(out)
    }

    /// Map a full genome to a catalog variant for a shape. Genomes
    /// whose projection is absent from the catalog are Unsupported
    /// (the platform reports it like a compile failure).
    pub fn project(
        &self,
        g: &KernelGenome,
        cfg: &GemmConfig,
    ) -> Result<&CatalogEntry, EvalError> {
        let key = VariantKey::from_genome(g);
        self.catalog.lookup(&key, cfg).ok_or_else(|| {
            EvalError::Unsupported(format!(
                "no compiled variant for projection {key:?} at {cfg}"
            ))
        })
    }

    /// Correctness check: run the variant and compare against the
    /// reference artifact's output (tolerance covers bf16 + fp8
    /// quantization differences between the kernel and library paths).
    pub fn verify(&mut self, name: &str, cfg: &GemmConfig) -> Result<(), EvalError> {
        let got = self.run(name, cfg)?;
        let want = self.reference_output(cfg)?;
        if got.len() != want.len() {
            return Err(EvalError::Incorrect(format!(
                "{name}: output length {} != {}",
                got.len(),
                want.len()
            )));
        }
        let max_abs = want.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1.0);
        let tol = 0.06 * max_abs;
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            if (g - w).abs() > tol {
                return Err(EvalError::Incorrect(format!(
                    "{name}: element {i}: {g} vs {w} (tol {tol})"
                )));
            }
        }
        Ok(())
    }

    /// Time one named catalog entry directly (used by reports/benches).
    pub fn time_entry(&mut self, name: &str, cfg: &GemmConfig) -> Result<f64, EvalError> {
        self.compile_entry(name)?;
        let _ = self.run(name, cfg)?; // warmup
        let mut best = f64::INFINITY;
        for _ in 0..self.inner_reps.max(1) {
            let t0 = Instant::now();
            let _ = self.run(name, cfg)?;
            best = best.min(t0.elapsed().as_secs_f64() * 1e6);
        }
        Ok(best)
    }
}

impl EvalBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt-cpu"
    }

    fn check(&mut self, genome: &KernelGenome) -> Result<(), EvalError> {
        genome
            .validate()
            .map_err(|e| EvalError::Compile(e.to_string()))?;
        // verify on the smallest covered shape (cheap), like the
        // platform's correctness gate
        let shapes = self.shapes();
        let cfg = shapes
            .iter()
            .min_by_key(|c| c.m as u64 * c.k as u64 * c.n as u64)
            .copied()
            .ok_or_else(|| EvalError::Unsupported("empty catalog".into()))?;
        let name = self.project(genome, &cfg)?.name.clone();
        self.verify(&name, &cfg)
    }

    fn measure(&mut self, genome: &KernelGenome, cfg: &GemmConfig) -> Result<f64, EvalError> {
        let name = self.project(genome, cfg)?.name.clone();
        self.time_entry(&name, cfg)
    }

    fn submission_cost_s(&self) -> f64 {
        5.0 // local testbed turnaround, not the competition queue
    }
}

// PJRT integration tests live in tests/pjrt_roundtrip.rs (they need the
// artifacts directory); catalog parsing tests are in catalog.rs.
