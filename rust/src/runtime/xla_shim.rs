//! In-tree stand-in for the `xla` crate's PJRT surface (DESIGN.md §5).
//!
//! The offline workspace cannot vendor the real `xla` crate, so this
//! module mirrors the exact API slice [`super::PjrtBackend`] uses —
//! same type names, same signatures — behind `use self::xla_shim as
//! xla` in `runtime::mod`. Every entry point compiles; at runtime the
//! first call `PjrtBackend::open` makes ([`PjRtClient::cpu`]) returns
//! a clear "PJRT unavailable" error, which the backend surfaces as
//! `EvalError::Unsupported`. The PJRT integration tests already skip
//! when `artifacts/` is absent, so `cargo test` stays green on a fresh
//! checkout.
//!
//! To run real compiled artifacts: add the `xla` dependency to
//! `rust/Cargo.toml`, delete this module, and drop the alias — no call
//! site changes.

use std::fmt;

/// Error type mirroring the crate's (call sites only format it).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT unavailable: this offline build ships the in-tree xla shim; \
         add the real `xla` crate to rust/Cargo.toml to load compiled artifacts"
            .into(),
    ))
}

/// Host literal (dense array) stand-in.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module stand-in.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Computation wrapper stand-in.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer stand-in.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable stand-in.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Clone>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client stand-in: construction fails with the shim notice.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_shim() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla shim"), "{err}");
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
        assert_eq!(l.reshape(&[3, 2]).unwrap().dims(), &[3, 2]);
    }
}
