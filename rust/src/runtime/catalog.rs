//! The AOT artifact catalog: `artifacts/catalog.json` parsing and the
//! genome -> compiled-variant projection.
//!
//! The python side (`python/compile/aot.py`) writes one entry per
//! (variant, shape): the HLO text file name, the `GemmVariant` fields,
//! and the VMEM footprint estimate. The rust side never re-derives
//! variant semantics — the catalog is the single source of truth for
//! what was compiled.

use std::collections::BTreeSet;
use std::path::Path;

use crate::genome::{KernelGenome, ScaleCache};
use crate::util::json;
use crate::workload::GemmConfig;

/// One compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct CatalogEntry {
    pub name: String,
    /// "reference" (library path) or "pallas" (kernel variant).
    pub kind: String,
    pub cfg: GemmConfig,
    /// Pallas variant parameters (None for reference entries).
    pub variant: Option<VariantParams>,
    /// VMEM footprint estimate from the python layer (bytes).
    pub vmem_bytes: Option<u64>,
    /// HLO text file name, relative to the artifact dir.
    pub artifact: String,
}

/// The python `GemmVariant` fields (the genome projection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VariantParams {
    pub block_m: u32,
    pub block_n: u32,
    pub block_k: u32,
    pub fuse_scales: bool,
    pub acc_in_scratch: bool,
    pub k_innermost: bool,
}

/// The structural key a genome projects onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VariantKey {
    pub block_m: u32,
    pub block_n: u32,
    pub block_k: u32,
    pub fuse_scales: bool,
    pub acc_in_scratch: bool,
    pub k_innermost: bool,
}

impl VariantKey {
    /// Project a full genome onto the Pallas-expressible axes:
    /// * tile sizes map directly;
    /// * fused scaling corresponds to any cached-scale epilogue
    ///   (`ScaleCache::Lds`/`LdsRepurposed`), unfused to global reload;
    /// * the scratch accumulator corresponds to `acc_in_regs`;
    /// * loop order maps directly.
    pub fn from_genome(g: &KernelGenome) -> VariantKey {
        VariantKey {
            block_m: g.block_m,
            block_n: g.block_n,
            block_k: g.block_k,
            fuse_scales: g.scale_cache != ScaleCache::GlobalReload,
            acc_in_scratch: g.acc_in_regs,
            k_innermost: g.k_innermost,
        }
    }

    fn matches(&self, v: &VariantParams) -> bool {
        self.block_m == v.block_m
            && self.block_n == v.block_n
            && self.block_k == v.block_k
            && self.fuse_scales == v.fuse_scales
            && self.acc_in_scratch == v.acc_in_scratch
            && self.k_innermost == v.k_innermost
    }

    /// Log-space tile distance (for nearest-variant fallback).
    fn tile_distance(&self, v: &VariantParams) -> f64 {
        let d = |a: u32, b: u32| ((a as f64).ln() - (b as f64).ln()).abs();
        d(self.block_m, v.block_m) + d(self.block_n, v.block_n) + d(self.block_k, v.block_k)
            + if self.fuse_scales != v.fuse_scales { 0.1 } else { 0.0 }
            + if self.acc_in_scratch != v.acc_in_scratch { 0.1 } else { 0.0 }
            + if self.k_innermost != v.k_innermost { 0.1 } else { 0.0 }
    }
}

/// The parsed catalog.
#[derive(Debug, Clone, Default)]
pub struct Catalog {
    pub entries: Vec<CatalogEntry>,
}

impl Catalog {
    pub fn parse(text: &str) -> Result<Catalog, String> {
        let doc = json::parse(text).map_err(|e| e.to_string())?;
        let version = doc.get("version").and_then(|v| v.as_u64()).unwrap_or(0);
        if version != 1 {
            return Err(format!("unsupported catalog version {version}"));
        }
        let mut entries = Vec::new();
        for e in doc
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or("missing entries")?
        {
            let get_str = |k: &str| {
                e.get(k)
                    .and_then(|v| v.as_str())
                    .map(String::from)
                    .ok_or_else(|| format!("entry missing {k}"))
            };
            let get_u32 = |k: &str| {
                e.get(k)
                    .and_then(|v| v.as_u64())
                    .map(|v| v as u32)
                    .ok_or_else(|| format!("entry missing {k}"))
            };
            let variant = match e.get("variant") {
                Some(v) if !v.is_null() => {
                    let vb = |k: &str| {
                        v.get(k)
                            .and_then(|x| x.as_bool())
                            .ok_or_else(|| format!("variant missing {k}"))
                    };
                    let vu = |k: &str| {
                        v.get(k)
                            .and_then(|x| x.as_u64())
                            .map(|x| x as u32)
                            .ok_or_else(|| format!("variant missing {k}"))
                    };
                    Some(VariantParams {
                        block_m: vu("block_m")?,
                        block_n: vu("block_n")?,
                        block_k: vu("block_k")?,
                        fuse_scales: vb("fuse_scales")?,
                        acc_in_scratch: vb("acc_in_scratch")?,
                        k_innermost: vb("k_innermost")?,
                    })
                }
                _ => None,
            };
            entries.push(CatalogEntry {
                name: get_str("name")?,
                kind: get_str("kind")?,
                cfg: GemmConfig::new(get_u32("m")?, get_u32("k")?, get_u32("n")?),
                variant,
                vmem_bytes: e.get("vmem_bytes").and_then(|v| v.as_u64()),
                artifact: get_str("artifact")?,
            });
        }
        Ok(Catalog { entries })
    }

    pub fn load(path: &Path) -> Result<Catalog, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e} (run `make artifacts` first)", path.display()))?;
        Catalog::parse(&text)
    }

    pub fn by_name(&self, name: &str) -> Option<&CatalogEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Distinct shapes covered by the catalog.
    pub fn shapes(&self) -> Vec<GemmConfig> {
        let set: BTreeSet<(u32, u32, u32)> = self
            .entries
            .iter()
            .map(|e| (e.cfg.m, e.cfg.k, e.cfg.n))
            .collect();
        set.into_iter()
            .map(|(m, k, n)| GemmConfig::new(m, k, n))
            .collect()
    }

    /// The reference (library) artifact for a shape.
    pub fn reference_for(&self, cfg: &GemmConfig) -> Option<&CatalogEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "reference" && e.cfg == *cfg)
    }

    /// All pallas variants for a shape.
    pub fn variants_for(&self, cfg: &GemmConfig) -> Vec<&CatalogEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == "pallas" && e.cfg == *cfg)
            .collect()
    }

    /// Find the compiled variant for a projection key at a shape:
    /// exact match first, then the nearest compiled tile configuration
    /// (the CPU testbed quantizes tile sizes to the compiled set —
    /// documented in DESIGN.md §2).
    pub fn lookup(&self, key: &VariantKey, cfg: &GemmConfig) -> Option<&CatalogEntry> {
        let variants = self.variants_for(cfg);
        if variants.is_empty() {
            return None;
        }
        if let Some(exact) = variants
            .iter()
            .find(|e| e.variant.map(|v| key.matches(&v)).unwrap_or(false))
        {
            return Some(exact);
        }
        variants
            .into_iter()
            .min_by(|a, b| {
                let da = key.tile_distance(&a.variant.unwrap());
                let db = key.tile_distance(&b.variant.unwrap());
                da.total_cmp(&db)
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;

    const SAMPLE: &str = r#"{
        "version": 1,
        "entries": [
            {"name": "ref_m256k256n256", "kind": "reference",
             "m": 256, "k": 256, "n": 256, "variant": null,
             "artifact": "ref_m256k256n256.hlo.txt", "sha256": "x"},
            {"name": "g64x64x64_fs_sc_ki_m256k256n256", "kind": "pallas",
             "m": 256, "k": 256, "n": 256,
             "variant": {"block_m": 64, "block_n": 64, "block_k": 64,
                          "fuse_scales": true, "acc_in_scratch": true,
                          "k_innermost": true},
             "vmem_bytes": 41472,
             "artifact": "g64.hlo.txt", "sha256": "y"},
            {"name": "g128x128x64_fs_sc_ki_m256k256n256", "kind": "pallas",
             "m": 256, "k": 256, "n": 256,
             "variant": {"block_m": 128, "block_n": 128, "block_k": 64,
                          "fuse_scales": true, "acc_in_scratch": true,
                          "k_innermost": true},
             "vmem_bytes": 115200,
             "artifact": "g128.hlo.txt", "sha256": "z"}
        ]
    }"#;

    #[test]
    fn parse_sample() {
        let c = Catalog::parse(SAMPLE).unwrap();
        assert_eq!(c.entries.len(), 3);
        assert_eq!(c.shapes(), vec![GemmConfig::new(256, 256, 256)]);
        let cfg = GemmConfig::new(256, 256, 256);
        assert!(c.reference_for(&cfg).is_some());
        assert_eq!(c.variants_for(&cfg).len(), 2);
        assert_eq!(
            c.by_name("g64x64x64_fs_sc_ki_m256k256n256")
                .unwrap()
                .vmem_bytes,
            Some(41472)
        );
    }

    #[test]
    fn exact_lookup() {
        let c = Catalog::parse(SAMPLE).unwrap();
        let key = VariantKey {
            block_m: 128,
            block_n: 128,
            block_k: 64,
            fuse_scales: true,
            acc_in_scratch: true,
            k_innermost: true,
        };
        let hit = c.lookup(&key, &GemmConfig::new(256, 256, 256)).unwrap();
        assert_eq!(hit.name, "g128x128x64_fs_sc_ki_m256k256n256");
    }

    #[test]
    fn nearest_lookup_quantizes_tiles() {
        let c = Catalog::parse(SAMPLE).unwrap();
        let key = VariantKey {
            block_m: 32, // not compiled; nearest is 64
            block_n: 64,
            block_k: 64,
            fuse_scales: true,
            acc_in_scratch: true,
            k_innermost: true,
        };
        let hit = c.lookup(&key, &GemmConfig::new(256, 256, 256)).unwrap();
        assert_eq!(hit.name, "g64x64x64_fs_sc_ki_m256k256n256");
    }

    #[test]
    fn lookup_missing_shape_is_none() {
        let c = Catalog::parse(SAMPLE).unwrap();
        let key = VariantKey::from_genome(&seeds::human_oracle());
        assert!(c.lookup(&key, &GemmConfig::new(512, 512, 512)).is_none());
    }

    #[test]
    fn genome_projection_maps_scale_cache() {
        let mut g = seeds::human_oracle();
        g.scale_cache = ScaleCache::GlobalReload;
        assert!(!VariantKey::from_genome(&g).fuse_scales);
        g.scale_cache = ScaleCache::LdsRepurposed;
        assert!(VariantKey::from_genome(&g).fuse_scales);
    }

    #[test]
    fn bad_version_rejected() {
        assert!(Catalog::parse(r#"{"version": 2, "entries": []}"#).is_err());
    }

    #[test]
    fn parses_real_catalog_if_present() {
        // integration-flavoured: only runs when `make artifacts` has run
        let path = std::path::Path::new("artifacts/catalog.json");
        if path.exists() {
            let c = Catalog::load(path).unwrap();
            assert!(c.entries.len() >= 10);
            assert!(!c.shapes().is_empty());
            for s in c.shapes() {
                assert!(c.reference_for(&s).is_some(), "no reference for {s}");
                assert!(!c.variants_for(&s).is_empty());
            }
        }
    }
}
