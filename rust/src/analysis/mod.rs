//! Static kernel analysis: the deterministic diagnostic engine
//! (DESIGN.md §13).
//!
//! The paper's loop pays an external evaluation for every hypothesis,
//! so any verdict the system can derive *statically* is free quota.
//! [`lint`] checks a [`KernelGenome`] against the architecture
//! constants and the workload's compile gate and returns a
//! stable-ordered list of [`Diagnostic`]s:
//!
//! * [`Severity::Error`] — the genome cannot run. Errors are produced
//!   *by construction* from [`KernelGenome::validate`] and
//!   [`crate::workload::Workload::admits`]: the engine calls them and
//!   re-emits their verdicts under stable lint codes
//!   ([`crate::genome::Invalid::code`]), so the lint-`Error` set
//!   provably equals the validate∪admits reject set
//!   (`tests/prop_invariants.rs` locks the equivalence).
//! * [`Severity::Warn`] — legal but statically doomed: LDS budget
//!   driving occupancy to the floor, MFMA fragment-shape mismatch,
//!   tiles that do not divide the problem shape, register-spill
//!   estimates, vector widths fighting coalescing ([`warnings`]).
//!
//! Each diagnostic names the profile [`Bottleneck`] component it
//! attacks, which is what lets `[lint] guided` steer the designer's
//! avenue priors through the existing [`crate::agents::knowledge::
//! Avenue::attacks`] mapping.
//!
//! Purity contract (same standing as `sim::profile`): a diagnostic
//! list is a pure function of (genome, arch, workload) — no RNG draw,
//! no clock, no allocation-order dependence — so linting can never
//! perturb a measurement stream or trajectory. The `[lint]` knobs only
//! gate what *acts* on diagnostics, never what they contain.

pub mod warnings;

use crate::genome::KernelGenome;
use crate::gpu::GpuArch;
use crate::sim::Bottleneck;
use crate::util::json::{push_str_value, req_str, Json};
use crate::workload::Workload;

/// Lint code of the workload compile-gate rejection
/// ([`crate::workload::Workload::admits`] `Err`) — the one `Error`
/// that does not originate in [`crate::genome::Invalid`].
pub const ADMITS_CODE: &str = "L030-workload-inadmissible";

/// How bad a diagnostic is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Severity {
    /// The genome cannot compile/launch (or the workload's compile
    /// gate rejects it). Exactly the `validate`/`admits` verdicts.
    Error,
    /// Legal, but statically predicted to waste a lane.
    Warn,
}

impl Severity {
    /// Stable wire tag (journal / CLI / report).
    pub fn tag(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
        }
    }

    /// Decode a [`Severity::tag`].
    pub fn from_tag(s: &str) -> Result<Severity, String> {
        match s {
            "error" => Ok(Severity::Error),
            "warn" => Ok(Severity::Warn),
            other => Err(format!("unknown severity '{other}'")),
        }
    }
}

/// One static finding about a genome.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Stable lint code (e.g. `L001-lds-over-budget`). Wire format:
    /// never renumber an existing code.
    pub code: String,
    pub severity: Severity,
    /// Human message (CLI `lint`, reports, journal reject records).
    pub message: String,
    /// The profile cost component this finding concerns — the hook
    /// `[lint] guided` boosts designer avenues through
    /// [`crate::agents::knowledge::Avenue::attacks`].
    pub attacks: Bottleneck,
}

impl Diagnostic {
    fn new(
        code: &str,
        severity: Severity,
        message: String,
        attacks: Bottleneck,
    ) -> Diagnostic {
        Diagnostic {
            code: code.to_string(),
            severity,
            message,
            attacks,
        }
    }

    /// One-line rendering: `error L001-lds-over-budget [lds]: ...`.
    pub fn render(&self) -> String {
        format!(
            "{} {} [{}]: {}",
            self.severity.tag(),
            self.code,
            self.attacks.tag(),
            self.message
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("attacks", Json::Str(self.attacks.tag().to_string())),
            ("code", Json::Str(self.code.clone())),
            ("message", Json::Str(self.message.clone())),
            ("severity", Json::Str(self.severity.tag().to_string())),
        ])
    }

    /// Streamed emission, byte-identical to `to_json().to_string()`
    /// (keys in alphabetical order).
    pub fn write_json(&self, out: &mut String) {
        out.push_str("{\"attacks\":");
        push_str_value(out, self.attacks.tag());
        out.push_str(",\"code\":");
        push_str_value(out, &self.code);
        out.push_str(",\"message\":");
        push_str_value(out, &self.message);
        out.push_str(",\"severity\":");
        push_str_value(out, self.severity.tag());
        out.push('}');
    }

    pub fn from_json(v: &Json) -> Result<Diagnostic, String> {
        Ok(Diagnostic {
            code: req_str(v, "code")?.to_string(),
            severity: Severity::from_tag(req_str(v, "severity")?)?,
            message: req_str(v, "message")?.to_string(),
            attacks: Bottleneck::from_tag(req_str(v, "attacks")?)?,
        })
    }
}

/// The profile component a [`crate::genome::Invalid`] rejection
/// concerns, keyed on its stable code. Digested knowledge, same
/// standing as [`crate::agents::knowledge::Avenue::attacks`].
fn invalid_attacks(e: &crate::genome::Invalid) -> Bottleneck {
    use crate::genome::Invalid;
    match e {
        Invalid::LdsOverflow { .. } => Bottleneck::Lds,
        Invalid::RegisterOverflow { .. } => Bottleneck::Compute,
        Invalid::NonPow2Block(..) | Invalid::BlockOutOfRange(..) => Bottleneck::Occupancy,
        Invalid::BadUnroll(_) => Bottleneck::Compute,
        Invalid::BadVectorWidth(_) => Bottleneck::Memory,
        Invalid::BadWaves(_) | Invalid::TooManyLanes(_) => Bottleneck::Occupancy,
        Invalid::DoubleBufferWithoutStaging | Invalid::ScaleLdsWithoutStaging => {
            Bottleneck::Memory
        }
        Invalid::SwizzleWithPadding => Bottleneck::Lds,
        Invalid::MfmaRequiresLowPrecision => Bottleneck::Compute,
    }
}

/// Lint a genome against an architecture and a workload.
///
/// Stable order: the `validate` error (first-failure, exactly as
/// [`KernelGenome::validate`] reports it), then the `admits` error,
/// then warnings in ascending code order. Deterministic and pure — the
/// same inputs always produce the byte-identical list.
pub fn lint(g: &KernelGenome, arch: &GpuArch, workload: &dyn Workload) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    if let Err(e) = g.validate() {
        out.push(Diagnostic::new(
            e.code(),
            Severity::Error,
            e.to_string(),
            invalid_attacks(&e),
        ));
    }
    if let Err(msg) = workload.admits(g) {
        out.push(Diagnostic::new(
            ADMITS_CODE,
            Severity::Error,
            msg,
            Bottleneck::Compute,
        ));
    }
    warnings::collect(g, arch, workload, &mut out);
    out
}

/// Does the genome carry at least one `Error` diagnostic? Equivalent
/// to `validate().is_err() || admits(g).is_err()` by construction —
/// the schedulers' pre-submission gate.
pub fn has_error(diags: &[Diagnostic]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

/// Codes of the `Error` diagnostics, in diagnostic order (journal
/// reject records carry these).
pub fn error_codes(diags: &[Diagnostic]) -> Vec<String> {
    diags
        .iter()
        .filter(|d| d.severity == Severity::Error)
        .map(|d| d.code.clone())
        .collect()
}

/// The bottleneck set `[lint] guided` feeds the designer: the base
/// genome's *warning* components plus the *error* components of its
/// statically doomed children (`siblings` — the already-failed
/// offspring of the same base). Returned deduplicated in
/// [`Bottleneck::ALL`] order, so the prior boost is a pure function of
/// the population — no stored state, which is what keeps resume exact.
pub fn guided_attacks<'a>(
    base: &KernelGenome,
    siblings: impl Iterator<Item = &'a KernelGenome>,
    arch: &GpuArch,
    workload: &dyn Workload,
) -> Vec<Bottleneck> {
    let mut hit = [false; 5];
    for d in lint(base, arch, workload) {
        if d.severity == Severity::Warn {
            hit[d.attacks.index()] = true;
        }
    }
    for s in siblings {
        for d in lint(s, arch, workload) {
            if d.severity == Severity::Error {
                hit[d.attacks.index()] = true;
            }
        }
    }
    Bottleneck::ALL
        .iter()
        .copied()
        .filter(|b| hit[b.index()])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, ComputePath, KernelGenome, Precision, ScaleCache};
    use crate::gpu::MI300;
    use crate::workload;

    fn lint_default(g: &KernelGenome) -> Vec<Diagnostic> {
        lint(g, &MI300, workload::default_workload().as_ref())
    }

    #[test]
    fn valid_seed_has_no_errors() {
        for (name, g) in seeds::all_seeds() {
            let diags = lint_default(&g);
            assert!(!has_error(&diags), "{name}: {diags:?}");
        }
    }

    #[test]
    fn validate_error_is_reemitted_under_its_code() {
        let g = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        let diags = lint_default(&g);
        assert!(has_error(&diags));
        let err = &diags[0];
        assert_eq!(err.severity, Severity::Error);
        assert_eq!(err.code, g.validate().unwrap_err().code());
        assert_eq!(err.message, g.validate().unwrap_err().to_string());
    }

    #[test]
    fn admits_rejection_is_an_error_with_the_admits_code() {
        let w = workload::lookup("bf16-gemm").unwrap();
        let g = seeds::human_oracle(); // fp8 operands: inadmissible
        assert!(g.validate().is_ok() && w.admits(&g).is_err());
        let diags = lint(&g, &MI300, w.as_ref());
        assert!(has_error(&diags));
        assert_eq!(diags[0].code, ADMITS_CODE);
        assert_eq!(error_codes(&diags), vec![ADMITS_CODE.to_string()]);
    }

    #[test]
    fn diagnostics_are_deterministic_and_stably_ordered() {
        for (_, g) in seeds::all_seeds() {
            let a = lint_default(&g);
            let b = lint_default(&g);
            assert_eq!(a, b);
            // errors strictly precede warnings
            let first_warn = a.iter().position(|d| d.severity == Severity::Warn);
            if let Some(i) = first_warn {
                assert!(a[i..].iter().all(|d| d.severity == Severity::Warn));
            }
            // warnings ascend by code
            let warn_codes: Vec<&str> = a
                .iter()
                .filter(|d| d.severity == Severity::Warn)
                .map(|d| d.code.as_str())
                .collect();
            let mut sorted = warn_codes.clone();
            sorted.sort_unstable();
            assert_eq!(warn_codes, sorted);
        }
    }

    #[test]
    fn json_roundtrip_lossless_and_streaming_matches() {
        let doomed = KernelGenome {
            compute: ComputePath::Mfma,
            precision: Precision::Fp32,
            ..seeds::mfma_seed()
        };
        for g in [seeds::naive_hip(), seeds::human_oracle(), doomed] {
            for d in lint_default(&g) {
                let emitted = d.to_json().to_string();
                let mut streamed = String::new();
                d.write_json(&mut streamed);
                assert_eq!(streamed, emitted, "streamed == tree emitter");
                let back =
                    Diagnostic::from_json(&crate::util::json::parse(&emitted).unwrap())
                        .unwrap();
                assert_eq!(back, d);
            }
        }
    }

    #[test]
    fn guided_attacks_collects_base_warns_and_sibling_errors() {
        let w = workload::default_workload();
        // a base with a known warning: direct-from-global narrow loads
        let base = KernelGenome {
            lds_staging: false,
            double_buffer: false,
            scale_cache: ScaleCache::GlobalReload,
            vector_width: 1,
            ..seeds::naive_hip()
        };
        let warn_attacks: Vec<Bottleneck> = lint(&base, &MI300, w.as_ref())
            .into_iter()
            .filter(|d| d.severity == Severity::Warn)
            .map(|d| d.attacks)
            .collect();
        assert!(warn_attacks.contains(&Bottleneck::Memory), "{warn_attacks:?}");
        // a sibling killed by the LDS budget
        let sibling = KernelGenome {
            block_m: 256,
            block_n: 256,
            block_k: 256,
            lds_staging: true,
            double_buffer: true,
            precision: Precision::Fp32,
            compute: ComputePath::Vectorized,
            acc_in_regs: false,
            waves_per_block: 8,
            ..seeds::naive_hip()
        };
        assert!(sibling.validate().is_err());
        let got = guided_attacks(&base, std::iter::once(&sibling), &MI300, w.as_ref());
        assert!(got.contains(&Bottleneck::Memory), "{got:?}");
        assert!(got.contains(&Bottleneck::Lds), "{got:?}");
        // dedup + ALL order
        let idx: Vec<usize> = got.iter().map(|b| b.index()).collect();
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(idx, sorted);
        // no siblings, clean base ⇒ pure function of the base's warnings
        let clean = guided_attacks(&base, std::iter::empty(), &MI300, w.as_ref());
        assert_eq!(
            clean,
            Bottleneck::ALL
                .iter()
                .copied()
                .filter(|b| warn_attacks.contains(b))
                .collect::<Vec<_>>()
        );
    }
}
