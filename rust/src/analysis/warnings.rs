//! Warn-severity lint rules: legal genomes the analyzer statically
//! predicts to waste a lane (DESIGN.md §13).
//!
//! Every rule is a pure predicate over (genome, arch, workload) with a
//! stable `W1xx` code, emitted in ascending code order. Warnings never
//! gate submission — they exist for the `lint` CLI, the report layer,
//! and the `[lint] guided` designer prior.

use crate::genome::{limits, ComputePath, KernelGenome};
use crate::gpu::{occupancy, GpuArch};
use crate::sim::Bottleneck;
use crate::workload::Workload;

use super::{Diagnostic, Severity};

/// The MFMA fragment shape the MI300 path issues (32x32x16): tiles
/// that do not tile the fragment leave matrix-pipe lanes idle.
pub const MFMA_M: u32 = 32;
pub const MFMA_N: u32 = 32;
pub const MFMA_K: u32 = 16;

/// Register-pressure share of the budget above which spills are
/// likely enough to flag (the compiler's effective ceiling sits well
/// below the architectural limit).
pub const SPILL_SHARE: f64 = 0.5;

/// Global-load width (bytes/lane) below which un-staged loads cannot
/// form coalesced transactions.
pub const COALESCE_MIN_WIDTH: u32 = 4;

fn push(
    out: &mut Vec<Diagnostic>,
    code: &'static str,
    message: String,
    attacks: Bottleneck,
) {
    out.push(Diagnostic {
        code: code.to_string(),
        severity: Severity::Warn,
        message,
        attacks,
    });
}

/// Append every firing warn rule to `out`, in ascending code order.
/// Rules assume the genome already passed `validate`/`admits`; they
/// still guard degenerate inputs (zero fields) so randomized-genome
/// property tests cannot panic the analyzer.
pub fn collect(
    g: &KernelGenome,
    arch: &GpuArch,
    workload: &dyn Workload,
    out: &mut Vec<Diagnostic>,
) {
    // W101: the LDS budget pins one workgroup per CU — occupancy at
    // the floor, so no latency hiding regardless of tile quality.
    if g.lds_staging {
        let occ = occupancy::occupancy(arch, g);
        if occ.limiter == "lds" && occ.workgroups_per_cu <= 1 {
            push(
                out,
                "W101-lds-occupancy-floor",
                format!(
                    "LDS use of {} B caps residency at {} workgroup/CU \
                     ({} waves): occupancy at the floor",
                    g.lds_bytes(),
                    occ.workgroups_per_cu,
                    occ.waves_per_cu
                ),
                Bottleneck::Occupancy,
            );
        }
    }

    // W102: tile shape does not tile the 32x32x16 MFMA fragment —
    // matrix-pipe lanes idle on every issue.
    if g.compute == ComputePath::Mfma
        && (g.block_m % MFMA_M != 0 || g.block_n % MFMA_N != 0 || g.block_k % MFMA_K != 0)
    {
        push(
            out,
            "W102-mfma-fragment-mismatch",
            format!(
                "tile {}x{}x{} does not tile the {MFMA_M}x{MFMA_N}x{MFMA_K} \
                 MFMA fragment",
                g.block_m, g.block_n, g.block_k
            ),
            Bottleneck::Compute,
        );
    }

    // W103: the tile does not divide some feedback-suite problem
    // shape — partial edge tiles serialize the grid tail.
    let ragged: Vec<String> = workload
        .feedback_suite()
        .configs
        .iter()
        .filter(|c| {
            (g.block_m > 0 && c.m % g.block_m != 0)
                || (g.block_n > 0 && c.n % g.block_n != 0)
                || (g.block_k > 0 && c.k % g.block_k != 0)
        })
        .map(|c| c.to_string())
        .collect();
    if !ragged.is_empty() {
        push(
            out,
            "W103-tile-does-not-divide-problem",
            format!(
                "tile {}x{}x{} leaves partial edge tiles on {} of {} \
                 feedback shapes (first: {})",
                g.block_m,
                g.block_n,
                g.block_k,
                ragged.len(),
                workload.feedback_suite().configs.len(),
                ragged[0]
            ),
            Bottleneck::Occupancy,
        );
    }

    // W104: register pressure deep into the budget — the compiler
    // will start spilling to scratch long before the hard cap.
    let vgprs = g.vgprs_per_lane();
    if (vgprs as f64) > SPILL_SHARE * limits::VGPRS_PER_LANE as f64
        && vgprs <= limits::VGPRS_PER_LANE
    {
        push(
            out,
            "W104-register-spill-risk",
            format!(
                "estimated {vgprs} VGPRs/lane exceeds {:.0}% of the {}-register \
                 budget: spill risk",
                SPILL_SHARE * 100.0,
                limits::VGPRS_PER_LANE
            ),
            Bottleneck::Compute,
        );
    }

    // W105: narrow un-staged global loads cannot coalesce — each wave
    // issues strided sub-transaction traffic straight at HBM.
    if !g.lds_staging && g.vector_width < COALESCE_MIN_WIDTH {
        push(
            out,
            "W105-vector-width-fights-coalescing",
            format!(
                "direct-from-global loads at {} B/lane (< {COALESCE_MIN_WIDTH} B) \
                 defeat coalescing without LDS staging",
                g.vector_width
            ),
            Bottleneck::Memory,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lint;
    use crate::genome::{seeds, Precision, ScaleCache, Swizzle, Writeback};
    use crate::gpu::MI300;
    use crate::workload;

    fn codes(g: &KernelGenome) -> Vec<String> {
        lint(g, &MI300, workload::default_workload().as_ref())
            .into_iter()
            .filter(|d| d.severity == Severity::Warn)
            .map(|d| d.code)
            .collect()
    }

    #[test]
    fn lds_occupancy_floor_fires_on_a_maximal_legal_tile() {
        // valid genome whose LDS use pins residency at 1 workgroup/CU
        let g = KernelGenome {
            block_m: 128,
            block_n: 128,
            block_k: 32,
            precision: Precision::Fp32,
            compute: crate::genome::ComputePath::Vectorized,
            lds_staging: true,
            double_buffer: true,
            lds_pad: 0,
            swizzle: Swizzle::None,
            scale_cache: ScaleCache::GlobalReload,
            acc_in_regs: false,
            writeback: Writeback::SingleWave,
            waves_per_block: 2,
            ..seeds::naive_hip()
        };
        assert!(g.validate().is_ok(), "{:?}", g.validate());
        let occ = occupancy::occupancy(&MI300, &g);
        assert_eq!((occ.limiter, occ.workgroups_per_cu), ("lds", 1));
        assert!(codes(&g).contains(&"W101-lds-occupancy-floor".to_string()));
    }

    #[test]
    fn mfma_fragment_mismatch_fires_on_a_16_wide_tile() {
        let g = KernelGenome {
            block_m: 16,
            ..seeds::mfma_seed()
        };
        if g.validate().is_ok() {
            assert!(codes(&g).contains(&"W102-mfma-fragment-mismatch".to_string()));
        }
        // an aligned MFMA tile stays quiet on W102
        let aligned = seeds::mfma_seed();
        assert!(aligned.block_m % MFMA_M == 0 && aligned.block_k % MFMA_K == 0);
        assert!(!codes(&aligned).contains(&"W102-mfma-fragment-mismatch".to_string()));
    }

    #[test]
    fn ragged_tile_flags_the_problem_shapes() {
        // the fp8 feedback suite has k = 512-multiples; block_k = 256
        // divides them all, but a 6144-row shape with block_m = 256
        // leaves no remainder either — force raggedness via block_k
        // against k = 512 with unroll-legal 256? use block_m on m=6144:
        // 6144 % 256 == 0, so pick block_n = 256 against n = 4096 (ok)
        // … the reliable ragged axis is m = 6144 with block_m = 128? no
        // (6144 = 48*128). Use a tile of 64 on k = 512 (divides) — so
        // construct raggedness explicitly with m=6144 % 256 = 0; the
        // suite's ragged pair is block_k=256 vs k=512? also divides.
        // m=6144 vs block_m=... 6144 = 2^11 * 3: any pow2 <= 2048
        // divides it. n=4096, k=512: all pow2 <= 512 divide. The fp8
        // suite is pow2-friendly by construction, so W103 must stay
        // quiet for every seed — that *is* the assertion.
        for (name, g) in seeds::all_seeds() {
            assert!(
                !codes(&g).contains(&"W103-tile-does-not-divide-problem".to_string()),
                "{name}: the fp8 suite is pow2-divisible"
            );
        }
    }

    #[test]
    fn spill_risk_fires_near_the_register_ceiling() {
        let g = KernelGenome {
            block_m: 128,
            block_n: 128,
            waves_per_block: 1,
            acc_in_regs: true,
            lds_staging: false,
            double_buffer: false,
            scale_cache: ScaleCache::GlobalReload,
            ..seeds::naive_hip()
        };
        // 128*128/64 = 256 accumulator registers alone
        assert!(g.validate().is_ok(), "{:?}", g.validate());
        assert!(g.vgprs_per_lane() > 256);
        assert!(codes(&g).contains(&"W104-register-spill-risk".to_string()));
    }

    #[test]
    fn narrow_unstaged_loads_flag_coalescing() {
        let g = KernelGenome {
            lds_staging: false,
            double_buffer: false,
            scale_cache: ScaleCache::GlobalReload,
            vector_width: 1,
            ..seeds::naive_hip()
        };
        assert!(g.validate().is_ok());
        assert!(codes(&g).contains(&"W105-vector-width-fights-coalescing".to_string()));
        let wide = KernelGenome {
            vector_width: 8,
            ..g.clone()
        };
        assert!(!codes(&wide).contains(&"W105-vector-width-fights-coalescing".to_string()));
    }
}
