//! Minimal JSON value type, parser, and emitter.
//!
//! Covers the full JSON grammar we exchange with the python AOT layer
//! (`artifacts/catalog.json`) and use for population persistence and
//! run reports. No external dependencies; strict enough for our own
//! round-trips, permissive on whitespace.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic — required for golden tests and stable fingerprints.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs unsupported (not needed for our data)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":1,"b":[true,null,"s"],"c":{"d":-2.5}}"#,
            r#"[1,2,3]"#,
            r#""escape \" \\ \n""#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\tcA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\tcA"));
        let emitted = Json::Str("x\"y\\z\n".into()).to_string();
        assert_eq!(parse(&emitted).unwrap().as_str(), Some("x\"y\\z\n"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integer_emission_is_integral() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn parses_real_catalog_shape() {
        let doc = r#"{"version": 1, "entries": [{"name": "ref_m64k64n64",
            "kind": "reference", "m": 64, "k": 64, "n": 64,
            "variant": null, "artifact": "ref_m64k64n64.hlo.txt",
            "sha256": "abc"}]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_u64(), Some(1));
        let entries = v.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("kind").unwrap().as_str(), Some("reference"));
        assert!(entries[0].get("variant").unwrap().is_null());
    }
}
