//! Minimal JSON value type, parser, and emitter.
//!
//! Covers the full JSON grammar we exchange with the python AOT layer
//! (`artifacts/catalog.json`) and use for population persistence and
//! run reports. No external dependencies; strict enough for our own
//! round-trips, permissive on whitespace.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic — required for golden tests and stable fingerprints.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => push_num_value(out, *n),
            Json::Str(s) => push_str_value(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Emit a JSON number exactly as [`Json::Num`] would (integral values
/// under 1e15 print without a fraction) — the streaming half of the
/// emitter, shared with the run-store's allocation-free journal writer
/// (§Perf). Writes via `fmt::Write`, no intermediate `String`.
pub fn push_num_value(out: &mut String, n: f64) {
    use std::fmt::Write as _;
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

/// Emit a JSON string literal (quotes + escapes) exactly as
/// [`Json::Str`] would — see [`push_num_value`].
pub fn push_str_value(out: &mut String, s: &str) {
    use std::fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Encode a full-width u64 as a hex string value. [`Json::Num`] is
/// f64-backed and loses integer precision past 2^53, so RNG state
/// words and other full-range u64s travel as 16-digit hex strings.
pub fn u64_hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// Decode a [`u64_hex`] value. Strict: hex digits only (from_str_radix
/// alone would accept a leading '+').
pub fn parse_u64_hex(v: &Json) -> Result<u64, String> {
    let s = v.as_str().ok_or("expected hex string")?;
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(format!("bad hex u64 '{s}'"));
    }
    u64::from_str_radix(s, 16).map_err(|_| format!("bad hex u64 '{s}'"))
}

/// Encode a string list (shared by the run-store serializers).
pub fn str_arr(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
}

/// Decode a [`str_arr`] value; `what` names the field in errors.
pub fn parse_str_arr(v: Option<&Json>, what: &str) -> Result<Vec<String>, String> {
    v.and_then(|x| x.as_arr())
        .ok_or_else(|| format!("missing {what}"))?
        .iter()
        .map(|s| {
            s.as_str()
                .map(String::from)
                .ok_or_else(|| format!("non-string {what} entry"))
        })
        .collect()
}

/// Required-field accessors over an object value, erroring with the
/// field name — the shared vocabulary of the run-store parsers.
pub fn req_u64(v: &Json, k: &str) -> Result<u64, String> {
    v.get(k)
        .and_then(|x| x.as_u64())
        .ok_or_else(|| format!("missing/invalid {k}"))
}

/// See [`req_u64`].
pub fn req_f64(v: &Json, k: &str) -> Result<f64, String> {
    v.get(k)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| format!("missing/invalid {k}"))
}

/// See [`req_u64`].
pub fn req_str<'a>(v: &'a Json, k: &str) -> Result<&'a str, String> {
    v.get(k)
        .and_then(|x| x.as_str())
        .ok_or_else(|| format!("missing/invalid {k}"))
}

/// See [`req_u64`].
pub fn req_bool(v: &Json, k: &str) -> Result<bool, String> {
    v.get(k)
        .and_then(|x| x.as_bool())
        .ok_or_else(|| format!("missing/invalid {k}"))
}

/// Parse error with byte position.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4(self.pos + 1)?;
                            if (0xD800..=0xDBFF).contains(&cp) {
                                // High surrogate: per RFC 8259 §7 a
                                // low-surrogate escape must follow, and
                                // the pair decodes to one supplementary
                                // scalar (non-BMP text round-trips
                                // instead of collapsing to U+FFFD).
                                if self.bytes.get(self.pos + 5) != Some(&b'\\')
                                    || self.bytes.get(self.pos + 6) != Some(&b'u')
                                {
                                    return Err(self.err(
                                        "lone high surrogate (expected \\u low surrogate)",
                                    ));
                                }
                                let lo = self.hex4(self.pos + 7)?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let scalar =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                let c = char::from_u32(scalar)
                                    .ok_or_else(|| self.err("bad surrogate pair"))?;
                                s.push(c);
                                self.pos += 10;
                            } else if (0xDC00..=0xDFFF).contains(&cp) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                // every non-surrogate BMP code point is
                                // a valid scalar value
                                s.push(char::from_u32(cp).expect("non-surrogate scalar"));
                                self.pos += 4;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    /// Four hex digits starting at byte `at` (the XXXX of a `\uXXXX`).
    /// Strict: from_str_radix alone would accept a leading '+'.
    fn hex4(&self, at: usize) -> Result<u32, ParseError> {
        if at + 4 > self.bytes.len()
            || !self.bytes[at..at + 4].iter().all(|b| b.is_ascii_hexdigit())
        {
            return Err(self.err("bad \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[at..at + 4]).expect("ascii hex");
        u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":1,"b":[true,null,"s"],"c":{"d":-2.5}}"#,
            r#"[1,2,3]"#,
            r#""escape \" \\ \n""#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\tcA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\tcA"));
        let emitted = Json::Str("x\"y\\z\n".into()).to_string();
        assert_eq!(parse(&emitted).unwrap().as_str(), Some("x\"y\\z\n"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
        // non-BMP scalars pass through as raw UTF-8 and round-trip
        let emitted = Json::Str("rationale 😀 𝒳 \u{10ffff}".into()).to_string();
        assert_eq!(
            parse(&emitted).unwrap().as_str(),
            Some("rationale 😀 𝒳 \u{10ffff}")
        );
    }

    #[test]
    fn surrogate_pairs_decode() {
        // U+1F600 as its canonical escaped pair
        assert_eq!(
            parse(r#""\uD83D\uDE00""#).unwrap().as_str(),
            Some("\u{1f600}")
        );
        // pair embedded in surrounding text, lowercase hex
        assert_eq!(
            parse(r#""a\ud835\udcb3b""#).unwrap().as_str(),
            Some("a\u{1d4b3}b")
        );
        // highest scalar value U+10FFFF
        assert_eq!(
            parse(r#""\uDBFF\uDFFF""#).unwrap().as_str(),
            Some("\u{10ffff}")
        );
        // BMP escapes still decode directly
        assert_eq!(parse(r#""\u4e16\u754c""#).unwrap().as_str(), Some("世界"));
    }

    #[test]
    fn lone_surrogates_rejected() {
        // a high surrogate with no continuation, a non-escape after it,
        // a bad low half, and a bare low surrogate are all parse errors
        // (never U+FFFD corruption)
        assert!(parse(r#""\uD83D""#).is_err());
        assert!(parse(r#""\uD83Dx""#).is_err());
        assert!(parse(r#""\uD83DA""#).is_err());
        assert!(parse(r#""\uDE00""#).is_err());
        assert!(parse(r#""\uD83D\uD83D""#).is_err());
        // strict hex: a leading '+' is not a hex digit
        assert!(parse(r#""\u+bcd""#).is_err());
    }

    #[test]
    fn hex_u64_roundtrip_is_strict() {
        assert_eq!(parse_u64_hex(&u64_hex(u64::MAX)).unwrap(), u64::MAX);
        assert_eq!(parse_u64_hex(&u64_hex(0)).unwrap(), 0);
        assert!(parse_u64_hex(&Json::Str("+00000000000000ff".into())).is_err());
        assert!(parse_u64_hex(&Json::Str("".into())).is_err());
        assert!(parse_u64_hex(&Json::Num(5.0)).is_err());
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integer_emission_is_integral() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn parses_real_catalog_shape() {
        let doc = r#"{"version": 1, "entries": [{"name": "ref_m64k64n64",
            "kind": "reference", "m": 64, "k": 64, "n": 64,
            "variant": null, "artifact": "ref_m64k64n64.hlo.txt",
            "sha256": "abc"}]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_u64(), Some(1));
        let entries = v.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("kind").unwrap().as_str(), Some("reference"));
        assert!(entries[0].get("variant").unwrap().is_null());
    }
}
