//! Dependency-free utilities: this build is fully offline (only the
//! `xla` PJRT crate tree is vendored), so JSON, timing helpers, and the
//! bench harness live in-tree.

pub mod bench;
pub mod json;
pub mod timer;
