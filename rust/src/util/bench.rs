//! In-tree benchmark harness (offline build — no criterion).
//!
//! The `benches/*.rs` targets are `harness = false` binaries; they use
//! this module for criterion-flavoured measurement and reporting:
//! warmup, repeated timed samples, mean ± stddev, and a compact table.
//! Paper-reproduction benches additionally print the markdown tables /
//! CSV series that EXPERIMENTS.md records.

use std::time::{Duration, Instant};

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Measure a closure: warmup, then timed samples until `budget` or
/// `max_samples`.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..3 {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && samples.len() < 10_000 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n;
    BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        samples: samples.len(),
    }
}

/// Print a result criterion-style.
pub fn report(r: &BenchResult) {
    println!(
        "{:40} time: [{} ± {}]  ({} samples, {:.0}/s)",
        r.name,
        super::timer::fmt_ns(r.mean_ns),
        super::timer::fmt_ns(r.stddev_ns),
        r.samples,
        r.throughput_per_s()
    );
}

/// Header line for a bench binary.
pub fn header(title: &str) {
    println!("\n=== bench: {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", Duration::from_millis(5), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.samples > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.throughput_per_s() > 0.0);
    }
}
