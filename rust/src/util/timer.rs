//! Wall-clock timing helpers shared by the CLI, examples, and the
//! in-tree bench harness.

use std::time::{Duration, Instant};

/// Time a closure, returning (result, elapsed).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// Run a closure repeatedly until `min_time` has elapsed (and at least
/// `min_iters` times), returning per-iteration statistics in
/// nanoseconds: (mean, stddev, iters).
pub fn measure_ns(
    min_time: Duration,
    min_iters: u64,
    mut f: impl FnMut(),
) -> (f64, f64, u64) {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    let mut iters = 0u64;
    while start.elapsed() < min_time || iters < min_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        iters += 1;
        if iters > 10_000_000 {
            break;
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|s| (s - mean) * (s - mean))
        .sum::<f64>()
        / samples.len() as f64;
    (mean, var.sqrt(), iters)
}

/// Format a nanosecond count human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (v, d) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn measure_ns_runs_min_iters() {
        let mut count = 0u64;
        let (_, _, iters) = measure_ns(Duration::from_millis(1), 10, || {
            count += 1;
        });
        assert!(iters >= 10);
        assert!(count >= iters); // warmup included
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("us"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
