//! Benchmark workloads: the competition's matrix-size configurations.
//!
//! The paper's platform returns timings for **6 specified MxKxN input
//! configurations** per submission (§3.1), while the leaderboard is the
//! **geometric average over 18 specific matrix sizes** (§4.5). The
//! exact size list is not published; we use an LLM-inference-shaped
//! spread (the competition kernel is an inference GEMM) that includes
//! the one size the paper does name, m=6144 k=512 n=4096 (App. A.1).


/// One GEMM problem size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmConfig {
    pub m: u32,
    pub k: u32,
    pub n: u32,
}

impl GemmConfig {
    pub const fn new(m: u32, k: u32, n: u32) -> Self {
        GemmConfig { m, k, n }
    }

    /// Multiply-add count x2.
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }

    /// Operand bytes at a given element size (A + B), one pass.
    pub fn operand_bytes(&self, elt: u32) -> f64 {
        (self.m as f64 * self.k as f64 + self.k as f64 * self.n as f64) * elt as f64
    }

    /// Output bytes (bf16 C).
    pub fn output_bytes(&self) -> f64 {
        self.m as f64 * self.n as f64 * 2.0
    }
}

impl std::fmt::Display for GemmConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "m={} k={} n={}", self.m, self.k, self.n)
    }
}

/// The 18 leaderboard sizes (geomean basis, Table 1).
pub const LEADERBOARD_SIZES: [GemmConfig; 18] = [
    GemmConfig::new(4096, 512, 4096),
    GemmConfig::new(4096, 1024, 4096),
    GemmConfig::new(4096, 2048, 4096),
    GemmConfig::new(4096, 4096, 4096),
    GemmConfig::new(6144, 512, 4096), // named in paper App. A.1
    GemmConfig::new(6144, 1024, 4096),
    GemmConfig::new(6144, 2048, 6144),
    GemmConfig::new(6144, 512, 6144),
    GemmConfig::new(8192, 512, 8192),
    GemmConfig::new(8192, 1024, 8192),
    GemmConfig::new(8192, 2048, 8192),
    GemmConfig::new(8192, 4096, 8192),
    GemmConfig::new(4096, 7168, 4096),
    GemmConfig::new(6144, 7168, 6144),
    GemmConfig::new(8192, 7168, 8192),
    GemmConfig::new(4096, 512, 8192),
    GemmConfig::new(8192, 512, 4096),
    GemmConfig::new(6144, 1024, 8192),
];

/// The 6 per-submission feedback configs (a subset of the leaderboard,
/// spanning the k range and the named paper size).
pub const FEEDBACK_CONFIGS: [GemmConfig; 6] = [
    GemmConfig::new(6144, 512, 4096),
    GemmConfig::new(4096, 1024, 4096),
    GemmConfig::new(4096, 4096, 4096),
    GemmConfig::new(8192, 512, 8192),
    GemmConfig::new(8192, 1024, 8192),
    GemmConfig::new(6144, 2048, 6144),
];

/// A named set of configs — the unit the evaluation platform runs.
#[derive(Debug, Clone)]
pub struct BenchmarkSuite {
    pub name: String,
    pub configs: Vec<GemmConfig>,
}

impl BenchmarkSuite {
    /// The per-submission feedback suite (6 configs).
    pub fn feedback() -> Self {
        BenchmarkSuite {
            name: "feedback-6".into(),
            configs: FEEDBACK_CONFIGS.to_vec(),
        }
    }

    /// The final leaderboard suite (18 sizes).
    pub fn leaderboard() -> Self {
        BenchmarkSuite {
            name: "leaderboard-18".into(),
            configs: LEADERBOARD_SIZES.to_vec(),
        }
    }

    /// Small CPU-testbed suite matching the PJRT artifact catalog
    /// shapes (see `python/compile/aot.py`).
    pub fn testbed() -> Self {
        BenchmarkSuite {
            name: "testbed-pjrt".into(),
            configs: vec![
                GemmConfig::new(256, 256, 256),
                GemmConfig::new(512, 256, 256),
                GemmConfig::new(256, 512, 512),
            ],
        }
    }

    /// Synthetic sweep for ablations: a grid over (m, k, n) decades.
    pub fn synthetic_sweep(points: usize, seed: u64) -> Self {
        let mut rng = crate::rng::Rng::seed_from_u64(seed);
        let dims = [512u32, 1024, 2048, 4096, 6144, 8192];
        let configs = (0..points)
            .map(|_| {
                GemmConfig::new(
                    *rng.choose(&dims),
                    *rng.choose(&dims[..4]),
                    *rng.choose(&dims),
                )
            })
            .collect();
        BenchmarkSuite {
            name: format!("synthetic-{points}"),
            configs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaderboard_has_18_unique_sizes() {
        let mut set = std::collections::HashSet::new();
        for c in LEADERBOARD_SIZES {
            set.insert(c);
        }
        assert_eq!(set.len(), 18);
    }

    #[test]
    fn feedback_is_subset_of_leaderboard() {
        for c in FEEDBACK_CONFIGS {
            assert!(LEADERBOARD_SIZES.contains(&c), "{c} not on leaderboard");
        }
    }

    #[test]
    fn paper_named_size_present() {
        let named = GemmConfig::new(6144, 512, 4096);
        assert!(FEEDBACK_CONFIGS.contains(&named));
        assert!(LEADERBOARD_SIZES.contains(&named));
    }

    #[test]
    fn flops_math() {
        let c = GemmConfig::new(2, 3, 4);
        assert_eq!(c.flops(), 48.0);
        assert_eq!(c.operand_bytes(1), 18.0);
        assert_eq!(c.output_bytes(), 16.0);
    }

    #[test]
    fn synthetic_sweep_deterministic() {
        let a = BenchmarkSuite::synthetic_sweep(10, 7);
        let b = BenchmarkSuite::synthetic_sweep(10, 7);
        assert_eq!(a.configs, b.configs);
    }
}
