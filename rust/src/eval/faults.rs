//! Deterministic fault injection and recovery state (DESIGN.md §14).
//!
//! Real evaluation services time out, drop submissions, straggle, and
//! return outlier timings; the paper's scientist steers on "only
//! observed timing data" from exactly such a service. This module
//! models that flakiness **deterministically**: a [`FaultyBackend`]
//! decorator over any [`EvalBackend`] decides, per dispatch, whether
//! the evaluation suffers a transient error, a straggler latency
//! multiplier, corrupted timings, or permanent lane death — and the
//! platform's recovery layer ([`FaultState`]) tracks per-lane health,
//! quarantine, and the retry/requeue bookkeeping the schedulers
//! journal.
//!
//! Determinism contract (the chaos-run analog of `sim/mod.rs`'s noise
//! stream): every fault decision is drawn from a **fresh per-dispatch
//! RNG** seeded by `fault_seed ⊕ mix(fingerprint) ⊕ mix(attempt)` —
//! the fault-model fork of the run seed, re-forked per dispatch the
//! way the simulator forks its noise stream per lane. The draw is a
//! pure function of (seed, genome, attempt): independent of dispatch
//! order, of resume points, and of how many other dispatches happened
//! first. Disabled, the decorator is pure delegation — zero RNG draws,
//! zero extra state — which is what the off-means-off bit-identity
//! guarantee rests on.
//!
//! In-flight aliasing note: both schedulers reserve fingerprints so a
//! genome is never in flight twice; a fault-class outcome is therefore
//! never the target of an in-flight alias (fault outcomes are excluded
//! from the eval cache so retries re-evaluate — an alias resolving
//! against an uncached faulted original would be a contract violation,
//! and cannot arise under the reservation discipline).

use crate::eval::EvalBackend;
use crate::genome::KernelGenome;
use crate::rng::Rng;
use crate::util::json::{self, Json};

/// The `[faults]` config table: injection rates and recovery policy.
/// Off by default; every knob other than `enabled` is inert until the
/// model is switched on.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Master switch. `false` (the default) means the decorator is
    /// pure delegation and no recovery code path runs.
    pub enabled: bool,
    /// P(transient evaluation error) per dispatch.
    pub transient: f64,
    /// P(straggler) per dispatch: the evaluation takes
    /// `straggler_factor x lognormal` as long.
    pub straggler: f64,
    /// Base latency multiplier for stragglers.
    pub straggler_factor: f64,
    /// Recovery: a dispatch whose latency multiplier reaches this
    /// factor is timed out (charged `straggler_timeout x` the nominal
    /// cost) and requeued instead of waited for.
    pub straggler_timeout: f64,
    /// P(corrupted timings) per dispatch: the reported timings are
    /// scaled by `corrupt_factor` (or its inverse), modeling a broken
    /// measurement harness.
    pub corrupt: f64,
    /// Multiplicative timing corruption magnitude.
    pub corrupt_factor: f64,
    /// P(permanent lane death) per dispatch: the submission is lost
    /// and the lane retires for the rest of the run.
    pub lane_death: f64,
    /// Master recovery switch: retries, straggler timeouts, and lane
    /// quarantine. With it off, faults simply consume quota (the
    /// ablation bench's contrast leg).
    pub recovery: bool,
    /// Max retry attempts per experiment beyond the first.
    pub max_retries: u32,
    /// Exponential-backoff base delay (virtual seconds) for transient
    /// failures: attempt `n` waits `base x 2^n`, capped.
    pub backoff_base_s: f64,
    /// Backoff cap (virtual seconds).
    pub backoff_cap_s: f64,
    /// Confirm outlier timings by repeat measurement before they enter
    /// the archive: timings far from the analytic estimate come back
    /// as [`crate::population::EvalOutcome::SuspectTimings`] and are
    /// re-measured instead of recorded.
    pub confirm_outliers: bool,
    /// Two-sided geomean ratio (vs the cost-model estimate) beyond
    /// which timings are suspect. Far above the simulator's noise
    /// sigma, so only corruption trips it.
    pub outlier_threshold: f64,
    /// Quarantine a lane after this many consecutive faulted
    /// dispatches.
    pub quarantine_after: u32,
    /// Quarantine duration (virtual seconds); the first job after
    /// re-admission is probational — one more fault retires the lane.
    pub probation_s: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enabled: false,
            transient: 0.05,
            straggler: 0.05,
            straggler_factor: 4.0,
            straggler_timeout: 2.5,
            corrupt: 0.02,
            corrupt_factor: 8.0,
            lane_death: 0.002,
            recovery: true,
            max_retries: 3,
            backoff_base_s: 30.0,
            backoff_cap_s: 480.0,
            confirm_outliers: true,
            outlier_threshold: 4.0,
            quarantine_after: 3,
            probation_s: 600.0,
        }
    }
}

impl FaultConfig {
    /// Serialize every knob (the config JSON embeds this only when
    /// `enabled` — off-config JSON stays byte-identical to pre-faults
    /// output).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("backoff_base_s", Json::Num(self.backoff_base_s)),
            ("backoff_cap_s", Json::Num(self.backoff_cap_s)),
            ("confirm_outliers", Json::Bool(self.confirm_outliers)),
            ("corrupt", Json::Num(self.corrupt)),
            ("corrupt_factor", Json::Num(self.corrupt_factor)),
            ("enabled", Json::Bool(self.enabled)),
            ("lane_death", Json::Num(self.lane_death)),
            ("max_retries", Json::Num(self.max_retries as f64)),
            ("outlier_threshold", Json::Num(self.outlier_threshold)),
            ("probation_s", Json::Num(self.probation_s)),
            ("quarantine_after", Json::Num(self.quarantine_after as f64)),
            ("recovery", Json::Bool(self.recovery)),
            ("straggler", Json::Num(self.straggler)),
            ("straggler_factor", Json::Num(self.straggler_factor)),
            ("straggler_timeout", Json::Num(self.straggler_timeout)),
            ("transient", Json::Num(self.transient)),
        ])
    }

    /// Tolerant parse: absent keys keep their defaults (pre-faults
    /// checkpoints and configs carry no `faults` object at all).
    pub fn from_json(v: &Json) -> Result<FaultConfig, String> {
        let d = FaultConfig::default();
        let f = |k: &str, dv: f64| v.get(k).and_then(|x| x.as_f64()).unwrap_or(dv);
        let b = |k: &str, dv: bool| v.get(k).and_then(|x| x.as_bool()).unwrap_or(dv);
        Ok(FaultConfig {
            enabled: b("enabled", d.enabled),
            transient: f("transient", d.transient),
            straggler: f("straggler", d.straggler),
            straggler_factor: f("straggler_factor", d.straggler_factor),
            straggler_timeout: f("straggler_timeout", d.straggler_timeout),
            corrupt: f("corrupt", d.corrupt),
            corrupt_factor: f("corrupt_factor", d.corrupt_factor),
            lane_death: f("lane_death", d.lane_death),
            recovery: b("recovery", d.recovery),
            max_retries: f("max_retries", d.max_retries as f64) as u32,
            backoff_base_s: f("backoff_base_s", d.backoff_base_s),
            backoff_cap_s: f("backoff_cap_s", d.backoff_cap_s),
            confirm_outliers: b("confirm_outliers", d.confirm_outliers),
            outlier_threshold: f("outlier_threshold", d.outlier_threshold),
            quarantine_after: f("quarantine_after", d.quarantine_after as f64) as u32,
            probation_s: f("probation_s", d.probation_s),
        })
    }

    /// Capped exponential backoff delay (virtual seconds) before retry
    /// attempt `attempt` of a transient failure.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        let exp = 2f64.powi(attempt.min(30) as i32);
        (self.backoff_base_s * exp).min(self.backoff_cap_s)
    }
}

/// What the fault model injects *instead of* running the evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InjectedFault {
    /// The evaluation errored transiently; a retry may succeed.
    Transient,
    /// The lane died mid-evaluation; the submission is lost and the
    /// lane never comes back.
    LaneDeath,
}

/// Per-dispatch fault decision, drawn before the evaluation runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DispatchPlan {
    /// Hard fault replacing the evaluation entirely.
    pub inject: Option<InjectedFault>,
    /// Latency multiplier (1.0 = nominal; > 1.0 = straggler).
    pub cost_factor: f64,
    /// Multiplicative timing corruption, applied to a successful
    /// evaluation's reported timings.
    pub corrupt_factor: Option<f64>,
}

impl DispatchPlan {
    /// The no-fault plan (what a healthy dispatch draws).
    pub fn clean() -> DispatchPlan {
        DispatchPlan {
            inject: None,
            cost_factor: 1.0,
            corrupt_factor: None,
        }
    }
}

/// splitmix64 finalizer — decorrelates the fingerprint/attempt key
/// before it perturbs the fault seed.
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Deterministic fault-injecting decorator over any backend.
///
/// Disabled (the default), every trait method delegates verbatim to
/// the inner backend and [`EvalBackend::fault_plan`] returns `None` —
/// the wrapper is invisible, which keeps off-runs bit-identical to a
/// build without this module. Enabled, [`EvalBackend::fork_lane`]
/// returns `None` so the platform evaluates inline on the parent
/// backend (fault dispatch decisions and lane-health bookkeeping live
/// on the platform's virtual clock, not on worker threads), and
/// `fault_plan` draws each dispatch's faults from its content-keyed
/// per-dispatch stream (module docs).
///
/// State capture delegates to the inner backend in **both** modes:
/// a checkpoint's backend blob is byte-identical to the unwrapped
/// backend's, because the fault model itself carries no stream state
/// to persist.
pub struct FaultyBackend<B: EvalBackend> {
    inner: B,
    cfg: FaultConfig,
    fault_seed: u64,
}

impl<B: EvalBackend> FaultyBackend<B> {
    /// Wrap `inner`. `seed` is the run seed; the fault stream is a
    /// fixed fork of it so fault draws never correlate with the
    /// simulator's noise streams.
    pub fn new(inner: B, cfg: FaultConfig, seed: u64) -> Self {
        FaultyBackend {
            inner,
            cfg,
            // constant stream tag: the fault model's fork of the run
            // seed (never fed to any other RNG consumer)
            fault_seed: mix(seed ^ 0xFA17_FA17_FA17_FA17),
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut B {
        &mut self.inner
    }
}

impl<B: EvalBackend> EvalBackend for FaultyBackend<B> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn check(&mut self, genome: &KernelGenome) -> Result<(), super::EvalError> {
        self.inner.check(genome)
    }

    fn measure(
        &mut self,
        genome: &KernelGenome,
        cfg: &crate::workload::GemmConfig,
    ) -> Result<f64, super::EvalError> {
        self.inner.measure(genome, cfg)
    }

    fn submission_cost_s(&self) -> f64 {
        self.inner.submission_cost_s()
    }

    fn profile(&self, genome: &KernelGenome) -> Option<crate::sim::ProfileReport> {
        self.inner.profile(genome)
    }

    fn workload(&self) -> std::sync::Arc<dyn crate::workload::Workload> {
        self.inner.workload()
    }

    fn fork_lane(&mut self, lane: u64) -> Option<Self> {
        if self.cfg.enabled {
            // force the inline stream path: fault decisions must
            // happen on the platform's virtual clock, per dispatch
            return None;
        }
        let cfg = self.cfg.clone();
        let fault_seed = self.fault_seed;
        self.inner.fork_lane(lane).map(|inner| FaultyBackend {
            inner,
            cfg,
            fault_seed,
        })
    }

    fn state_json(&self) -> Option<Json> {
        self.inner.state_json()
    }

    fn restore_state(&mut self, state: &Json) -> Result<(), String> {
        self.inner.restore_state(state)
    }

    fn fault_plan(&mut self, fingerprint: u64, attempt: u32) -> Option<DispatchPlan> {
        if !self.cfg.enabled {
            return None;
        }
        // fresh per-dispatch stream: a pure function of
        // (seed, genome, attempt) — see the module docs
        let key = self.fault_seed ^ mix(fingerprint) ^ mix(0xA77E_0000 | attempt as u64);
        let mut rng = Rng::seed_from_u64(key);
        let mut plan = DispatchPlan::clean();
        // fixed draw order (lane death, transient, straggler, corrupt)
        // so a config change to one rate never re-routes the draws of
        // another fault class for the same dispatch key
        if rng.chance(self.cfg.lane_death) {
            plan.inject = Some(InjectedFault::LaneDeath);
            return Some(plan);
        }
        if rng.chance(self.cfg.transient) {
            plan.inject = Some(InjectedFault::Transient);
            return Some(plan);
        }
        if rng.chance(self.cfg.straggler) {
            plan.cost_factor = self.cfg.straggler_factor * rng.lognormal_factor(0.5);
        }
        if rng.chance(self.cfg.corrupt) {
            plan.corrupt_factor = Some(if rng.chance(0.5) {
                self.cfg.corrupt_factor
            } else {
                1.0 / self.cfg.corrupt_factor
            });
        }
        Some(plan)
    }
}

/// What one faulted dispatch turned out to be — carried in flight and
/// resolved into events/stats/health at commit time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultTag {
    /// Injected transient evaluation error.
    Transient,
    /// Permanent lane death (retires the lane at commit).
    LaneDeath,
    /// Straggler that hit the recovery timeout (requeued).
    StragglerTimeout,
    /// Straggler that ran slow but finished (no fault outcome).
    Straggler,
    /// Corrupted timings that slipped through (confirmation off).
    Corrupt,
    /// Corrupted/outlier timings caught by confirmation.
    Suspect,
}

impl FaultTag {
    /// Journal/event kind string (stable).
    pub fn kind(&self) -> &'static str {
        match self {
            FaultTag::Transient => "transient",
            FaultTag::LaneDeath => "lane_death",
            FaultTag::StragglerTimeout => "straggler_timeout",
            FaultTag::Straggler => "straggler",
            FaultTag::Corrupt => "corrupt",
            FaultTag::Suspect => "suspect",
        }
    }

    pub fn from_kind(kind: &str) -> Option<FaultTag> {
        Some(match kind {
            "transient" => FaultTag::Transient,
            "lane_death" => FaultTag::LaneDeath,
            "straggler_timeout" => FaultTag::StragglerTimeout,
            "straggler" => FaultTag::Straggler,
            "corrupt" => FaultTag::Corrupt,
            "suspect" => FaultTag::Suspect,
            _ => return None,
        })
    }

    /// Whether this dispatch counts against the lane's health (slow
    /// and silently corrupted dispatches don't — the service can't
    /// see them either).
    pub fn counts_against_lane(&self) -> bool {
        matches!(
            self,
            FaultTag::Transient
                | FaultTag::LaneDeath
                | FaultTag::StragglerTimeout
                | FaultTag::Suspect
        )
    }
}

/// One lane's health record.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LaneHealth {
    /// Faulted dispatches since the last clean one.
    pub consecutive_faults: u32,
    /// Quarantined until this virtual time (cleared, with `probation`
    /// left set, when the lane is next selected past it).
    pub quarantined_until: Option<f64>,
    /// The next dispatch is probational: a fault retires the lane, a
    /// clean completion re-admits it.
    pub probation: bool,
    /// Permanently out of service (lane death, or a probation fault).
    pub retired: bool,
}

impl LaneHealth {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![(
            "consecutive_faults",
            Json::Num(self.consecutive_faults as f64),
        )];
        if let Some(q) = self.quarantined_until {
            pairs.push(("quarantined_until", Json::Num(q)));
        }
        if self.probation {
            pairs.push(("probation", Json::Bool(true)));
        }
        if self.retired {
            pairs.push(("retired", Json::Bool(true)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<LaneHealth, String> {
        Ok(LaneHealth {
            consecutive_faults: v
                .get("consecutive_faults")
                .and_then(|x| x.as_u64())
                .unwrap_or(0) as u32,
            quarantined_until: v.get("quarantined_until").and_then(|x| x.as_f64()),
            probation: v.get("probation").and_then(|x| x.as_bool()).unwrap_or(false),
            retired: v.get("retired").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }
}

/// Committed fault counters (checkpointed; only-when-nonzero JSON so
/// a faults-off checkpoint is byte-identical to pre-faults output —
/// though faults-off runs never construct this at all).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultStats {
    pub transients: u64,
    pub lane_deaths: u64,
    pub straggler_timeouts: u64,
    pub stragglers: u64,
    pub corrupted: u64,
    pub suspects: u64,
    pub quarantines: u64,
    pub readmissions: u64,
    pub retirements: u64,
}

impl FaultStats {
    /// Fault-class dispatch outcomes (the ones the recovery layer must
    /// resolve into a retry or an abandonment).
    pub fn injected(&self) -> u64 {
        self.transients + self.lane_deaths + self.straggler_timeouts + self.suspects
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = Vec::new();
        let mut num = |k: &'static str, v: u64| {
            if v > 0 {
                pairs.push((k, Json::Num(v as f64)));
            }
        };
        num("corrupted", self.corrupted);
        num("lane_deaths", self.lane_deaths);
        num("quarantines", self.quarantines);
        num("readmissions", self.readmissions);
        num("retirements", self.retirements);
        num("straggler_timeouts", self.straggler_timeouts);
        num("stragglers", self.stragglers);
        num("suspects", self.suspects);
        num("transients", self.transients);
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> FaultStats {
        let n = |k: &str| v.get(k).and_then(|x| x.as_u64()).unwrap_or(0);
        FaultStats {
            transients: n("transients"),
            lane_deaths: n("lane_deaths"),
            straggler_timeouts: n("straggler_timeouts"),
            stragglers: n("stragglers"),
            corrupted: n("corrupted"),
            suspects: n("suspects"),
            quarantines: n("quarantines"),
            readmissions: n("readmissions"),
            retirements: n("retirements"),
        }
    }
}

/// One typed fault/recovery event, journaled as a `"t":"fault"` record
/// (store layer) and surfaced to the scheduler through the platform's
/// event outbox.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRecord {
    /// Stable kind: a [`FaultTag::kind`] string, or the scheduler's
    /// own `"retry"` / `"abandon"` / platform `"quarantine"` /
    /// `"readmit"` / `"retire"`.
    pub kind: String,
    pub lane: Option<u32>,
    pub submission_index: Option<u64>,
    pub attempt: u32,
    /// Virtual time of the commit that produced the event.
    pub at_s: f64,
}

impl FaultRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("at_s", Json::Num(self.at_s))];
        if self.attempt > 0 {
            pairs.push(("attempt", Json::Num(self.attempt as f64)));
        }
        pairs.push(("kind", Json::Str(self.kind.clone())));
        if let Some(l) = self.lane {
            pairs.push(("lane", Json::Num(l as f64)));
        }
        if let Some(s) = self.submission_index {
            pairs.push(("submission_index", Json::Num(s as f64)));
        }
        pairs.push(("t", Json::Str("fault".into())));
        Json::obj(pairs)
    }

    /// Streamed emission, byte-identical to `to_json().to_string()`
    /// (keys in sorted order) — the journal's zero-alloc path.
    pub fn write_json(&self, out: &mut String) {
        out.push_str("{\"at_s\":");
        json::push_num_value(out, self.at_s);
        if self.attempt > 0 {
            out.push_str(",\"attempt\":");
            json::push_num_value(out, self.attempt as f64);
        }
        out.push_str(",\"kind\":");
        json::push_str_value(out, &self.kind);
        if let Some(l) = self.lane {
            out.push_str(",\"lane\":");
            json::push_num_value(out, l as f64);
        }
        if let Some(s) = self.submission_index {
            out.push_str(",\"submission_index\":");
            json::push_num_value(out, s as f64);
        }
        out.push_str(",\"t\":\"fault\"}");
    }

    pub fn from_json(v: &Json) -> Result<FaultRecord, String> {
        Ok(FaultRecord {
            kind: v
                .get("kind")
                .and_then(|x| x.as_str())
                .ok_or("fault record missing kind")?
                .to_string(),
            lane: v.get("lane").and_then(|x| x.as_u64()).map(|l| l as u32),
            submission_index: v.get("submission_index").and_then(|x| x.as_u64()),
            attempt: v.get("attempt").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
            at_s: v.get("at_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
        })
    }
}

/// The platform's recovery-layer state: config, per-lane health, the
/// committed counters, and the event outbox the scheduler drains after
/// each poll.
#[derive(Debug, Clone)]
pub struct FaultState {
    pub cfg: FaultConfig,
    pub lanes: Vec<LaneHealth>,
    pub stats: FaultStats,
    /// Typed events produced at commit time, drained (and journaled)
    /// by the scheduler after each poll. Must be empty at checkpoint
    /// time.
    pub events: Vec<FaultRecord>,
}

impl FaultState {
    pub fn new(cfg: FaultConfig, lanes: usize) -> FaultState {
        FaultState {
            cfg,
            lanes: vec![LaneHealth::default(); lanes],
            stats: FaultStats::default(),
            events: Vec::new(),
        }
    }

    /// Lanes still in service.
    pub fn live_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| !l.retired).count()
    }

    /// Resolve one committed dispatch into stats, lane health, and
    /// events. `tag` is `None` for a clean dispatch.
    pub fn on_commit(
        &mut self,
        lane: usize,
        tag: Option<FaultTag>,
        attempt: u32,
        submission_index: u64,
        at_s: f64,
    ) {
        match tag {
            Some(t) => {
                match t {
                    FaultTag::Transient => self.stats.transients += 1,
                    FaultTag::LaneDeath => self.stats.lane_deaths += 1,
                    FaultTag::StragglerTimeout => self.stats.straggler_timeouts += 1,
                    FaultTag::Straggler => self.stats.stragglers += 1,
                    FaultTag::Corrupt => self.stats.corrupted += 1,
                    FaultTag::Suspect => {
                        self.stats.corrupted += 1;
                        self.stats.suspects += 1;
                    }
                }
                self.events.push(FaultRecord {
                    kind: t.kind().into(),
                    lane: Some(lane as u32),
                    submission_index: Some(submission_index),
                    attempt,
                    at_s,
                });
                if t.counts_against_lane() {
                    self.on_lane_fault(lane, t, attempt, at_s);
                } else {
                    self.on_lane_clean(lane, attempt, at_s);
                }
            }
            None => self.on_lane_clean(lane, attempt, at_s),
        }
    }

    fn on_lane_fault(&mut self, lane: usize, tag: FaultTag, attempt: u32, at_s: f64) {
        let h = &mut self.lanes[lane];
        h.consecutive_faults += 1;
        if tag == FaultTag::LaneDeath {
            // permanent death is part of the fault model, not the
            // recovery policy: the lane is gone either way
            h.retired = true;
            self.stats.retirements += 1;
            self.events.push(FaultRecord {
                kind: "retire".into(),
                lane: Some(lane as u32),
                submission_index: None,
                attempt,
                at_s,
            });
            return;
        }
        if !self.cfg.recovery {
            return;
        }
        if h.probation {
            h.retired = true;
            self.stats.retirements += 1;
            self.events.push(FaultRecord {
                kind: "retire".into(),
                lane: Some(lane as u32),
                submission_index: None,
                attempt,
                at_s,
            });
        } else if h.consecutive_faults >= self.cfg.quarantine_after {
            h.quarantined_until = Some(at_s + self.cfg.probation_s);
            h.probation = true;
            h.consecutive_faults = 0;
            self.stats.quarantines += 1;
            self.events.push(FaultRecord {
                kind: "quarantine".into(),
                lane: Some(lane as u32),
                submission_index: None,
                attempt,
                at_s,
            });
        }
    }

    fn on_lane_clean(&mut self, lane: usize, attempt: u32, at_s: f64) {
        let h = &mut self.lanes[lane];
        h.consecutive_faults = 0;
        if h.probation {
            h.probation = false;
            self.stats.readmissions += 1;
            self.events.push(FaultRecord {
                kind: "readmit".into(),
                lane: Some(lane as u32),
                submission_index: None,
                attempt,
                at_s,
            });
        }
    }
}

/// Run-level fault/recovery summary (RunOutcome + report): the
/// platform's committed counters plus the scheduler's retry decisions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSummary {
    pub stats: FaultStats,
    pub retries: u64,
    pub abandoned: u64,
    pub retired_lanes: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::sim::SimBackend;
    use crate::workload::FEEDBACK_CONFIGS;

    fn on_cfg() -> FaultConfig {
        FaultConfig {
            enabled: true,
            ..Default::default()
        }
    }

    #[test]
    fn disabled_wrapper_is_pure_delegation() {
        let mut plain = SimBackend::new(9);
        let mut wrapped = FaultyBackend::new(SimBackend::new(9), FaultConfig::default(), 9);
        let g = seeds::mfma_seed();
        assert!(wrapped.fault_plan(g.fingerprint_hash(), 0).is_none());
        assert_eq!(plain.check(&g).is_ok(), wrapped.check(&g).is_ok());
        for cfg in &FEEDBACK_CONFIGS[..2] {
            assert_eq!(
                EvalBackend::measure(&mut plain, &g, cfg).unwrap(),
                EvalBackend::measure(&mut wrapped, &g, cfg).unwrap(),
                "disabled decorator must not perturb the noise stream"
            );
        }
        assert_eq!(
            plain.state_json(),
            wrapped.state_json(),
            "state capture delegates: checkpoint blobs stay identical"
        );
        assert!(wrapped.fork_lane(0).is_some(), "disabled forks delegate");
    }

    #[test]
    fn enabled_wrapper_refuses_lane_forks() {
        let mut b = FaultyBackend::new(SimBackend::new(9), on_cfg(), 9);
        assert!(b.fork_lane(0).is_none());
    }

    #[test]
    fn fault_plan_is_a_pure_function_of_seed_genome_attempt() {
        let mut a = FaultyBackend::new(SimBackend::new(1), on_cfg(), 42);
        let mut b = FaultyBackend::new(SimBackend::new(2), on_cfg(), 42);
        let g = seeds::mfma_seed();
        let fp = g.fingerprint_hash();
        // interleave unrelated draws on `a`: the plan must not change
        a.fault_plan(12345, 3);
        a.fault_plan(67890, 1);
        for attempt in 0..4 {
            assert_eq!(
                a.fault_plan(fp, attempt),
                b.fault_plan(fp, attempt),
                "per-dispatch streams are order-independent"
            );
        }
        // attempts draw distinct streams (retries re-roll the dice)
        let plans: Vec<_> = (0..64).map(|i| a.fault_plan(fp, i).unwrap()).collect();
        assert!(
            plans.iter().any(|p| *p != plans[0]),
            "attempt salt must vary the draw"
        );
    }

    #[test]
    fn fault_rates_are_roughly_honored() {
        let cfg = FaultConfig {
            enabled: true,
            transient: 0.2,
            straggler: 0.0,
            corrupt: 0.0,
            lane_death: 0.0,
            ..Default::default()
        };
        let mut b = FaultyBackend::new(SimBackend::new(1), cfg, 7);
        let n = 5000;
        let injected = (0..n)
            .filter(|&i| {
                b.fault_plan(i as u64 * 0x9E37_79B9, 0)
                    .unwrap()
                    .inject
                    .is_some()
            })
            .count();
        let rate = injected as f64 / n as f64;
        assert!((rate - 0.2).abs() < 0.03, "transient rate ~0.2, got {rate}");
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let cfg = FaultConfig::default();
        assert_eq!(cfg.backoff_s(0), 30.0);
        assert_eq!(cfg.backoff_s(1), 60.0);
        assert_eq!(cfg.backoff_s(2), 120.0);
        assert_eq!(cfg.backoff_s(10), 480.0, "cap");
    }

    #[test]
    fn lane_health_quarantines_then_retires_on_probation_fault() {
        let mut fs = FaultState::new(on_cfg(), 2);
        for i in 0..3 {
            fs.on_commit(0, Some(FaultTag::Transient), 0, i, 90.0 * (i + 1) as f64);
        }
        assert_eq!(fs.stats.quarantines, 1);
        assert!(fs.lanes[0].probation);
        assert!(fs.lanes[0].quarantined_until.is_some());
        assert!(!fs.lanes[0].retired);
        // probation fault retires the lane
        fs.lanes[0].quarantined_until = None;
        fs.on_commit(0, Some(FaultTag::Transient), 1, 3, 900.0);
        assert!(fs.lanes[0].retired);
        assert_eq!(fs.stats.retirements, 1);
        assert_eq!(fs.live_lanes(), 1);
    }

    #[test]
    fn lane_health_readmits_after_a_clean_probation_job() {
        let mut fs = FaultState::new(on_cfg(), 1);
        for i in 0..3 {
            fs.on_commit(0, Some(FaultTag::Transient), 0, i, 90.0);
        }
        assert!(fs.lanes[0].probation);
        fs.lanes[0].quarantined_until = None;
        fs.on_commit(0, None, 0, 3, 990.0);
        assert!(!fs.lanes[0].probation);
        assert_eq!(fs.stats.readmissions, 1);
        assert_eq!(fs.lanes[0].consecutive_faults, 0);
    }

    #[test]
    fn lane_death_always_retires_even_without_recovery() {
        let cfg = FaultConfig {
            recovery: false,
            ..on_cfg()
        };
        let mut fs = FaultState::new(cfg, 2);
        fs.on_commit(1, Some(FaultTag::LaneDeath), 0, 0, 90.0);
        assert!(fs.lanes[1].retired);
        assert_eq!(fs.stats.lane_deaths, 1);
        assert_eq!(fs.stats.retirements, 1);
    }

    #[test]
    fn fault_record_streamed_matches_tree_emitter() {
        let records = [
            FaultRecord {
                kind: "transient".into(),
                lane: Some(2),
                submission_index: Some(17),
                attempt: 1,
                at_s: 270.0,
            },
            FaultRecord {
                kind: "quarantine".into(),
                lane: Some(0),
                submission_index: None,
                attempt: 0,
                at_s: 90.0,
            },
            FaultRecord {
                kind: "retry".into(),
                lane: None,
                submission_index: Some(3),
                attempt: 2,
                at_s: 180.5,
            },
        ];
        for r in &records {
            let mut streamed = String::new();
            r.write_json(&mut streamed);
            assert_eq!(streamed, r.to_json().to_string());
            let parsed = FaultRecord::from_json(&crate::util::json::parse(&streamed).unwrap())
                .unwrap();
            assert_eq!(&parsed, r);
        }
    }

    #[test]
    fn fault_config_json_roundtrip_and_tolerant_parse() {
        let cfg = FaultConfig {
            enabled: true,
            transient: 0.125,
            max_retries: 5,
            recovery: false,
            ..Default::default()
        };
        let back = FaultConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // tolerant: an empty object is all defaults
        let empty = FaultConfig::from_json(&crate::util::json::parse("{}").unwrap()).unwrap();
        assert_eq!(empty, FaultConfig::default());
    }

    #[test]
    fn fault_stats_json_is_only_when_nonzero() {
        let stats = FaultStats::default();
        assert_eq!(stats.to_json().to_string(), "{}");
        let some = FaultStats {
            transients: 3,
            quarantines: 1,
            ..Default::default()
        };
        let v = some.to_json();
        assert!(v.get("stragglers").is_none());
        assert_eq!(FaultStats::from_json(&v), some);
    }
}
