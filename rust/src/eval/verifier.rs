//! The platform's numerical verifier model.
//!
//! The competition gate compares a submission's output against the
//! reference implementation under a tolerance sized for the task's
//! fp8-compute / f32-accumulate / bf16-output pipeline. On the PJRT
//! backend this comparison is literal (`runtime::PjrtBackend::verify`
//! runs both artifacts); on the simulated MI300 it is modeled: each
//! semantic hazard class implies an error distribution, and the
//! verifier decides pass/fail from the *predicted* error against the
//! same tolerance policy.
//!
//! Modeling the error (instead of a boolean) matters for fidelity:
//! the paper's system occasionally submitted kernels that were subtly
//! wrong, and the platform's verdict — not the writer — is what caught
//! them (§3.4). The error model also feeds the failure messages the
//! agents see in the ledger.

use crate::genome::{Hazard, KernelGenome};
use crate::workload::GemmConfig;

/// Tolerance policy for the block-scaled fp8 GEMM task: relative
/// tolerance grows with the reduction depth (k-sum reassociation in
/// f32) on top of the bf16 output quantum and fp8 input quantum.
#[derive(Debug, Clone)]
pub struct TolerancePolicy {
    /// Base relative tolerance (bf16 output: ~2^-8).
    pub base_rtol: f64,
    /// Extra rtol per sqrt(k) of accumulation depth.
    pub accum_rtol_per_sqrt_k: f64,
}

impl Default for TolerancePolicy {
    fn default() -> Self {
        TolerancePolicy {
            base_rtol: 1.0 / 256.0,
            accum_rtol_per_sqrt_k: 2e-4,
        }
    }
}

impl TolerancePolicy {
    /// Allowed relative error for a config.
    pub fn rtol(&self, cfg: &GemmConfig) -> f64 {
        self.base_rtol + self.accum_rtol_per_sqrt_k * (cfg.k as f64).sqrt()
    }
}

/// Predicted relative error of a kernel's output on a config.
pub fn predicted_rel_error(g: &KernelGenome, cfg: &GemmConfig) -> f64 {
    // correct kernels: rounding only — fp8 inputs are exact (they're
    // the reference's own quantized inputs), so the error is the f32
    // reassociation + bf16 store, well inside tolerance.
    let benign = 1e-4 + 1e-5 * (cfg.k as f64).sqrt();
    match g.correctness_hazard() {
        None => benign,
        // cross-wave RMW race: large fractions of the accumulation are
        // lost or double-counted — O(1) relative garbage that grows
        // with the number of racing waves.
        Some(Hazard::MultiWaveAccumulationRace) => {
            0.25 * (g.waves_per_block as f64 - 1.0).max(1.0)
        }
        // scales read from a live buffer: the wrong bits reinterpreted
        // as f32 scales — typically catastrophic on some tiles.
        Some(Hazard::ScaleRepurposeOverlap) => 0.5,
    }
}

/// The verdict the platform reports.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    Pass,
    /// Max relative error and the config it was observed on.
    Fail { rel_error: f64, cfg: GemmConfig, reason: String },
}

/// Run the modeled verification across a suite of configs.
pub fn verify(
    policy: &TolerancePolicy,
    g: &KernelGenome,
    configs: &[GemmConfig],
) -> Verdict {
    for cfg in configs {
        let err = predicted_rel_error(g, cfg);
        let tol = policy.rtol(cfg);
        if err > tol {
            let reason = match g.correctness_hazard() {
                Some(Hazard::MultiWaveAccumulationRace) => format!(
                    "mismatch vs reference (rel err {err:.2} > tol {tol:.4}) — \
                     cross-wave accumulation race on {cfg}"
                ),
                Some(Hazard::ScaleRepurposeOverlap) => format!(
                    "mismatch vs reference (rel err {err:.2} > tol {tol:.4}) — \
                     corrupted scales read from live LDS on {cfg}"
                ),
                None => format!(
                    "mismatch vs reference (rel err {err:.2} > tol {tol:.4}) on {cfg}"
                ),
            };
            return Verdict::Fail {
                rel_error: err,
                cfg: *cfg,
                reason,
            };
        }
    }
    Verdict::Pass
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome, ScaleCache, Writeback};
    use crate::workload::FEEDBACK_CONFIGS;

    #[test]
    fn correct_kernels_pass_every_config() {
        let policy = TolerancePolicy::default();
        for (name, g) in seeds::all_seeds() {
            assert_eq!(
                verify(&policy, &g, &FEEDBACK_CONFIGS),
                Verdict::Pass,
                "{name}"
            );
        }
    }

    #[test]
    fn race_fails_with_reasoned_verdict() {
        let g = KernelGenome {
            waves_per_block: 4,
            acc_in_regs: false,
            writeback: Writeback::Cooperative,
            ..seeds::mfma_seed()
        };
        match verify(&TolerancePolicy::default(), &g, &FEEDBACK_CONFIGS) {
            Verdict::Fail { rel_error, reason, .. } => {
                assert!(rel_error > 0.1);
                assert!(reason.contains("race"));
            }
            Verdict::Pass => panic!("race must fail verification"),
        }
    }

    #[test]
    fn scale_overlap_fails() {
        let g = KernelGenome {
            lds_staging: true,
            double_buffer: false,
            scale_cache: ScaleCache::LdsRepurposed,
            ..seeds::mfma_seed()
        };
        assert!(matches!(
            verify(&TolerancePolicy::default(), &g, &FEEDBACK_CONFIGS),
            Verdict::Fail { .. }
        ));
    }

    #[test]
    fn tolerance_grows_with_k() {
        let p = TolerancePolicy::default();
        let shallow = p.rtol(&GemmConfig::new(4096, 512, 4096));
        let deep = p.rtol(&GemmConfig::new(4096, 7168, 4096));
        assert!(deep > shallow);
    }

    #[test]
    fn benign_error_below_tolerance_at_any_depth() {
        let p = TolerancePolicy::default();
        let g = seeds::human_oracle();
        for k in [512u32, 1024, 4096, 7168] {
            let cfg = GemmConfig::new(4096, k, 4096);
            assert!(predicted_rel_error(&g, &cfg) < p.rtol(&cfg));
        }
    }

    #[test]
    fn more_racing_waves_more_error() {
        let mk = |w: u32| KernelGenome {
            waves_per_block: w,
            acc_in_regs: false,
            writeback: Writeback::Cooperative,
            ..seeds::mfma_seed()
        };
        let cfg = FEEDBACK_CONFIGS[0];
        assert!(predicted_rel_error(&mk(8), &cfg) > predicted_rel_error(&mk(2), &cfg));
    }
}
