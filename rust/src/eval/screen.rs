//! The analytic pre-screen tier (DESIGN.md §10): multi-fidelity
//! evaluation in front of the full simulated platform.
//!
//! The paper's own bottleneck is evaluation latency — every hypothesis
//! costs a 90 s-class submission slot (§ evaluation loop). This tier
//! scores each planned candidate with the workload's **noiseless
//! analytic cost model** ([`crate::workload::Workload::estimate`]) at
//! negligible simulated cost, accumulates candidates into fixed-size
//! *rungs*, and promotes only the top `keep_fraction` of each rung
//! (successive halving) into the expensive tier
//! ([`super::EvalPlatform::submit_stream`]). Rejected candidates never
//! occupy an evaluation lane and never consume submission quota —
//! exactly like the scheduler's replanned-duplicate path.
//!
//! Determinism: the screen score is a pure function of the genome (the
//! cost model draws no RNG — `prop_estimate_is_pure` locks this), the
//! comparator is [`f64::total_cmp`] with ties broken by submission
//! order, and the tier touches neither the platform clock nor any
//! backend RNG stream. A screening-off run therefore takes **no** code
//! path through this module, and a screening-on run replays from
//! (seed, config) at any lane count.
//!
//! NaN-safety (the PR 5 convention): a candidate whose cost model
//! fails, or returns a non-finite or non-positive timing, is *never*
//! promoted and *never* reaches the comparator — it is rejected at
//! promotion time. Finite scores are debug-asserted at the tier
//! boundary.

use std::sync::Arc;

use crate::genome::KernelGenome;
use crate::gpu::MI300;
use crate::workload::{GemmConfig, Workload};

/// Promotion-policy knobs (the `[screen]` config table).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScreenConfig {
    /// Candidates accumulated before a promotion decision. The
    /// pipeline scheduler screens in rungs of this size; the lockstep
    /// scheduler screens each planned batch as its own rung.
    pub rung: u32,
    /// Fraction of each rung promoted to full evaluation, in (0, 1].
    /// `ceil(keep_fraction * rung_len)` survive (at least one, never
    /// more than the rung's finite-scored candidates).
    pub keep_fraction: f64,
}

impl Default for ScreenConfig {
    fn default() -> Self {
        ScreenConfig {
            rung: 8,
            keep_fraction: 0.5,
        }
    }
}

/// Conservation counters: `screened == promoted + rejected + pending`
/// at every instant, so after a final flush every screened candidate is
/// accounted promoted or rejected.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ScreenStats {
    /// Candidates that entered the tier (scored by the cost model).
    pub screened: u64,
    /// Survivors forwarded to the full platform.
    pub promoted: u64,
    /// Candidates culled: below the rung's keep cut, or carrying an
    /// invalid / non-finite cost-model score.
    pub rejected: u64,
}

/// One promotion decision: the rung's survivors (in submission order)
/// and its culled candidates.
#[derive(Debug)]
pub struct ScreenOutcome<T> {
    pub promoted: Vec<T>,
    pub rejected: Vec<T>,
}

impl<T> ScreenOutcome<T> {
    fn empty() -> Self {
        ScreenOutcome {
            promoted: Vec::new(),
            rejected: Vec::new(),
        }
    }
}

struct Candidate<T> {
    /// Sanitized screen score (`None` = unscoreable: invalid genome or
    /// non-finite cost-model output — rejected, never compared).
    score: Option<f64>,
    /// Submission order within the tier — the comparator's tie-break.
    seq: u64,
    payload: T,
}

/// The pre-screen tier: a rung accumulator generic over the scheduler's
/// payload (the pipeline stores `(PlannedExperiment, log_pos)`).
pub struct ScreenTier<T> {
    cfg: ScreenConfig,
    workload: Arc<dyn Workload>,
    /// The workload's feedback-suite configs, fetched once — the screen
    /// scores candidates on exactly the basis the platform times.
    configs: Vec<GemmConfig>,
    rung: Vec<Candidate<T>>,
    seq: u64,
    stats: ScreenStats,
}

impl<T> ScreenTier<T> {
    pub fn new(cfg: ScreenConfig, workload: Arc<dyn Workload>) -> ScreenTier<T> {
        assert!(cfg.rung >= 1, "screen rung must be >= 1");
        assert!(
            cfg.keep_fraction > 0.0 && cfg.keep_fraction <= 1.0,
            "screen keep_fraction must be in (0, 1]"
        );
        let configs = workload.feedback_suite().configs;
        ScreenTier {
            cfg,
            workload,
            configs,
            rung: Vec::new(),
            seq: 0,
            stats: ScreenStats::default(),
        }
    }

    /// Analytic screen score for one candidate: geometric mean of the
    /// cost model's `total_us` over the feedback suite. `None` when the
    /// genome fails validation, the workload's compile gate, or the
    /// cost model — or when any timing is non-finite or non-positive
    /// (never promoted, never compared, never a panic).
    pub fn score(&self, genome: &KernelGenome) -> Option<f64> {
        if genome.validate().is_err() || self.workload.admits(genome).is_err() {
            return None;
        }
        let mut log_sum = 0.0f64;
        for cfg in &self.configs {
            let t = self.workload.estimate(&MI300, genome, cfg).ok()?.total_us;
            if !t.is_finite() || t <= 0.0 {
                return None;
            }
            log_sum += t.ln();
        }
        let score = (log_sum / self.configs.len().max(1) as f64).exp();
        score.is_finite().then_some(score)
    }

    /// Score `genome` and add it to the current rung. Returns the
    /// promotion decision when this push completes a rung.
    pub fn push(&mut self, genome: &KernelGenome, payload: T) -> Option<ScreenOutcome<T>> {
        let score = self.score(genome);
        self.push_scored(score, payload)
    }

    /// Add a pre-scored candidate (the schedulers score first to keep
    /// the payload move disjoint from the genome borrow; property tests
    /// inject adversarial scores here). Non-finite scores are
    /// sanitized to `None` at this boundary.
    pub fn push_scored(&mut self, score: Option<f64>, payload: T) -> Option<ScreenOutcome<T>> {
        let score = score.filter(|s| s.is_finite());
        self.stats.screened += 1;
        let seq = self.seq;
        self.seq += 1;
        self.rung.push(Candidate {
            score,
            seq,
            payload,
        });
        (self.rung.len() >= self.cfg.rung as usize).then(|| self.promote())
    }

    /// Re-insert a candidate restored from a checkpoint's screen queue:
    /// its `screened` count is already in the restored scheduler
    /// counters, and a checkpointed rung is always partial (promotion
    /// drains a rung the instant it fills), so restoring never decides.
    pub fn restore(&mut self, score: Option<f64>, payload: T) {
        let score = score.filter(|s| s.is_finite());
        let seq = self.seq;
        self.seq += 1;
        self.rung.push(Candidate {
            score,
            seq,
            payload,
        });
        debug_assert!(
            self.rung.len() < self.cfg.rung as usize,
            "restored screen queue at or above the rung size"
        );
    }

    /// Decide a partial rung (planning went dead or the budget ran
    /// out): same keep rule, applied to however many candidates sit in
    /// the rung. Empty outcome when the rung is empty.
    pub fn flush(&mut self) -> ScreenOutcome<T> {
        self.promote()
    }

    /// Candidates awaiting a promotion decision.
    pub fn pending(&self) -> usize {
        self.rung.len()
    }

    /// Payloads of the candidates awaiting a decision, in submission
    /// order (checkpointing walks these).
    pub fn pending_payloads(&self) -> impl Iterator<Item = &T> {
        self.rung.iter().map(|c| &c.payload)
    }

    pub fn stats(&self) -> ScreenStats {
        self.stats
    }

    /// Promotion rule: `keep = clamp(ceil(keep_fraction * n), 1, n)`
    /// survivors by ascending screen score (`f64::total_cmp`, ties by
    /// submission order), capped by the number of finite-scored
    /// candidates — an unscoreable candidate is never promoted, even
    /// from an otherwise-empty rung. Survivors return in submission
    /// order, so the promotion never reorders the scheduler's queue
    /// among survivors.
    fn promote(&mut self) -> ScreenOutcome<T> {
        let rung = std::mem::take(&mut self.rung);
        let n = rung.len();
        if n == 0 {
            return ScreenOutcome::empty();
        }
        let keep_target =
            ((self.cfg.keep_fraction * n as f64).ceil() as usize).clamp(1, n);
        let mut order: Vec<usize> = (0..n).filter(|&i| rung[i].score.is_some()).collect();
        order.sort_by(|&a, &b| {
            let (sa, sb) = (rung[a].score.unwrap(), rung[b].score.unwrap());
            // the tier boundary: only finite scores may be compared
            debug_assert!(sa.is_finite() && sb.is_finite());
            sa.total_cmp(&sb).then(rung[a].seq.cmp(&rung[b].seq))
        });
        order.truncate(keep_target);
        let keep: std::collections::HashSet<usize> = order.into_iter().collect();
        let mut out = ScreenOutcome::empty();
        for (i, c) in rung.into_iter().enumerate() {
            if keep.contains(&i) {
                out.promoted.push(c.payload);
            } else {
                out.rejected.push(c.payload);
            }
        }
        self.stats.promoted += out.promoted.len() as u64;
        self.stats.rejected += out.rejected.len() as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::workload;

    fn tier(rung: u32, keep: f64) -> ScreenTier<usize> {
        ScreenTier::new(
            ScreenConfig {
                rung,
                keep_fraction: keep,
            },
            workload::default_workload(),
        )
    }

    #[test]
    fn full_rung_promotes_the_top_keep_fraction() {
        let mut t = tier(4, 0.5);
        assert!(t.push_scored(Some(40.0), 0).is_none());
        assert!(t.push_scored(Some(10.0), 1).is_none());
        assert!(t.push_scored(Some(30.0), 2).is_none());
        let out = t.push_scored(Some(20.0), 3).expect("rung full");
        // lowest two scores survive, in submission order
        assert_eq!(out.promoted, vec![1, 3]);
        assert_eq!(out.rejected, vec![0, 2]);
        assert_eq!(t.pending(), 0);
        assert_eq!(
            t.stats(),
            ScreenStats {
                screened: 4,
                promoted: 2,
                rejected: 2
            }
        );
    }

    #[test]
    fn score_ties_break_by_submission_order() {
        let mut t = tier(4, 0.5);
        for i in 0..3 {
            assert!(t.push_scored(Some(5.0), i).is_none());
        }
        let out = t.push_scored(Some(5.0), 3).unwrap();
        assert_eq!(out.promoted, vec![0, 1], "earliest submissions win ties");
    }

    #[test]
    fn unscoreable_candidates_are_never_promoted() {
        let mut t = tier(4, 1.0);
        t.push_scored(None, 0);
        t.push_scored(Some(f64::NAN), 1);
        t.push_scored(Some(f64::INFINITY), 2);
        let out = t.push_scored(Some(7.0), 3).unwrap();
        // keep_fraction = 1.0 but only the finite-scored candidate may
        // survive
        assert_eq!(out.promoted, vec![3]);
        assert_eq!(out.rejected, vec![0, 1, 2]);
    }

    #[test]
    fn flush_decides_a_partial_rung_with_the_same_rule() {
        let mut t = tier(8, 0.5);
        t.push_scored(Some(3.0), 0);
        t.push_scored(Some(1.0), 1);
        t.push_scored(Some(2.0), 2);
        let out = t.flush();
        // ceil(0.5 * 3) = 2 survivors
        assert_eq!(out.promoted, vec![1, 2]);
        assert_eq!(out.rejected, vec![0]);
        assert!(t.flush().promoted.is_empty(), "empty rung flushes empty");
    }

    #[test]
    fn score_is_the_feedback_suite_geomean_of_the_cost_model() {
        let t = tier(4, 0.5);
        let w = workload::default_workload();
        let g = seeds::human_oracle();
        let score = t.score(&g).expect("valid seed must score");
        let timings: Vec<f64> = w
            .feedback_suite()
            .configs
            .iter()
            .map(|c| w.estimate(&MI300, &g, c).unwrap().total_us)
            .collect();
        let expected = crate::metrics::geomean(&timings);
        assert!((score - expected).abs() < 1e-9 * expected);
        // scoring is pure: same genome, same score
        assert_eq!(t.score(&g), t.score(&g));
    }

    #[test]
    fn invalid_genomes_score_none() {
        let t = tier(4, 0.5);
        let invalid = crate::genome::KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        assert_eq!(t.score(&invalid), None);
    }

    #[test]
    fn restore_refills_a_partial_rung_without_counting() {
        let mut t = tier(4, 0.5);
        t.restore(Some(2.0), 7);
        t.restore(Some(1.0), 8);
        assert_eq!(t.pending(), 2);
        assert_eq!(t.stats().screened, 0, "restored candidates were already counted");
        let pend: Vec<usize> = t.pending_payloads().copied().collect();
        assert_eq!(pend, vec![7, 8]);
    }
}
