//! The kernel testing & evaluation platform — the competition-server
//! substrate (paper §3.4).
//!
//! By default submissions are processed **sequentially** (the paper's
//! "good-citizen" rule, which it also names as the system's main
//! bottleneck, §5.1). Each submission passes a compile gate, a
//! correctness gate, then is timed on the feedback suite. The platform
//! keeps a full submission log and a simulated wall clock so the
//! parallelism ablation can compare sequential vs parallel submission
//! at a fixed wall-clock budget.
//!
//! With `parallelism > 1`, batches submitted through
//! [`EvalPlatform::submit_batch`] run on *real* worker threads via
//! [`executor`], one independently-forked backend per lane, and a
//! genome-fingerprint [`executor::EvalCache`] makes duplicate
//! submissions free (DESIGN.md §3). The completion-driven stream API
//! ([`EvalPlatform::submit_stream`] / [`EvalPlatform::poll_completed`])
//! feeds the same lanes one submission at a time so a scheduler can
//! refill each lane the moment it frees (DESIGN.md §8).

pub mod executor;
pub mod faults;
pub mod platform;
pub mod screen;
pub mod verifier;

use crate::genome::KernelGenome;
use crate::workload::{GemmConfig, Workload};

pub use executor::{evaluate_one, run_batch, EvalCache, StreamExecutor};
pub use faults::{
    DispatchPlan, FaultConfig, FaultRecord, FaultState, FaultStats, FaultSummary, FaultTag,
    FaultyBackend, InjectedFault, LaneHealth,
};
pub use platform::{
    BatchResult, CompletedEval, EvalPlatform, PlatformCheckpoint, PlatformConfig,
    SubmissionRecord,
};
pub use screen::{ScreenConfig, ScreenOutcome, ScreenStats, ScreenTier};
pub use verifier::{TolerancePolicy, Verdict};

/// Why a submission failed.
#[derive(Debug, Clone, PartialEq)]
pub enum EvalError {
    /// The kernel does not compile / launch (reported instantly).
    Compile(String),
    /// The kernel ran but produced wrong results on the verifier.
    Incorrect(String),
    /// The backend cannot evaluate this genome/config (PJRT backend
    /// only covers the compiled catalog projection).
    Unsupported(String),
}

impl std::fmt::Display for EvalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EvalError::Compile(m) => write!(f, "compile failure: {m}"),
            EvalError::Incorrect(m) => write!(f, "incorrect result: {m}"),
            EvalError::Unsupported(m) => write!(f, "unsupported: {m}"),
        }
    }
}

impl std::error::Error for EvalError {}

/// A timing backend: something that can check and time one kernel on
/// one config. Implemented by the MI300 simulator ([`crate::sim::SimBackend`])
/// and the PJRT artifact runtime ([`crate::runtime::PjrtBackend`]).
pub trait EvalBackend {
    /// Human-readable backend name (for logs/reports).
    fn name(&self) -> &str;

    /// Compile + correctness gates. `Ok(())` means the kernel may be
    /// timed. Called once per submission, before any timing.
    fn check(&mut self, genome: &KernelGenome) -> Result<(), EvalError>;

    /// One end-to-end timing measurement, microseconds.
    fn measure(&mut self, genome: &KernelGenome, cfg: &GemmConfig) -> Result<f64, EvalError>;

    /// Simulated seconds one (check + 6-config timing) submission
    /// occupies the platform — drives the wall-clock ablation. The
    /// default approximates the competition's queue+run latency.
    fn submission_cost_s(&self) -> f64 {
        90.0
    }

    /// Bottleneck-classified profile of one genome over the feedback
    /// suite (DESIGN.md §11). Must be a **pure** function of the genome
    /// — no RNG draw, no counted measurement — so the platform can
    /// attach profiles unconditionally without perturbing any
    /// trajectory. `None` — the default — means the backend has no
    /// counter model (the PJRT runtime times opaque artifacts);
    /// submissions then journal without a profile.
    fn profile(&self, _genome: &KernelGenome) -> Option<crate::sim::ProfileReport> {
        None
    }

    /// The workload this backend evaluates. The default is the paper's
    /// fp8 GEMM — backends that don't know better (the PJRT runtime
    /// serves the compiled fp8 catalog) inherit it; the simulator
    /// reports whichever registered workload it carries.
    fn workload(&self) -> std::sync::Arc<dyn crate::workload::Workload> {
        crate::workload::default_workload()
    }

    /// Create an independent backend for one parallel submission lane
    /// (the executor asks once per lane per batch). `None` — the
    /// default — means the backend cannot be forked and batches fall
    /// back to in-order sequential evaluation; the platform still does
    /// multi-lane wall-clock accounting. Forked lanes must be
    /// deterministic functions of `(self, lane)` so multi-lane runs
    /// replay from a seed (see `executor` module docs).
    fn fork_lane(&mut self, _lane: u64) -> Option<Self>
    where
        Self: Sized,
    {
        None
    }

    /// Serializable mutable state (RNG streams, counters) for run-store
    /// checkpoints (DESIGN.md §9). `None` — the default — means the
    /// backend cannot be checkpointed, and runs over it refuse a
    /// `[store]` configuration instead of persisting unre-playable
    /// ledgers (the PJRT runtime's device state lives outside us).
    fn state_json(&self) -> Option<crate::util::json::Json> {
        None
    }

    /// Restore state captured by [`EvalBackend::state_json`]. After the
    /// restore, the backend's measurement streams must continue exactly
    /// as the checkpointed run's would have.
    fn restore_state(&mut self, _state: &crate::util::json::Json) -> Result<(), String> {
        Err("backend does not support checkpoint restore".into())
    }

    /// Per-dispatch fault decision (DESIGN.md §14), consulted by the
    /// platform's stream path just before it charges a lane. `None` —
    /// the default, and what every backend other than an **enabled**
    /// [`faults::FaultyBackend`] returns — means the dispatch cannot
    /// fault and the platform takes the exact pre-faults code path
    /// (the off-means-off bit-identity switch). Must draw only from
    /// the fault model's own content-keyed stream, never from any
    /// measurement RNG.
    fn fault_plan(&mut self, _fingerprint: u64, _attempt: u32) -> Option<faults::DispatchPlan> {
        None
    }
}

impl EvalBackend for crate::sim::SimBackend {
    fn name(&self) -> &str {
        "mi300-sim"
    }

    fn check(&mut self, genome: &KernelGenome) -> Result<(), EvalError> {
        genome
            .validate()
            .map_err(|e| EvalError::Compile(e.to_string()))?;
        let workload = self.workload().clone();
        // workload family gate (e.g. no fp8 operands on a bf16 task)
        workload.admits(genome).map_err(EvalError::Compile)?;
        // numerical verification against the reference, modeled by the
        // workload's tolerance policy + per-hazard error distributions
        match verifier::verify(
            &workload.tolerance(),
            genome,
            &workload.feedback_suite().configs,
        ) {
            verifier::Verdict::Pass => Ok(()),
            verifier::Verdict::Fail { reason, .. } => Err(EvalError::Incorrect(reason)),
        }
    }

    fn measure(&mut self, genome: &KernelGenome, cfg: &GemmConfig) -> Result<f64, EvalError> {
        crate::sim::SimBackend::measure(self, genome, cfg)
            .map_err(|e| EvalError::Compile(e.to_string()))
    }

    fn fork_lane(&mut self, lane: u64) -> Option<Self> {
        Some(crate::sim::SimBackend::lane_clone(self, lane))
    }

    fn profile(&self, genome: &KernelGenome) -> Option<crate::sim::ProfileReport> {
        crate::sim::SimBackend::profile(self, genome)
    }

    fn workload(&self) -> std::sync::Arc<dyn crate::workload::Workload> {
        crate::sim::SimBackend::workload(self).clone()
    }

    fn state_json(&self) -> Option<crate::util::json::Json> {
        Some(crate::sim::SimBackend::state_json(self))
    }

    fn restore_state(&mut self, state: &crate::util::json::Json) -> Result<(), String> {
        crate::sim::SimBackend::restore_state_json(self, state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome, Writeback};
    use crate::sim::SimBackend;
    use crate::workload::FEEDBACK_CONFIGS;

    #[test]
    fn sim_backend_checks_validity() {
        let mut b = SimBackend::new(1);
        assert!(b.check(&seeds::human_oracle()).is_ok());
        let invalid = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        assert!(matches!(b.check(&invalid), Err(EvalError::Compile(_))));
    }

    #[test]
    fn sim_backend_catches_races() {
        let mut b = SimBackend::new(1);
        let racy = KernelGenome {
            waves_per_block: 4,
            acc_in_regs: false,
            writeback: Writeback::Cooperative,
            ..seeds::mfma_seed()
        };
        assert!(matches!(b.check(&racy), Err(EvalError::Incorrect(_))));
    }

    #[test]
    fn sim_backend_check_enforces_the_workload_family_gate() {
        // the bf16 family rejects fp8 genomes at the compile gate; the
        // same genome passes on the default (fp8) workload
        let mut fp8 = SimBackend::new(1);
        assert!(fp8.check(&seeds::mfma_seed()).is_ok());
        let mut bf16 = SimBackend::new(1)
            .with_workload(crate::workload::lookup("bf16-gemm").unwrap());
        assert!(matches!(
            bf16.check(&seeds::mfma_seed()),
            Err(EvalError::Compile(_))
        ));
        assert!(bf16
            .check(&crate::workload::bf16_gemm::library_seed())
            .is_ok());
    }

    #[test]
    fn sim_backend_measures_through_trait() {
        let mut b = SimBackend::new(1);
        let t =
            EvalBackend::measure(&mut b, &seeds::human_oracle(), &FEEDBACK_CONFIGS[0]).unwrap();
        assert!(t > 0.0);
        assert_eq!(b.name(), "mi300-sim");
    }
}
