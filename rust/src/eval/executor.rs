//! The multi-lane evaluation executor and the genome-keyed result
//! cache (DESIGN.md §3).
//!
//! The paper's §5.1 ablation identifies submission parallelism as the
//! dominant throughput lever: the "good citizen" sequential queue is
//! what made the optimization loop slow. Before this module the
//! platform only *simulated* parallel lanes via wall-clock bookkeeping
//! while every evaluation ran in-process, one after another. Here the
//! lanes are real: a batch of submissions is spread over `parallelism`
//! OS threads, each owning an independent lane backend, and the
//! simulated wall-clock accounting in [`super::EvalPlatform`] mirrors
//! exactly the lane occupancy these threads model.
//!
//! Determinism contract (relied on by the executor tests):
//!
//! * **1 lane** — the batch degenerates to the plain sequential call
//!   sequence on the platform's own backend, so outcomes are
//!   bit-identical to submitting each genome through
//!   [`super::EvalPlatform::submit`] in order.
//! * **N lanes** — jobs are partitioned statically round-robin
//!   (job *i* → lane *i* mod N) and each lane evaluates its slice in
//!   order on its own forked backend ([`super::EvalBackend::fork_lane`]),
//!   so results are reproducible for a fixed seed and lane count
//!   regardless of OS scheduling. Lane streams are decorrelated, which
//!   models distinct competition servers with independent measurement
//!   noise.
//! * Backends that cannot fork (e.g. the PJRT runtime, which owns a
//!   single client) fall back to in-order sequential evaluation; the
//!   platform still performs multi-lane wall-clock accounting, which
//!   matches the pre-executor simulated-lanes behaviour.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::{EvalBackend, EvalError};
use crate::genome::KernelGenome;
use crate::population::EvalOutcome;
use crate::workload::BenchmarkSuite;

/// Run the compile/correctness gates plus the timing sweep for one
/// genome — the unit of work one submission lane executes. Shared by
/// the sequential [`super::EvalPlatform::submit`] path and the batch
/// executor so both report identical outcomes for identical backend
/// state.
pub fn evaluate_one<B: EvalBackend>(
    backend: &mut B,
    suite: &BenchmarkSuite,
    reps_per_config: u32,
    genome: &KernelGenome,
) -> EvalOutcome {
    if let Err(e) = backend.check(genome) {
        // the Compile/Unsupported distinction is preserved as stable
        // outcome kinds (both permanent, but the retry policy and the
        // journal must be able to tell them apart — DESIGN.md §14)
        return match e {
            EvalError::Compile(m) => EvalOutcome::CompileFailure(m),
            EvalError::Unsupported(m) => EvalOutcome::Unsupported(m),
            EvalError::Incorrect(m) => EvalOutcome::IncorrectResult(m),
        };
    }
    let mut timings = Vec::with_capacity(suite.configs.len());
    for cfg in &suite.configs {
        let mut best = f64::INFINITY;
        for _ in 0..reps_per_config.max(1) {
            match backend.measure(genome, cfg) {
                Ok(t) => best = best.min(t),
                Err(e) => {
                    return match e {
                        EvalError::Incorrect(m) => EvalOutcome::IncorrectResult(m),
                        EvalError::Compile(m) => EvalOutcome::CompileFailure(m),
                        EvalError::Unsupported(m) => EvalOutcome::Unsupported(m),
                    }
                }
            }
        }
        timings.push(best);
    }
    EvalOutcome::Timings(timings)
}

/// Evaluate a batch of genomes across `lanes` worker threads, returning
/// outcomes in input order. See the module docs for the determinism
/// contract; quota and wall-clock accounting stay with the platform —
/// this function only runs the evaluations.
pub fn run_batch<B: EvalBackend + Send>(
    backend: &mut B,
    suite: &BenchmarkSuite,
    reps_per_config: u32,
    genomes: &[KernelGenome],
    lanes: u32,
) -> Vec<EvalOutcome> {
    let lanes = (lanes.max(1) as usize).min(genomes.len().max(1));
    if lanes <= 1 || genomes.len() < 2 {
        return genomes
            .iter()
            .map(|g| evaluate_one(backend, suite, reps_per_config, g))
            .collect();
    }
    let mut lane_backends: Vec<B> = Vec::new();
    for lane in 0..lanes {
        match backend.fork_lane(lane as u64) {
            Some(b) => lane_backends.push(b),
            None => {
                lane_backends.clear();
                break;
            }
        }
    }
    if lane_backends.is_empty() {
        // Backend cannot fork: keep the exact in-order call sequence.
        return genomes
            .iter()
            .map(|g| evaluate_one(backend, suite, reps_per_config, g))
            .collect();
    }
    let n_lanes = lane_backends.len();
    let mut results: Vec<Option<EvalOutcome>> = vec![None; genomes.len()];
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n_lanes);
        for (lane, mut lane_backend) in lane_backends.into_iter().enumerate() {
            let jobs: Vec<(usize, &KernelGenome)> = genomes
                .iter()
                .enumerate()
                .filter(|(i, _)| i % n_lanes == lane)
                .collect();
            handles.push(scope.spawn(move || {
                jobs.into_iter()
                    .map(|(i, g)| {
                        (i, evaluate_one(&mut lane_backend, suite, reps_per_config, g))
                    })
                    .collect::<Vec<(usize, EvalOutcome)>>()
            }));
        }
        for handle in handles {
            for (i, outcome) in handle.join().expect("evaluation lane panicked") {
                results[i] = Some(outcome);
            }
        }
    });
    results
        .into_iter()
        .map(|o| o.expect("executor lane dropped a job"))
        .collect()
}

/// Persistent lane workers for the completion-driven stream path
/// ([`super::EvalPlatform::submit_stream`] /
/// [`super::EvalPlatform::poll_completed`], DESIGN.md §8).
///
/// Where [`run_batch`] forks fresh lane backends per barrier batch,
/// the stream executor forks each lane **once** and keeps its worker
/// thread alive for the platform's lifetime: jobs trickle in as the
/// scheduler plans them and results trickle back as lanes finish, so
/// evaluation overlaps with planning instead of waiting at a barrier.
///
/// Determinism contract: the caller assigns jobs to lanes (the
/// platform uses its earliest-free virtual lane, which for uniform
/// submission costs is the same static round-robin partition
/// [`run_batch`] uses), each lane worker evaluates its jobs strictly
/// in FIFO order on its own forked backend, and [`Self::collect`]
/// returns one lane's oldest outstanding result. Nothing about OS
/// thread scheduling can reorder results within a lane, so stream
/// outcomes are a pure function of (backend seed, job→lane
/// assignment) — the platform's virtual clock decides the assignment
/// and the completion order.
///
/// The worker type is erased (channels carry only genomes and
/// outcomes), so holders of a `StreamExecutor` need no knowledge of
/// the backend type; only [`Self::spawn`] requires `B: Send + 'static`.
pub struct StreamExecutor {
    lanes: Vec<StreamLane>,
}

struct StreamLane {
    /// `None` once shutdown has begun (sender dropped to stop the
    /// worker loop).
    jobs: Option<mpsc::Sender<(u64, KernelGenome)>>,
    results: mpsc::Receiver<(u64, EvalOutcome)>,
    handle: Option<JoinHandle<()>>,
}

impl StreamExecutor {
    /// Fork `lanes` worker backends off `backend` and start one
    /// evaluation thread per lane. Returns `None` when the backend
    /// cannot fork (the caller falls back to inline sequential
    /// evaluation, exactly like [`run_batch`]) or when a single lane
    /// is requested (inline is already bit-identical there).
    pub fn spawn<B: EvalBackend + Send + 'static>(
        backend: &mut B,
        suite: &BenchmarkSuite,
        reps_per_config: u32,
        lanes: u32,
    ) -> Option<StreamExecutor> {
        if lanes <= 1 {
            return None;
        }
        let mut lane_backends = Vec::with_capacity(lanes as usize);
        for lane in 0..lanes as u64 {
            lane_backends.push(backend.fork_lane(lane)?);
        }
        Some(Self::from_backends(lane_backends, suite, reps_per_config))
    }

    /// Start one worker thread per pre-built lane backend. The resume
    /// path uses this directly: it re-forks the lanes from the
    /// checkpointed pre-spawn parent state, fast-forwards each by
    /// replaying its committed FIFO prefix, and hands them here — the
    /// workers then continue exactly where the crashed run's would
    /// have (DESIGN.md §9).
    pub fn from_backends<B: EvalBackend + Send + 'static>(
        lane_backends: Vec<B>,
        suite: &BenchmarkSuite,
        reps_per_config: u32,
    ) -> StreamExecutor {
        let lanes = lane_backends
            .into_iter()
            .map(|mut lane_backend| {
                let suite = suite.clone();
                let (jobs_tx, jobs_rx) = mpsc::channel::<(u64, KernelGenome)>();
                let (results_tx, results_rx) = mpsc::channel();
                let handle = std::thread::spawn(move || {
                    while let Ok((ticket, genome)) = jobs_rx.recv() {
                        let outcome =
                            evaluate_one(&mut lane_backend, &suite, reps_per_config, &genome);
                        if results_tx.send((ticket, outcome)).is_err() {
                            break; // receiver gone: shutting down
                        }
                    }
                });
                StreamLane {
                    jobs: Some(jobs_tx),
                    results: results_rx,
                    handle: Some(handle),
                }
            })
            .collect();
        StreamExecutor { lanes }
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Queue one job on `lane`'s worker. Returns immediately; the
    /// evaluation proceeds on the worker thread.
    pub fn dispatch(&self, lane: usize, ticket: u64, genome: KernelGenome) {
        self.lanes[lane]
            .jobs
            .as_ref()
            .expect("stream executor already shut down")
            .send((ticket, genome))
            .expect("evaluation lane worker exited");
    }

    /// Block until `lane`'s **oldest outstanding** job finishes and
    /// return its (ticket, outcome). Per-lane FIFO order is the
    /// executor's half of the determinism contract.
    pub fn collect(&self, lane: usize) -> (u64, EvalOutcome) {
        self.lanes[lane]
            .results
            .recv()
            .expect("evaluation lane worker exited")
    }
}

impl Drop for StreamExecutor {
    fn drop(&mut self) {
        // Close every job channel first so all workers wind down
        // concurrently, then join them.
        for lane in &mut self.lanes {
            lane.jobs.take();
        }
        for lane in &mut self.lanes {
            if let Some(handle) = lane.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

/// Eval-result cache keyed by genome content hash
/// ([`KernelGenome::fingerprint_hash`]): re-submitting a duplicate
/// genome is free — it returns the recorded [`EvalOutcome`] without
/// consuming submission quota, platform time, or a backend evaluation.
/// The u64 hash key replaced the formatted fingerprint `String`
/// (§Perf, archive-scaling pass): every submission probes the cache,
/// and rendering a string per probe was the hot path's dominant
/// allocation.
#[derive(Debug, Clone, Default)]
pub struct EvalCache {
    enabled: bool,
    map: HashMap<u64, EvalOutcome>,
    hits: u64,
    misses: u64,
}

impl EvalCache {
    pub fn new(enabled: bool) -> Self {
        EvalCache {
            enabled,
            ..Default::default()
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Rebuild a cache from checkpointed contents + counted stats (the
    /// warm-start path: prior evaluation artifacts are reused instead
    /// of recomputed, and hit/miss accounting continues seamlessly).
    pub fn restore(
        enabled: bool,
        entries: Vec<(u64, EvalOutcome)>,
        hits: u64,
        misses: u64,
    ) -> Self {
        EvalCache {
            enabled,
            map: if enabled {
                entries.into_iter().collect()
            } else {
                HashMap::new()
            },
            hits,
            misses,
        }
    }

    /// Counted lookup (batch path): hits and misses feed `stats`.
    pub fn lookup(&mut self, fingerprint: u64) -> Option<EvalOutcome> {
        if !self.enabled {
            return None;
        }
        match self.map.get(&fingerprint) {
            Some(out) => {
                self.hits += 1;
                Some(out.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Uncounted lookup (planning probes that must not skew stats).
    pub fn peek(&self, fingerprint: u64) -> Option<&EvalOutcome> {
        if !self.enabled {
            return None;
        }
        self.map.get(&fingerprint)
    }

    pub fn insert(&mut self, fingerprint: u64, outcome: EvalOutcome) {
        if self.enabled {
            self.map.insert(fingerprint, outcome);
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// (hits, misses) over counted lookups.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::sim::SimBackend;

    fn suite() -> BenchmarkSuite {
        BenchmarkSuite::feedback()
    }

    #[test]
    fn evaluate_one_times_valid_genome() {
        let mut b = SimBackend::new(3);
        let out = evaluate_one(&mut b, &suite(), 3, &seeds::mfma_seed());
        let t = out.timings().expect("valid genome times");
        assert_eq!(t.len(), 6);
        assert!(t.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn evaluate_one_reports_failures() {
        let mut b = SimBackend::new(3);
        let invalid = crate::genome::KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        assert!(matches!(
            evaluate_one(&mut b, &suite(), 3, &invalid),
            EvalOutcome::CompileFailure(_)
        ));
        let racy = crate::scientist::bootstrap::race_probe();
        assert!(matches!(
            evaluate_one(&mut b, &suite(), 3, &racy),
            EvalOutcome::IncorrectResult(_)
        ));
    }

    #[test]
    fn single_lane_batch_matches_sequential_calls() {
        let jobs: Vec<_> = crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
            .into_iter()
            .take(6)
            .map(|(_, g)| g)
            .collect();
        let mut seq_backend = SimBackend::new(11);
        let expected: Vec<EvalOutcome> = jobs
            .iter()
            .map(|g| evaluate_one(&mut seq_backend, &suite(), 3, g))
            .collect();
        let mut batch_backend = SimBackend::new(11);
        let got = run_batch(&mut batch_backend, &suite(), 3, &jobs, 1);
        assert_eq!(expected, got);
    }

    #[test]
    fn multi_lane_batch_is_deterministic_per_seed() {
        let jobs: Vec<_> = crate::genome::edit::valid_neighbors(&seeds::human_oracle())
            .into_iter()
            .take(9)
            .map(|(_, g)| g)
            .collect();
        let mut b1 = SimBackend::new(5);
        let mut b2 = SimBackend::new(5);
        let r1 = run_batch(&mut b1, &suite(), 2, &jobs, 3);
        let r2 = run_batch(&mut b2, &suite(), 2, &jobs, 3);
        assert_eq!(r1, r2, "static lane partition must be schedule-independent");
        assert_eq!(r1.len(), jobs.len());
        assert!(r1.iter().all(|o| o.is_success()));
    }

    #[test]
    fn stream_executor_matches_run_batch_partition() {
        // same jobs, same seed: dispatching job i to lane i mod N
        // through the stream workers must reproduce run_batch's static
        // round-robin outcomes exactly
        let jobs: Vec<_> = crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
            .into_iter()
            .take(9)
            .map(|(_, g)| g)
            .collect();
        let mut batch_backend = SimBackend::new(7);
        let expected = run_batch(&mut batch_backend, &suite(), 2, &jobs, 3);

        let mut stream_backend = SimBackend::new(7);
        let ex = StreamExecutor::spawn(&mut stream_backend, &suite(), 2, 3)
            .expect("sim backend forks lanes");
        assert_eq!(ex.lanes(), 3);
        for (i, g) in jobs.iter().enumerate() {
            ex.dispatch(i % 3, i as u64, g.clone());
        }
        let mut got = vec![None; jobs.len()];
        for (i, _) in jobs.iter().enumerate() {
            let (ticket, outcome) = ex.collect(i % 3);
            got[ticket as usize] = Some(outcome);
        }
        let got: Vec<EvalOutcome> = got.into_iter().map(|o| o.unwrap()).collect();
        assert_eq!(expected, got);
    }

    #[test]
    fn stream_executor_lane_results_are_fifo() {
        let jobs: Vec<_> = crate::genome::edit::valid_neighbors(&seeds::human_oracle())
            .into_iter()
            .take(4)
            .map(|(_, g)| g)
            .collect();
        let mut backend = SimBackend::new(19);
        let ex = StreamExecutor::spawn(&mut backend, &suite(), 1, 2).unwrap();
        // two jobs on lane 0, two on lane 1
        for (i, g) in jobs.iter().enumerate() {
            ex.dispatch(i % 2, i as u64, g.clone());
        }
        assert_eq!(ex.collect(0).0, 0, "lane 0 returns its oldest job first");
        assert_eq!(ex.collect(1).0, 1);
        assert_eq!(ex.collect(0).0, 2);
        assert_eq!(ex.collect(1).0, 3);
    }

    #[test]
    fn stream_executor_refuses_single_lane_and_shuts_down_clean() {
        let mut backend = SimBackend::new(3);
        assert!(StreamExecutor::spawn(&mut backend, &suite(), 3, 1).is_none());
        // spawning and dropping without dispatching must not hang
        let ex = StreamExecutor::spawn(&mut backend, &suite(), 3, 4).unwrap();
        drop(ex);
    }

    #[test]
    fn cache_hits_and_stats() {
        let mut c = EvalCache::new(true);
        let fp = seeds::mfma_seed().fingerprint_hash();
        assert!(c.lookup(fp).is_none());
        c.insert(fp, EvalOutcome::Timings(vec![1.0; 6]));
        assert_eq!(
            c.lookup(fp),
            Some(EvalOutcome::Timings(vec![1.0; 6]))
        );
        assert_eq!(c.stats(), (1, 1));
        assert_eq!(c.len(), 1);
        assert!(c.peek(fp).is_some());
    }

    #[test]
    fn disabled_cache_never_serves() {
        let mut c = EvalCache::new(false);
        let fp = seeds::mfma_seed().fingerprint_hash();
        c.insert(fp, EvalOutcome::Timings(vec![1.0; 6]));
        assert!(c.lookup(fp).is_none());
        assert!(c.peek(fp).is_none());
        assert!(c.is_empty());
    }
}
