//! The submission platform: submission queue, gates, timing runs,
//! leaderboard scoring, the simulated wall clock, and (since the
//! executor refactor, DESIGN.md §3) genuinely concurrent batch
//! submission plus the genome-fingerprint result cache.
//!
//! Two concurrent submission APIs coexist (both on top of the
//! multi-lane executor):
//!
//! * **Barrier batches** — [`EvalPlatform::submit_batch`]: one call,
//!   one result vector, the caller waits for everything.
//! * **Completion-driven stream** — [`EvalPlatform::submit_stream`] +
//!   [`EvalPlatform::poll_completed`] (DESIGN.md §8): submissions
//!   enter individually as a scheduler plans them, and completions
//!   are drained one at a time in **virtual-clock order**, so the
//!   steady-state pipeline can refill a lane the moment it frees.

use std::collections::HashMap;

use super::executor::{self, EvalCache, StreamExecutor};
use super::{EvalBackend, EvalError};
use crate::genome::KernelGenome;
use crate::metrics::geomean;
use crate::population::EvalOutcome;
use crate::sim::ProfileReport;
use crate::workload::BenchmarkSuite;

/// Platform policy knobs.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Timing repetitions per config (platform reports the minimum —
    /// standard benchmark practice).
    pub reps_per_config: u32,
    /// Concurrent submission lanes. The paper runs 1 ("good citizen");
    /// the §5.1 ablation raises it. Batches submitted through
    /// [`EvalPlatform::submit_batch`] run on this many real worker
    /// threads when the backend supports lane forking.
    pub parallelism: u32,
    /// Hard cap on total submissions (competition quota), if any.
    pub submission_quota: Option<u64>,
    /// Serve duplicate genomes from the eval-result cache on the batch
    /// path (free: no quota, no platform time, no backend run).
    pub cache_results: bool,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            reps_per_config: 3,
            parallelism: 1,
            submission_quota: None,
            cache_results: true,
        }
    }
}

/// One line of the platform's submission log.
#[derive(Debug, Clone)]
pub struct SubmissionRecord {
    pub index: u64,
    /// Simulated wall-clock time (s) at which results became available.
    pub completed_at_s: f64,
    /// Virtual lane that evaluated the submission. Checkpoint restores
    /// use it to replay each stream lane's committed FIFO prefix
    /// (DESIGN.md §9).
    pub lane: u32,
    pub outcome: EvalOutcome,
    /// Bottleneck-classified counter profile (DESIGN.md §11). A pure
    /// function of the submitted genome — `None` when the backend has
    /// no counter model or the genome failed its gates.
    pub profile: Option<ProfileReport>,
    /// Served from the cross-run federation store (DESIGN.md §12): the
    /// submission consumed quota and lane time exactly like a genuine
    /// evaluation but never ran the backend, so checkpoint restores
    /// must not replay it onto a lane backend.
    pub federated: bool,
}

/// Per-genome result of a [`EvalPlatform::submit_batch`] call, in
/// submission order.
#[derive(Debug, Clone)]
pub struct BatchResult {
    pub outcome: EvalOutcome,
    /// Served from the eval cache: no quota, no platform time consumed.
    pub cached: bool,
    /// Index in the submission log (`None` for cache hits).
    pub submission_index: Option<u64>,
    /// Simulated wall-clock time at which the result became available.
    pub completed_at_s: f64,
}

/// One completed stream submission, returned by
/// [`EvalPlatform::poll_completed`] in virtual-clock order.
#[derive(Debug, Clone)]
pub struct CompletedEval {
    /// The ticket [`EvalPlatform::submit_stream`] handed out.
    pub ticket: u64,
    pub outcome: EvalOutcome,
    /// Served from the eval cache (or aliased to an in-flight
    /// duplicate): no quota, no platform time consumed.
    pub cached: bool,
    /// Index in the submission log (`None` for cache hits).
    pub submission_index: Option<u64>,
    /// Simulated wall-clock time at which the result became available.
    pub completed_at_s: f64,
}

/// Platform accounting captured into (and restored from) a run-store
/// checkpoint, rolled back to the last committed completion — see
/// [`EvalPlatform::checkpoint_state`]. Serialization lives with the
/// store ([`crate::store`]); backend state travels as the opaque JSON
/// the backend's [`super::EvalBackend::state_json`] produced.
#[derive(Debug, Clone)]
pub struct PlatformCheckpoint {
    pub lane_busy_until: Vec<f64>,
    pub busy_lane_s: f64,
    pub next_ticket: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub backend: crate::util::json::Json,
    /// Parent backend state just before the stream executor forked its
    /// lane workers (present iff `stream_threaded`).
    pub prespawn_backend: Option<crate::util::json::Json>,
    /// Whether the checkpointed run had live stream lane workers.
    pub stream_threaded: bool,
    /// Submission-log length at stream-worker spawn time: entries from
    /// here on replay onto re-forked lane backends at restore.
    pub stream_log_start: u64,
    /// Committed federation-store hits (DESIGN.md §12). Counted only at
    /// commit time, so — unlike cache stats — no in-flight rollback is
    /// needed.
    pub federated_hits: u64,
    /// Fault-model state (DESIGN.md §14): lane health, committed fault
    /// counters, and the in-flight pending entries persisted **as
    /// data** (faults-mode checkpoints do not unwind — see
    /// [`EvalPlatform::checkpoint_state`]). `None` on every faults-off
    /// run, keeping off-checkpoints byte-identical to pre-faults
    /// output.
    pub faults: Option<crate::util::json::Json>,
}

/// How stream submissions are evaluated (decided once, at the first
/// [`EvalPlatform::submit_stream`] call).
enum StreamState {
    /// No stream submission has happened yet.
    Idle,
    /// Evaluate inline on the platform's own backend at submit time —
    /// the single-lane / unforkable-backend path, bit-identical to
    /// sequential [`EvalPlatform::submit`] calls.
    Inline,
    /// Dispatch to the persistent lane workers.
    Threaded(StreamExecutor),
}

/// One in-flight (or already-served) stream submission.
struct PendingEval {
    ticket: u64,
    completed_at_s: f64,
    kind: PendingKind,
}

enum PendingKind {
    /// Occupies a lane. `inline_outcome` is `Some` on the inline path
    /// (evaluated at submit time), `None` while a worker runs it.
    Run {
        lane: usize,
        /// Genome content hash ([`KernelGenome::fingerprint_hash`]) —
        /// the in-flight alias key (§Perf: no per-dispatch `String`).
        fingerprint: u64,
        inline_outcome: Option<EvalOutcome>,
        /// Lane-seconds this dispatch occupies (the nominal submission
        /// cost, or a fault-scaled value). Charged to `busy_lane_s` —
        /// and the submission index assigned — at **commit** time
        /// (poll), never at dispatch: with varying per-dispatch costs,
        /// commits happen out of dispatch order, and committed-only
        /// accounting is what keeps checkpoints exact (DESIGN.md §14).
        cost_s: f64,
        /// Lane-clock value as of just before this dispatch: a
        /// faults-off checkpoint unwinds in-flight work by restoring
        /// the recorded value (exact — no float subtraction).
        prev_lane_clock: f64,
        /// Inline path only: parent backend state just before this
        /// dispatch's inline evaluation. Inline evaluation advances the
        /// parent's noise stream at *submit* time, so unwinding the
        /// submission must also rewind the backend to here (threaded
        /// dispatches never touch the parent — `None`).
        prev_backend_state: Option<crate::util::json::Json>,
        /// Profile computed at submit time (the genome is not retained
        /// in flight), committed to the log line at poll time.
        profile: Option<ProfileReport>,
        /// Federation-store hit: `inline_outcome` carries the stored
        /// result, no backend ever ran this dispatch (DESIGN.md §12).
        federated: bool,
        /// Retry attempt number (0 = first try; DESIGN.md §14).
        attempt: u32,
        /// What the fault model did to this dispatch (`None` = clean),
        /// resolved into stats/health/events at commit.
        fault: Option<super::faults::FaultTag>,
    },
    /// Served from the result cache at submit time (free).
    Cached { outcome: EvalOutcome },
    /// Duplicate of an in-flight run with the same fingerprint:
    /// resolves from the cache once the original completes (free).
    Alias { fingerprint: u64 },
}

/// The evaluation platform wrapping a backend.
pub struct EvalPlatform<B: EvalBackend> {
    backend: B,
    pub config: PlatformConfig,
    pub feedback_suite: BenchmarkSuite,
    log: Vec<SubmissionRecord>,
    /// Simulated wall clock, seconds. With `parallelism` lanes, each
    /// lane is a virtual worker; the clock advances to the earliest
    /// free lane at submit time. Batch submissions assign lanes in
    /// submission order with equal per-submission cost, which matches
    /// the executor's static round-robin thread partition.
    lane_busy_until: Vec<f64>,
    /// Total lane-seconds spent evaluating (drives
    /// [`EvalPlatform::lane_occupancy`]; idle time shows up as the gap
    /// to `lanes x wall_clock_s`).
    busy_lane_s: f64,
    /// Eval-result cache keyed by genome fingerprint (DESIGN.md §3).
    cache: EvalCache,
    /// Stream path state (submit_stream / poll_completed).
    stream: StreamState,
    pending: Vec<PendingEval>,
    next_ticket: u64,
    /// Capture backend-state snapshots at the points a checkpoint
    /// would need them (stream spawn, inline dispatches). Off by
    /// default — store-less runs pay nothing on the submission path;
    /// enabled by [`EvalPlatform::enable_state_capture`] when a run
    /// store is configured.
    capture_backend_state: bool,
    /// Backend state captured just before the stream executor forked
    /// its lane workers — checkpoints carry it so a resume can re-fork
    /// identical lane backends (DESIGN.md §9).
    prespawn_state: Option<crate::util::json::Json>,
    /// Submission-log length at the moment the stream workers spawned:
    /// log entries from here on were evaluated on lane backends (and
    /// are replayed per lane on restore); earlier entries ran inline on
    /// the parent backend (covered by its own state snapshot).
    stream_log_start: u64,
    /// Cross-run federation results for this run's exact (workload,
    /// config-digest) key, attached by the scientist when a
    /// `[federation]` store is configured (DESIGN.md §12). `None` means
    /// federation is off and every consult site is skipped — the
    /// off-means-off bit-identity guarantee rests on this being the
    /// only switch.
    federated: Option<HashMap<u64, EvalOutcome>>,
    /// Committed federation hits (counted at commit, never in flight).
    federated_hits: u64,
    /// Recovery-layer state (DESIGN.md §14): per-lane health, committed
    /// fault counters, and the event outbox the scheduler drains after
    /// each poll. `None` means the fault model is off and every consult
    /// site is skipped — like `federated`, this is the only switch the
    /// off-means-off bit-identity guarantee rests on.
    faults: Option<super::faults::FaultState>,
}

impl<B: EvalBackend> EvalPlatform<B> {
    pub fn new(backend: B, config: PlatformConfig) -> Self {
        let lanes = config.parallelism.max(1) as usize;
        let cache = EvalCache::new(config.cache_results);
        EvalPlatform {
            backend,
            config,
            feedback_suite: BenchmarkSuite::feedback(),
            log: Vec::new(),
            lane_busy_until: vec![0.0; lanes],
            busy_lane_s: 0.0,
            cache,
            stream: StreamState::Idle,
            pending: Vec::new(),
            next_ticket: 0,
            capture_backend_state: false,
            prespawn_state: None,
            stream_log_start: 0,
            federated: None,
            federated_hits: 0,
            faults: None,
        }
    }

    /// Switch on the fault model's recovery layer (lane health,
    /// quarantine, fault counters). Call before any submission, and
    /// only when the backend is an enabled
    /// [`super::faults::FaultyBackend`] — the platform consults
    /// [`super::EvalBackend::fault_plan`] per stream dispatch and
    /// resolves what it injected into this state at commit time.
    pub fn enable_faults(&mut self, cfg: super::faults::FaultConfig) {
        debug_assert!(
            self.log.is_empty() && self.pending.is_empty(),
            "enable_faults() after submissions began"
        );
        let lanes = self.lane_busy_until.len();
        self.faults = Some(super::faults::FaultState::new(cfg, lanes));
    }

    /// Recovery-layer state, if the fault model is on.
    pub fn fault_state(&self) -> Option<&super::faults::FaultState> {
        self.faults.as_ref()
    }

    /// Drain the typed fault/recovery events produced since the last
    /// drain (the scheduler journals them after each poll). Empty when
    /// the fault model is off.
    pub fn take_fault_events(&mut self) -> Vec<super::faults::FaultRecord> {
        match &mut self.faults {
            Some(fs) => std::mem::take(&mut fs.events),
            None => Vec::new(),
        }
    }

    /// Attach the cross-run federation results for this run's exact
    /// (workload, config-digest) key. Every submission path consults
    /// the map before burning a backend run; a hit consumes quota and
    /// lane time exactly like a genuine evaluation (so run trajectories
    /// stay identical) but skips the backend. Must be attached before
    /// any submission; never call it when `[federation]` is off.
    pub fn attach_federation(&mut self, results: HashMap<u64, EvalOutcome>) {
        debug_assert!(
            self.log.is_empty() && self.pending.is_empty(),
            "attach_federation() after submissions began"
        );
        self.federated = Some(results);
    }

    /// Committed federation-store hits so far.
    pub fn federated_hits(&self) -> u64 {
        self.federated_hits
    }

    /// Federation consult: stored outcome for this fingerprint, if the
    /// store is attached and has one.
    fn federated_outcome(&self, fp: u64) -> Option<EvalOutcome> {
        self.federated.as_ref().and_then(|m| m.get(&fp)).cloned()
    }

    /// Switch on checkpoint-state capture (see the field docs). Must be
    /// called before any stream submission whose state a checkpoint may
    /// need — [`crate::scientist::ScientistRun`] enables it at
    /// construction whenever a `[store]` is configured.
    pub fn enable_state_capture(&mut self) {
        self.capture_backend_state = true;
    }

    /// Use a non-default feedback suite (the PJRT backend needs the
    /// testbed shapes).
    pub fn with_feedback_suite(mut self, suite: BenchmarkSuite) -> Self {
        self.feedback_suite = suite;
        self
    }

    pub fn backend_name(&self) -> String {
        self.backend.name().to_string()
    }

    /// The workload the backend evaluates (seed genomes, suites — see
    /// [`crate::workload::Workload`]). Tuners use this to stay
    /// workload-generic.
    pub fn workload(&self) -> std::sync::Arc<dyn crate::workload::Workload> {
        self.backend.workload()
    }

    pub fn submissions(&self) -> u64 {
        self.log.len() as u64
    }

    pub fn log(&self) -> &[SubmissionRecord] {
        &self.log
    }

    /// Simulated wall-clock seconds consumed so far (max over lanes).
    pub fn wall_clock_s(&self) -> f64 {
        self.lane_busy_until.iter().cloned().fold(0.0, f64::max)
    }

    /// Whether the quota (if any) is exhausted.
    pub fn quota_exhausted(&self) -> bool {
        self.config
            .submission_quota
            .map(|q| self.submissions() >= q)
            .unwrap_or(false)
    }

    /// Submit one kernel: gates, then `reps_per_config` timing reps on
    /// each feedback config (minimum reported). Advances the simulated
    /// clock on the earliest-free lane — the sequential default means
    /// strictly serialized submissions, as in the paper. Always runs
    /// the backend (the cache only *serves* on the batch path, but
    /// results recorded here do populate it).
    pub fn submit(&mut self, genome: &KernelGenome) -> EvalOutcome {
        debug_assert!(
            self.pending.is_empty(),
            "submit() while stream evaluations are in flight"
        );
        assert!(
            !self.quota_exhausted(),
            "platform quota exhausted ({} submissions)",
            self.submissions()
        );
        // Federation consult: a stored result is committed with full
        // quota/clock accounting (identical trajectory to a genuine
        // run) but never touches the backend. No cache-stat counting —
        // this path never counts lookups.
        if let Some(outcome) = self.federated_outcome(genome.fingerprint_hash()) {
            self.cache.insert(genome.fingerprint_hash(), outcome.clone());
            let profile = self.backend.profile(genome);
            self.account_submission(outcome.clone(), profile, true);
            return outcome;
        }
        let outcome = executor::evaluate_one(
            &mut self.backend,
            &self.feedback_suite,
            self.config.reps_per_config,
            genome,
        );
        self.cache.insert(genome.fingerprint_hash(), outcome.clone());
        let profile = self.backend.profile(genome);
        self.account_submission(outcome.clone(), profile, false);
        outcome
    }

    /// Submit a batch of kernels. Cache hits are served for free (no
    /// quota, no platform time) — including duplicates *within* the
    /// batch, whose later occurrences alias the first occurrence's
    /// result. The misses run concurrently on `parallelism` executor
    /// lanes and are then committed to the log, quota, and lane clocks
    /// **in submission order**, exactly as if each had gone through
    /// [`EvalPlatform::submit`] in turn. If the quota runs out
    /// mid-batch, processing stops at the first entry the quota cannot
    /// cover and the rest are dropped — even entries that would have
    /// been free — so the returned vector is always a prefix-aligned
    /// result per input; callers that must not lose work pre-truncate
    /// to their remaining budget.
    pub fn submit_batch(&mut self, genomes: &[KernelGenome]) -> Vec<BatchResult>
    where
        B: Send,
    {
        debug_assert!(
            self.pending.is_empty(),
            "submit_batch() while stream evaluations are in flight"
        );
        enum Slot {
            Cached(EvalOutcome),
            Run(usize),
            /// Duplicate (within this batch) of an already planned Run
            /// or Fed slot with this fingerprint — resolved from the
            /// cache at assembly (the original commits first).
            Alias(u64),
            /// Federation-store hit: consumes quota and lane time like
            /// a genuine run, no backend dispatch (DESIGN.md §12).
            Fed {
                fp: u64,
                outcome: EvalOutcome,
                profile: Option<ProfileReport>,
            },
        }
        let remaining = match self.config.submission_quota {
            Some(q) => q.saturating_sub(self.submissions()),
            None => u64::MAX,
        };
        let mut slots: Vec<Slot> = Vec::with_capacity(genomes.len());
        let mut jobs: Vec<KernelGenome> = Vec::new();
        let mut job_fps: Vec<u64> = Vec::new();
        let mut planned_fps: std::collections::HashSet<u64> = std::collections::HashSet::new();
        let mut planned_quota = 0u64;
        for genome in genomes {
            let fp = genome.fingerprint_hash();
            // Counted-stats invariant: every *processed* entry (one
            // that yields a result) contributes exactly one counted
            // lookup — in-batch duplicates count theirs as the hit at
            // result assembly, and the entry that triggers quota
            // truncation counts nothing — so with the cache enabled,
            // hits + misses == results returned by this path.
            if self.cache.enabled() {
                if planned_fps.contains(&fp) {
                    slots.push(Slot::Alias(fp));
                    continue;
                }
                if self.cache.peek(fp).is_some() {
                    let hit = self.cache.lookup(fp).expect("peeked entry present");
                    slots.push(Slot::Cached(hit));
                    continue;
                }
            }
            if planned_quota >= remaining {
                break; // quota exhausted: truncate the batch here, uncounted
            }
            if self.cache.enabled() {
                let miss = self.cache.lookup(fp); // counted miss
                debug_assert!(miss.is_none());
            }
            // Federation consult after the counted miss, so a fed hit
            // leaves the same cache-stat footprint the original run's
            // genuine evaluation did.
            if let Some(outcome) = self.federated_outcome(fp) {
                let profile = self.backend.profile(genome);
                slots.push(Slot::Fed { fp, outcome, profile });
                planned_fps.insert(fp);
                planned_quota += 1;
                continue;
            }
            slots.push(Slot::Run(jobs.len()));
            planned_fps.insert(fp);
            planned_quota += 1;
            job_fps.push(fp);
            jobs.push(genome.clone());
        }
        let outcomes = executor::run_batch(
            &mut self.backend,
            &self.feedback_suite,
            self.config.reps_per_config,
            &jobs,
            self.config.parallelism,
        );
        let mut results = Vec::with_capacity(slots.len());
        for slot in slots {
            match slot {
                Slot::Cached(outcome) => results.push(BatchResult {
                    outcome,
                    cached: true,
                    submission_index: None,
                    completed_at_s: self.wall_clock_s(),
                }),
                Slot::Alias(fp) => {
                    // By commit order the aliased Run or Fed slot has
                    // already been committed and cached (aliases only
                    // exist with the cache enabled); the lookup also
                    // counts the hit in the cache stats.
                    let outcome = self
                        .cache
                        .lookup(fp)
                        .expect("aliased original commits before its duplicates");
                    results.push(BatchResult {
                        outcome,
                        cached: true,
                        submission_index: None,
                        completed_at_s: self.wall_clock_s(),
                    });
                }
                Slot::Fed { fp, outcome, profile } => {
                    self.cache.insert(fp, outcome.clone());
                    let (index, completed_at_s) =
                        self.account_submission(outcome.clone(), profile, true);
                    results.push(BatchResult {
                        outcome,
                        cached: false,
                        submission_index: Some(index),
                        completed_at_s,
                    });
                }
                Slot::Run(j) => {
                    let outcome = outcomes[j].clone();
                    self.cache.insert(job_fps[j], outcome.clone());
                    let profile = self.backend.profile(&jobs[j]);
                    let (index, completed_at_s) =
                        self.account_submission(outcome.clone(), profile, false);
                    results.push(BatchResult {
                        outcome,
                        cached: false,
                        submission_index: Some(index),
                        completed_at_s,
                    });
                }
            }
        }
        results
    }

    /// Stream submissions currently in flight (incl. cache hits not
    /// yet polled).
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// In-flight stream submissions that occupy a lane (i.e. count
    /// toward the quota once they complete).
    fn pending_runs(&self) -> u64 {
        self.pending
            .iter()
            .filter(|p| matches!(p.kind, PendingKind::Run { .. }))
            .count() as u64
    }

    /// The in-flight run (if any) evaluating this fingerprint — the
    /// aliasing target for duplicate stream submissions.
    fn pending_run_with_fp(&self, fp: u64) -> Option<&PendingEval> {
        self.pending.iter().find(|p| {
            matches!(&p.kind, PendingKind::Run { fingerprint, .. } if *fingerprint == fp)
        })
    }

    /// Submit one kernel on the completion-driven stream path and
    /// return its ticket; the result arrives through
    /// [`EvalPlatform::poll_completed`]. Semantics match the batch
    /// path per entry: cache hits (and duplicates of in-flight
    /// submissions) are free — no quota, no platform time — while
    /// misses occupy the earliest-free virtual lane for
    /// `submission_cost_s` and run concurrently on that lane's
    /// persistent worker thread (`B: 'static`; backends that cannot
    /// fork evaluate inline, preserving the exact sequential call
    /// sequence). Panics if the quota cannot cover a miss, counting
    /// in-flight misses as already spent — stream callers plan
    /// against `submissions() + in_flight()`.
    pub fn submit_stream(&mut self, genome: &KernelGenome) -> u64
    where
        B: Send + 'static,
    {
        self.submit_stream_retry(genome, 0.0, 0)
    }

    /// [`EvalPlatform::submit_stream`] with recovery-layer controls
    /// (DESIGN.md §14): the dispatch starts no earlier than
    /// `not_before_s` on the virtual clock (retry backoff is charged
    /// as lane idle time), and `attempt` salts the fault model's
    /// per-dispatch stream so a retry re-rolls its faults. With
    /// `(0.0, 0)` — and the fault model off — this **is** the plain
    /// stream path, bit for bit.
    pub fn submit_stream_retry(
        &mut self,
        genome: &KernelGenome,
        not_before_s: f64,
        attempt: u32,
    ) -> u64
    where
        B: Send + 'static,
    {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        let fp = genome.fingerprint_hash();
        if self.cache.enabled() {
            // duplicate of an in-flight run: resolves (free) when the
            // original lands in the cache. Counted as a hit at poll
            // time, mirroring the batch path's alias accounting.
            if let Some(original) = self.pending_run_with_fp(fp) {
                let completed_at_s = original.completed_at_s;
                self.pending.push(PendingEval {
                    ticket,
                    completed_at_s,
                    kind: PendingKind::Alias { fingerprint: fp },
                });
                return ticket;
            }
            // counted lookup either way: a hit serves the entry below,
            // a miss is the run's one counted miss (batch-path parity)
            if let Some(outcome) = self.cache.lookup(fp) {
                self.pending.push(PendingEval {
                    ticket,
                    completed_at_s: self.wall_clock_s(),
                    kind: PendingKind::Cached { outcome },
                });
                return ticket;
            }
        }
        let pending_runs = self.pending_runs();
        assert!(
            self.config
                .submission_quota
                .map(|q| self.submissions() + pending_runs < q)
                .unwrap_or(true),
            "platform quota exhausted ({} submissions, {pending_runs} in flight)",
            self.submissions()
        );
        // Federation consult (after the counted miss above, so the
        // cache-stat footprint matches the original run's genuine
        // evaluation): a hit occupies a lane for the usual cost and
        // consumes quota — identical trajectory bookkeeping — but never
        // spawns stream workers and never dispatches to a backend. It
        // also never faults: a federation hit is a local store read,
        // not a service round trip.
        if let Some(outcome) = self.federated_outcome(fp) {
            let cost = self.backend.submission_cost_s();
            let (lane, start_s) = self.pick_lane(not_before_s);
            let prev_lane_clock = self.lane_busy_until[lane];
            self.lane_busy_until[lane] = start_s + cost;
            let completed_at_s = start_s + cost;
            let profile = self.backend.profile(genome);
            self.pending.push(PendingEval {
                ticket,
                completed_at_s,
                kind: PendingKind::Run {
                    lane,
                    fingerprint: fp,
                    inline_outcome: Some(outcome),
                    cost_s: cost,
                    prev_lane_clock,
                    prev_backend_state: None,
                    profile,
                    federated: true,
                    attempt,
                    fault: None,
                },
            });
            return ticket;
        }
        if matches!(self.stream, StreamState::Idle) {
            // capture the pre-fork backend state first: a checkpoint
            // needs it to re-fork identical lane workers on resume
            let prespawn = if self.capture_backend_state {
                self.backend.state_json()
            } else {
                None
            };
            self.stream = match StreamExecutor::spawn(
                &mut self.backend,
                &self.feedback_suite,
                self.config.reps_per_config,
                self.config.parallelism,
            ) {
                Some(executor) => {
                    self.prespawn_state = prespawn;
                    self.stream_log_start = self.log.len() as u64;
                    StreamState::Threaded(executor)
                }
                None => StreamState::Inline,
            };
        }
        let nominal = self.backend.submission_cost_s();
        // Per-dispatch fault consult (DESIGN.md §14). The default
        // fault_plan is None — with the model off nothing below this
        // point differs from the pre-faults path.
        let plan = self.backend.fault_plan(fp, attempt);
        debug_assert!(
            plan.is_none() || self.faults.is_some(),
            "fault_plan fired without enable_faults()"
        );
        let mut cost = nominal;
        let mut injected: Option<EvalOutcome> = None;
        let mut fault_tag: Option<super::faults::FaultTag> = None;
        let mut corrupt_factor = None;
        if let Some(plan) = plan {
            use super::faults::{FaultTag, InjectedFault};
            match plan.inject {
                Some(InjectedFault::LaneDeath) => {
                    injected = Some(EvalOutcome::LaneFailure(
                        "evaluation lane died mid-run; submission lost".into(),
                    ));
                    fault_tag = Some(FaultTag::LaneDeath);
                }
                Some(InjectedFault::Transient) => {
                    injected = Some(EvalOutcome::TransientFailure(
                        "transient evaluation-service error".into(),
                    ));
                    fault_tag = Some(FaultTag::Transient);
                }
                None => {
                    let fcfg = &self.faults.as_ref().expect("asserted above").cfg;
                    if fcfg.recovery && plan.cost_factor >= fcfg.straggler_timeout {
                        // timeout-and-requeue: charge the capped cost
                        // and hand the scheduler a retryable failure
                        // instead of waiting the straggler out
                        cost = nominal * fcfg.straggler_timeout;
                        injected = Some(EvalOutcome::TransientFailure(format!(
                            "straggler timed out at {:.1}x the nominal cost",
                            fcfg.straggler_timeout
                        )));
                        fault_tag = Some(FaultTag::StragglerTimeout);
                    } else {
                        cost = nominal * plan.cost_factor;
                        if plan.cost_factor > 1.0 {
                            fault_tag = Some(FaultTag::Straggler);
                        }
                        corrupt_factor = plan.corrupt_factor;
                    }
                }
            }
        }
        let (lane, start_s) = self.pick_lane(not_before_s);
        let prev_lane_clock = self.lane_busy_until[lane];
        self.lane_busy_until[lane] = start_s + cost;
        let completed_at_s = start_s + cost;
        let (inline_outcome, prev_backend_state) = if let Some(outcome) = injected {
            // hard-faulted dispatches never run the evaluation: no
            // measurement-RNG draw, no backend state change
            (Some(outcome), None)
        } else {
            match &self.stream {
                StreamState::Threaded(executor) => {
                    debug_assert!(
                        self.faults.is_none(),
                        "an enabled fault model forces the inline stream path"
                    );
                    executor.dispatch(lane, ticket, genome.clone());
                    (None, None)
                }
                StreamState::Inline => {
                    let prev = if self.capture_backend_state {
                        self.backend.state_json()
                    } else {
                        None
                    };
                    let mut outcome = executor::evaluate_one(
                        &mut self.backend,
                        &self.feedback_suite,
                        self.config.reps_per_config,
                        genome,
                    );
                    // fault model: corrupted measurement harness
                    if let (Some(f), EvalOutcome::Timings(ts)) = (corrupt_factor, &mut outcome)
                    {
                        for t in ts.iter_mut() {
                            *t *= f;
                        }
                        fault_tag = Some(super::faults::FaultTag::Corrupt);
                    }
                    // recovery: confirm outlier timings against the
                    // analytic estimate before they can enter the
                    // archive (DESIGN.md §14)
                    if let Some(fs) = &self.faults {
                        if fs.cfg.confirm_outliers {
                            if let EvalOutcome::Timings(ts) = &outcome {
                                if let Some(expected) = self.expected_us(genome) {
                                    let measured = geomean(ts);
                                    let ratio =
                                        (measured / expected).max(expected / measured);
                                    if ratio > fs.cfg.outlier_threshold {
                                        outcome = EvalOutcome::SuspectTimings(ts.clone());
                                        fault_tag = Some(super::faults::FaultTag::Suspect);
                                    }
                                }
                            }
                        }
                    }
                    (Some(outcome), prev)
                }
                StreamState::Idle => unreachable!("stream mode decided above"),
            }
        };
        let profile = self.backend.profile(genome);
        self.pending.push(PendingEval {
            ticket,
            completed_at_s,
            kind: PendingKind::Run {
                lane,
                fingerprint: fp,
                inline_outcome,
                cost_s: cost,
                prev_lane_clock,
                prev_backend_state,
                profile,
                federated: false,
                attempt,
                fault: fault_tag,
            },
        });
        ticket
    }

    /// Lane selection for one stream dispatch, starting no earlier
    /// than `not_before_s`. Faults off: the shared earliest-free rule
    /// (ties to the lowest index), exactly as every path always chose.
    /// Faults on: retired lanes are skipped and a quarantined lane is
    /// unavailable before its quarantine expires — selecting it past
    /// that point clears the window and leaves the lane probational.
    /// Panics loudly when every lane has retired: graceful degradation
    /// has run out of lanes and the run cannot continue.
    fn pick_lane(&mut self, not_before_s: f64) -> (usize, f64) {
        let fs = match &mut self.faults {
            None => {
                let lane = self.earliest_free_lane();
                return (lane, self.lane_busy_until[lane].max(not_before_s));
            }
            Some(fs) => fs,
        };
        let mut best: Option<(usize, f64)> = None;
        for (i, &busy) in self.lane_busy_until.iter().enumerate() {
            let h = &fs.lanes[i];
            if h.retired {
                continue;
            }
            let free_at = busy.max(h.quarantined_until.unwrap_or(0.0));
            // strict `<` keeps the lowest index on ties
            if best.map(|(_, t)| free_at < t).unwrap_or(true) {
                best = Some((i, free_at));
            }
        }
        let (lane, free_at) = match best {
            Some(b) => b,
            None => panic!(
                "all {} evaluation lanes retired — the fault model killed every lane; \
                 aborting the run",
                fs.lanes.len()
            ),
        };
        // free_at >= quarantined_until by construction, so selection
        // always clears the window; `probation` stays set until a
        // clean completion readmits the lane
        fs.lanes[lane].quarantined_until = None;
        (lane, free_at.max(not_before_s))
    }

    /// Analytic cost-model estimate (geomean `total_us` over the
    /// feedback suite) used as the outlier-confirmation reference —
    /// the same recipe the screen tier scores with (DESIGN.md §10).
    /// `None` when the workload cannot estimate this genome; the
    /// confirmation check is then skipped.
    fn expected_us(&self, genome: &KernelGenome) -> Option<f64> {
        let workload = self.backend.workload();
        let mut vals = Vec::with_capacity(self.feedback_suite.configs.len());
        for cfg in &self.feedback_suite.configs {
            let est = workload.estimate(&crate::gpu::MI300, genome, cfg).ok()?.total_us;
            if !est.is_finite() || est <= 0.0 {
                return None;
            }
            vals.push(est);
        }
        if vals.is_empty() {
            return None;
        }
        Some(geomean(&vals))
    }

    /// Drain the in-flight stream submission with the **earliest
    /// virtual completion time** (ties resolve to the earliest
    /// ticket), blocking on its lane worker if it is still running.
    /// Returns `None` when nothing is in flight.
    ///
    /// Because each virtual lane's clock only moves forward and each
    /// lane worker finishes jobs in FIFO order, the completion order
    /// this returns is a pure function of the submission sequence —
    /// never of OS scheduling (DESIGN.md §8).
    pub fn poll_completed(&mut self) -> Option<CompletedEval> {
        if self.pending.is_empty() {
            return None;
        }
        // strict `<` keeps the earliest-pushed (lowest-ticket) entry on
        // ties, which also guarantees an aliased original resolves
        // before its duplicates
        let mut earliest = 0;
        for (i, p) in self.pending.iter().enumerate().skip(1) {
            if p.completed_at_s < self.pending[earliest].completed_at_s {
                earliest = i;
            }
        }
        let p = self.pending.remove(earliest);
        match p.kind {
            PendingKind::Cached { outcome } => Some(CompletedEval {
                ticket: p.ticket,
                outcome,
                cached: true,
                submission_index: None,
                completed_at_s: p.completed_at_s,
            }),
            PendingKind::Alias { fingerprint } => {
                let outcome = self
                    .cache
                    .lookup(fingerprint) // the alias's counted hit
                    .expect("aliased submission completes before its duplicates");
                Some(CompletedEval {
                    ticket: p.ticket,
                    outcome,
                    cached: true,
                    submission_index: None,
                    completed_at_s: p.completed_at_s,
                })
            }
            PendingKind::Run {
                lane,
                fingerprint,
                inline_outcome,
                cost_s,
                profile,
                federated,
                attempt,
                fault,
                ..
            } => {
                let outcome = match inline_outcome {
                    Some(outcome) => outcome,
                    None => {
                        let StreamState::Threaded(executor) = &self.stream else {
                            unreachable!("worker-dispatched job without workers")
                        };
                        let (ticket, outcome) = executor.collect(lane);
                        debug_assert_eq!(
                            ticket, p.ticket,
                            "lane workers must finish jobs in FIFO order"
                        );
                        outcome
                    }
                };
                // fault-class outcomes never enter the cache: a retry
                // must re-evaluate, and a cached transient would leak
                // into other consumers as if it were a result
                if !outcome.is_fault() {
                    self.cache.insert(fingerprint, outcome.clone());
                }
                // commit-time accounting: with per-dispatch costs able
                // to vary (fault model), commits can happen out of
                // dispatch order, so busy time and the log index are
                // charged/assigned here — never at dispatch
                self.busy_lane_s += cost_s;
                let submission_index = self.log.len() as u64;
                if federated {
                    self.federated_hits += 1;
                }
                if let Some(fs) = &mut self.faults {
                    fs.on_commit(lane, fault, attempt, submission_index, p.completed_at_s);
                }
                self.log.push(SubmissionRecord {
                    index: submission_index,
                    completed_at_s: p.completed_at_s,
                    lane: lane as u32,
                    outcome: outcome.clone(),
                    profile,
                    federated,
                });
                Some(CompletedEval {
                    ticket: p.ticket,
                    outcome,
                    cached: false,
                    submission_index: Some(submission_index),
                    completed_at_s: p.completed_at_s,
                })
            }
        }
    }

    /// Push a whole batch through the stream path and wait for all of
    /// it — the streaming equivalent of [`EvalPlatform::submit_batch`]
    /// (same quota-truncation semantics: planning stops at the first
    /// entry the remaining quota cannot cover, so the result is a
    /// prefix-aligned vector). The genetic baseline evaluates its
    /// generations through this.
    pub fn submit_stream_batch(&mut self, genomes: &[KernelGenome]) -> Vec<BatchResult>
    where
        B: Send + 'static,
    {
        // the drain below consumes every pending completion, so prior
        // stream work must already be polled (same contract as the
        // barrier paths)
        debug_assert!(
            self.pending.is_empty(),
            "submit_stream_batch() while stream evaluations are in flight"
        );
        let remaining = match self.config.submission_quota {
            Some(q) => q.saturating_sub(self.submissions() + self.pending_runs()),
            None => u64::MAX,
        };
        let mut planned = 0u64;
        let mut tickets = Vec::with_capacity(genomes.len());
        for genome in genomes {
            let fp = genome.fingerprint_hash();
            let free = self.cache.enabled()
                && (self.cache.peek(fp).is_some() || self.pending_run_with_fp(fp).is_some());
            if !free {
                if planned >= remaining {
                    break;
                }
                planned += 1;
            }
            tickets.push(self.submit_stream(genome));
        }
        let mut by_ticket: HashMap<u64, BatchResult> = HashMap::with_capacity(tickets.len());
        while let Some(done) = self.poll_completed() {
            by_ticket.insert(
                done.ticket,
                BatchResult {
                    outcome: done.outcome,
                    cached: done.cached,
                    submission_index: done.submission_index,
                    completed_at_s: done.completed_at_s,
                },
            );
        }
        tickets
            .into_iter()
            .map(|t| by_ticket.remove(&t).expect("every ticket completes"))
            .collect()
    }

    /// Model a scheduling barrier: every lane waits for the slowest
    /// one (lockstep's "plan the next round only after the whole batch
    /// completes", DESIGN.md §8). A no-op with a single lane; must not
    /// be called with stream work in flight.
    pub fn sync_lanes(&mut self) {
        debug_assert!(
            self.pending.is_empty(),
            "sync_lanes() while stream evaluations are in flight"
        );
        let barrier = self.wall_clock_s();
        for lane in &mut self.lane_busy_until {
            *lane = barrier;
        }
    }

    /// Fraction of total lane-time spent evaluating: busy lane-seconds
    /// over `lanes x` simulated makespan. A zero makespan (zero-budget
    /// or all-cache-hit run) reports 0.0 — no lane-time existed to
    /// occupy, and anything else would leak a NaN or a vacuous 100%
    /// into the reports.
    pub fn lane_occupancy(&self) -> f64 {
        let makespan = self.wall_clock_s();
        if makespan <= 0.0 {
            return 0.0;
        }
        self.busy_lane_s / (self.lane_busy_until.len() as f64 * makespan)
    }

    /// The lane-assignment rule shared by every submission path:
    /// earliest-free virtual lane, ties to the LOWEST index. With
    /// uniform submission costs this is exactly `run_batch`'s static
    /// round-robin partition (job i -> lane i mod N), which is what
    /// keeps stream and barrier evaluation agreeing on which lane
    /// backend times which job.
    fn earliest_free_lane(&self) -> usize {
        let mut lane = 0;
        for (i, &busy) in self.lane_busy_until.iter().enumerate().skip(1) {
            if busy < self.lane_busy_until[lane] {
                lane = i;
            }
        }
        lane
    }

    /// Record one completed submission: quota, earliest-free-lane wall
    /// clock, and the log line. Returns (log index, completion time).
    fn account_submission(
        &mut self,
        outcome: EvalOutcome,
        profile: Option<ProfileReport>,
        federated: bool,
    ) -> (u64, f64) {
        let cost = self.backend.submission_cost_s();
        let lane = self.earliest_free_lane();
        self.lane_busy_until[lane] += cost;
        self.busy_lane_s += cost;
        let completed_at_s = self.lane_busy_until[lane];
        let index = self.log.len() as u64;
        if federated {
            self.federated_hits += 1;
        }
        self.log.push(SubmissionRecord {
            index,
            completed_at_s,
            lane: lane as u32,
            outcome,
            profile,
            federated,
        });
        (index, completed_at_s)
    }

    /// Bottleneck-classified profile of one genome, straight off the
    /// backend's counter model (pure — no RNG draw, no quota, no
    /// platform time). Journaling uses this for cache-served results,
    /// whose log line never existed.
    pub fn profile_of(&self, genome: &KernelGenome) -> Option<ProfileReport> {
        self.backend.profile(genome)
    }

    /// Read-only cache probe (planning aid for batch callers: a cached
    /// genome will not consume quota). Does not count toward stats.
    pub fn cached_outcome(&self, genome: &KernelGenome) -> Option<EvalOutcome> {
        self.cache.peek(genome.fingerprint_hash()).cloned()
    }

    /// (hits, misses) of counted cache lookups on the batch path.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Platform accounting for a run-store checkpoint (DESIGN.md §9).
    ///
    /// **Faults off** (the historical contract): rolled back to the
    /// last committed completion — in-flight stream submissions are
    /// unwound exactly (lane clocks restore the recorded pre-dispatch
    /// values; quota/ticket/cache-stat effects are subtracted) because
    /// the scheduler re-submits the corresponding experiments on
    /// resume through the normal path, which re-derives identical
    /// lanes, tickets, and clocks. Busy time needs no rollback at all:
    /// it is charged at commit, so the live value is already
    /// committed-only.
    ///
    /// **Faults on**: no unwind. With per-dispatch costs able to vary,
    /// commits happen out of dispatch order and an unwind would have
    /// to rewind the backend's noise stream non-sequentially — so the
    /// checkpoint instead persists the live clocks/ticket/backend
    /// state plus every in-flight entry **as already-evaluated data**
    /// (DESIGN.md §14); a restore re-creates the pending set verbatim
    /// and polls proceed as if the process never died.
    ///
    /// Errors when the backend cannot serialize its state.
    pub fn checkpoint_state(&self) -> Result<PlatformCheckpoint, String> {
        if !self.capture_backend_state {
            return Err(
                "platform state capture is disabled (call enable_state_capture before \
                 submitting anything a checkpoint must cover)"
                    .into(),
            );
        }
        if self.faults.is_some() {
            return self.checkpoint_state_faults();
        }
        // Inline in-flight dispatches already advanced the parent's
        // noise stream at submit time; rewinding them means rewinding
        // the backend to the oldest dispatch's recorded pre-state.
        let backend = self
            .pending
            .iter()
            .find_map(|p| match &p.kind {
                PendingKind::Run {
                    prev_backend_state: Some(s),
                    ..
                } => Some(s.clone()),
                _ => None,
            })
            .or_else(|| self.backend.state_json())
            .ok_or_else(|| {
                format!("backend '{}' does not support checkpointing", self.backend.name())
            })?;
        let mut lanes = self.lane_busy_until.clone();
        let mut pending_hits = 0u64;
        let mut pending_misses = 0u64;
        // unwind in reverse dispatch order so stacked dispatches on one
        // lane restore the oldest recorded value. Stat rollback mirrors
        // submit_stream's counting exactly: a Run's miss (and a Cached
        // entry's hit) is only ever counted when the cache is enabled —
        // with it disabled, stats stay (0, 0).
        let counted = self.cache.enabled();
        for p in self.pending.iter().rev() {
            match &p.kind {
                PendingKind::Run {
                    lane,
                    prev_lane_clock,
                    ..
                } => {
                    lanes[*lane] = *prev_lane_clock;
                    pending_misses += counted as u64;
                }
                PendingKind::Cached { .. } => pending_hits += 1,
                PendingKind::Alias { .. } => {}
            }
        }
        let (hits, misses) = self.cache.stats();
        Ok(PlatformCheckpoint {
            lane_busy_until: lanes,
            // busy time is committed-only by construction (charged at
            // poll/account time) — no in-flight rollback needed
            busy_lane_s: self.busy_lane_s,
            next_ticket: self.next_ticket - self.pending.len() as u64,
            cache_hits: hits - pending_hits,
            cache_misses: misses - pending_misses,
            backend,
            prespawn_backend: self.prespawn_state.clone(),
            stream_threaded: matches!(self.stream, StreamState::Threaded(_)),
            stream_log_start: self.stream_log_start,
            // committed-only by construction (incremented at poll /
            // account time), so no in-flight rollback is needed; the
            // pending_misses rollback above already covers fed pending
            // runs, which counted their miss at submit
            federated_hits: self.federated_hits,
            faults: None,
        })
    }

    /// The faults-mode checkpoint (see [`EvalPlatform::checkpoint_state`]):
    /// live accounting plus the pending set persisted as data. Every
    /// in-flight run already carries its outcome (the fault model
    /// forces the inline stream path), so no evaluation is ever
    /// re-run — or unwound — across a kill/resume.
    fn checkpoint_state_faults(&self) -> Result<PlatformCheckpoint, String> {
        use crate::util::json::{self as json, Json};
        let fs = self.faults.as_ref().expect("caller checked");
        debug_assert!(
            fs.events.is_empty(),
            "fault events must be drained (journaled) before a checkpoint"
        );
        let backend = self.backend.state_json().ok_or_else(|| {
            format!("backend '{}' does not support checkpointing", self.backend.name())
        })?;
        let mut pending = Vec::with_capacity(self.pending.len());
        for p in &self.pending {
            let entry = match &p.kind {
                PendingKind::Run {
                    lane,
                    fingerprint,
                    inline_outcome,
                    cost_s,
                    profile,
                    federated,
                    attempt,
                    fault,
                    ..
                } => {
                    let outcome = inline_outcome.as_ref().ok_or(
                        "faults-mode checkpoint found a worker-dispatched job in flight \
                         (the fault model must evaluate inline)",
                    )?;
                    let mut pairs = Vec::new();
                    if *attempt > 0 {
                        pairs.push(("attempt", Json::Num(*attempt as f64)));
                    }
                    pairs.push(("completed_at_s", Json::Num(p.completed_at_s)));
                    pairs.push(("cost_s", Json::Num(*cost_s)));
                    if let Some(tag) = fault {
                        pairs.push(("fault", Json::Str(tag.kind().into())));
                    }
                    if *federated {
                        pairs.push(("federated", Json::Bool(true)));
                    }
                    pairs.push(("fingerprint", json::u64_hex(*fingerprint)));
                    pairs.push(("kind", Json::Str("run".into())));
                    pairs.push(("lane", Json::Num(*lane as f64)));
                    pairs.push(("outcome", outcome.to_json()));
                    if let Some(pr) = profile {
                        pairs.push(("profile", pr.to_json()));
                    }
                    pairs.push(("ticket", Json::Num(p.ticket as f64)));
                    Json::obj(pairs)
                }
                PendingKind::Cached { outcome } => Json::obj(vec![
                    ("completed_at_s", Json::Num(p.completed_at_s)),
                    ("kind", Json::Str("cached".into())),
                    ("outcome", outcome.to_json()),
                    ("ticket", Json::Num(p.ticket as f64)),
                ]),
                PendingKind::Alias { fingerprint } => Json::obj(vec![
                    ("completed_at_s", Json::Num(p.completed_at_s)),
                    ("fingerprint", json::u64_hex(*fingerprint)),
                    ("kind", Json::Str("alias".into())),
                    ("ticket", Json::Num(p.ticket as f64)),
                ]),
            };
            pending.push(entry);
        }
        let faults_obj = Json::obj(vec![
            (
                "lanes",
                Json::Arr(fs.lanes.iter().map(|l| l.to_json()).collect()),
            ),
            ("pending", Json::Arr(pending)),
            ("stats", fs.stats.to_json()),
        ]);
        let (hits, misses) = self.cache.stats();
        Ok(PlatformCheckpoint {
            lane_busy_until: self.lane_busy_until.clone(),
            busy_lane_s: self.busy_lane_s,
            next_ticket: self.next_ticket,
            cache_hits: hits,
            cache_misses: misses,
            backend,
            prespawn_backend: self.prespawn_state.clone(),
            stream_threaded: matches!(self.stream, StreamState::Threaded(_)),
            stream_log_start: self.stream_log_start,
            federated_hits: self.federated_hits,
            faults: Some(faults_obj),
        })
    }

    /// Restore a freshly constructed platform from a checkpoint: the
    /// submission log (journal-derived, in submission order), the eval
    /// cache contents, and — when the crashed run had live stream
    /// workers — re-forked lane backends fast-forwarded by replaying
    /// each lane's committed FIFO prefix (`committed_genomes` aligns
    /// with `log`). Replay outcomes are compared against the ledger, so
    /// a corrupted journal or non-deterministic backend fails loudly
    /// instead of silently diverging.
    pub fn restore_checkpoint(
        &mut self,
        cp: &PlatformCheckpoint,
        log: Vec<SubmissionRecord>,
        cache_entries: Vec<(u64, EvalOutcome)>,
        committed_genomes: &[KernelGenome],
    ) -> Result<(), String>
    where
        B: Send + 'static,
    {
        assert!(
            self.log.is_empty() && self.pending.is_empty(),
            "restore_checkpoint() expects a freshly constructed platform"
        );
        if cp.lane_busy_until.len() != self.lane_busy_until.len() {
            return Err(format!(
                "checkpoint has {} lanes but the platform is configured for {} \
                 (platform.parallelism must match the checkpointed run)",
                cp.lane_busy_until.len(),
                self.lane_busy_until.len()
            ));
        }
        if committed_genomes.len() != log.len() {
            return Err(format!(
                "{} committed genomes for {} log entries",
                committed_genomes.len(),
                log.len()
            ));
        }
        if cp.stream_threaded {
            // re-fork the lane workers from the pre-spawn parent state,
            // then advance each by its committed jobs: a lane backend's
            // state is a pure function of (fork state, FIFO prefix)
            let prespawn = cp
                .prespawn_backend
                .as_ref()
                .ok_or("checkpoint marks live stream workers but has no pre-spawn state")?;
            self.backend.restore_state(prespawn)?;
            let lanes = self.config.parallelism as usize;
            let mut lane_backends = Vec::with_capacity(lanes);
            for lane in 0..lanes as u64 {
                lane_backends.push(
                    self.backend
                        .fork_lane(lane)
                        .ok_or("backend no longer supports lane forking")?,
                );
            }
            for (i, rec) in log.iter().enumerate().skip(cp.stream_log_start as usize) {
                if rec.federated {
                    // federation hits consumed a lane slot but no lane
                    // backend ever evaluated them — replaying one would
                    // advance the lane's noise stream and falsely flag
                    // divergence
                    continue;
                }
                let lane = rec.lane as usize;
                if lane >= lane_backends.len() {
                    return Err(format!("log entry {i} names out-of-range lane {lane}"));
                }
                let replayed = executor::evaluate_one(
                    &mut lane_backends[lane],
                    &self.feedback_suite,
                    self.config.reps_per_config,
                    &committed_genomes[i],
                );
                if replayed != rec.outcome {
                    return Err(format!(
                        "ledger/backend divergence replaying submission {i} on lane {lane}: \
                         journal says {:?}, replay produced {replayed:?}",
                        rec.outcome
                    ));
                }
            }
            self.stream = StreamState::Threaded(StreamExecutor::from_backends(
                lane_backends,
                &self.feedback_suite,
                self.config.reps_per_config,
            ));
            self.prespawn_state = Some(prespawn.clone());
        }
        // parent backend continues from its checkpointed stream state;
        // the resumed platform keeps checkpointing, so capture stays on
        self.backend.restore_state(&cp.backend)?;
        self.capture_backend_state = true;
        self.stream_log_start = cp.stream_log_start;
        self.log = log;
        self.lane_busy_until = cp.lane_busy_until.clone();
        self.busy_lane_s = cp.busy_lane_s;
        self.next_ticket = cp.next_ticket;
        self.federated_hits = cp.federated_hits;
        self.cache = EvalCache::restore(
            self.config.cache_results,
            cache_entries,
            cp.cache_hits,
            cp.cache_misses,
        );
        if let Some(fobj) = &cp.faults {
            self.restore_faults(fobj)?;
        }
        Ok(())
    }

    /// Restore the faults-mode checkpoint object: lane health, fault
    /// counters, and the in-flight pending set re-created verbatim as
    /// already-evaluated data (the stream stays `Idle` and re-decides
    /// the inline path on the next dispatch). Requires
    /// [`EvalPlatform::enable_faults`] to have been called — resuming
    /// a chaos run with the fault model off would silently change the
    /// trajectory, so it fails loudly instead.
    fn restore_faults(&mut self, fobj: &crate::util::json::Json) -> Result<(), String> {
        use super::faults::{FaultStats, FaultTag, LaneHealth};
        use crate::util::json::{self as json};
        let fs = self.faults.as_mut().ok_or(
            "checkpoint carries fault-model state but the fault model is off \
             (resume with the original [faults] config)",
        )?;
        if let Some(lanes) = fobj.get("lanes").and_then(|v| v.as_arr()) {
            if lanes.len() != fs.lanes.len() {
                return Err(format!(
                    "checkpoint has {} lane-health records for {} lanes",
                    lanes.len(),
                    fs.lanes.len()
                ));
            }
            for (i, l) in lanes.iter().enumerate() {
                fs.lanes[i] = LaneHealth::from_json(l)?;
            }
        }
        if let Some(stats) = fobj.get("stats") {
            fs.stats = FaultStats::from_json(stats);
        }
        if let Some(entries) = fobj.get("pending").and_then(|v| v.as_arr()) {
            for e in entries {
                let ticket = json::req_u64(e, "ticket")?;
                let completed_at_s = json::req_f64(e, "completed_at_s")?;
                let kind = match json::req_str(e, "kind")? {
                    "run" => PendingKind::Run {
                        lane: json::req_u64(e, "lane")? as usize,
                        fingerprint: json::parse_u64_hex(
                            e.get("fingerprint").ok_or("pending entry missing fingerprint")?,
                        )?,
                        inline_outcome: Some(EvalOutcome::from_json(
                            e.get("outcome").ok_or("pending entry missing outcome")?,
                        )?),
                        cost_s: json::req_f64(e, "cost_s")?,
                        // unused in faults mode: checkpoints persist
                        // pending entries instead of unwinding them
                        prev_lane_clock: 0.0,
                        prev_backend_state: None,
                        profile: e
                            .get("profile")
                            .map(ProfileReport::from_json)
                            .transpose()?,
                        federated: e
                            .get("federated")
                            .and_then(|x| x.as_bool())
                            .unwrap_or(false),
                        attempt: e.get("attempt").and_then(|x| x.as_u64()).unwrap_or(0)
                            as u32,
                        fault: e
                            .get("fault")
                            .and_then(|x| x.as_str())
                            .and_then(FaultTag::from_kind),
                    },
                    "cached" => PendingKind::Cached {
                        outcome: EvalOutcome::from_json(
                            e.get("outcome").ok_or("pending entry missing outcome")?,
                        )?,
                    },
                    "alias" => PendingKind::Alias {
                        fingerprint: json::parse_u64_hex(
                            e.get("fingerprint").ok_or("pending entry missing fingerprint")?,
                        )?,
                    },
                    other => return Err(format!("unknown pending kind '{other}'")),
                };
                self.pending.push(PendingEval {
                    ticket,
                    completed_at_s,
                    kind,
                });
            }
        }
        Ok(())
    }

    /// Final leaderboard score: geomean over a (typically 18-size)
    /// suite, taken outside the submission quota (the organisers run
    /// this once at the end).
    pub fn leaderboard_score(
        &mut self,
        genome: &KernelGenome,
        suite: &BenchmarkSuite,
    ) -> Result<f64, EvalError> {
        self.backend.check(genome)?;
        let mut times = Vec::with_capacity(suite.configs.len());
        for cfg in &suite.configs {
            let mut best = f64::INFINITY;
            for _ in 0..self.config.reps_per_config.max(1) {
                best = best.min(self.backend.measure(genome, cfg)?);
            }
            times.push(best);
        }
        Ok(geomean(&times))
    }

    /// Direct backend access (reports/benches only — the scientist
    /// never touches this).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome};
    use crate::sim::SimBackend;
    use crate::workload::BenchmarkSuite;

    fn platform() -> EvalPlatform<SimBackend> {
        EvalPlatform::new(SimBackend::new(42), PlatformConfig::default())
    }

    #[test]
    fn successful_submission_returns_six_timings() {
        let mut p = platform();
        let out = p.submit(&seeds::mfma_seed());
        let t = out.timings().expect("should time");
        assert_eq!(t.len(), 6);
        assert!(t.iter().all(|&x| x > 0.0));
        assert_eq!(p.submissions(), 1);
    }

    #[test]
    fn compile_failure_logged() {
        let mut p = platform();
        let bad = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        let out = p.submit(&bad);
        assert!(matches!(out, EvalOutcome::CompileFailure(_)));
        assert!(matches!(
            p.log()[0].outcome,
            EvalOutcome::CompileFailure(_)
        ));
    }

    #[test]
    fn sequential_clock_advances_per_submission() {
        let mut p = platform();
        p.submit(&seeds::mfma_seed());
        let t1 = p.wall_clock_s();
        p.submit(&seeds::mfma_seed());
        let t2 = p.wall_clock_s();
        assert!(t2 > t1);
        assert!((t2 - 2.0 * t1).abs() < 1e-9, "strictly serialized");
    }

    #[test]
    fn parallel_lanes_share_wall_clock() {
        let mut seq = EvalPlatform::new(SimBackend::new(1), PlatformConfig::default());
        let mut par = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                parallelism: 3,
                ..Default::default()
            },
        );
        for _ in 0..6 {
            seq.submit(&seeds::mfma_seed());
            par.submit(&seeds::mfma_seed());
        }
        assert!((par.wall_clock_s() - seq.wall_clock_s() / 3.0).abs() < 1e-6);
    }

    #[test]
    fn quota_enforced() {
        let mut p = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        p.submit(&seeds::mfma_seed());
        assert!(!p.quota_exhausted());
        p.submit(&seeds::mfma_seed());
        assert!(p.quota_exhausted());
    }

    #[test]
    #[should_panic(expected = "quota exhausted")]
    fn submit_past_quota_panics() {
        let mut p = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                submission_quota: Some(1),
                ..Default::default()
            },
        );
        p.submit(&seeds::mfma_seed());
        p.submit(&seeds::mfma_seed());
    }

    #[test]
    fn batch_matches_sequential_at_one_lane() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
                .into_iter()
                .take(5)
                .map(|(_, g)| g)
                .collect();
        let mut seq = EvalPlatform::new(SimBackend::new(4), PlatformConfig::default());
        let expected: Vec<EvalOutcome> = jobs.iter().map(|g| seq.submit(g)).collect();
        let mut bat = EvalPlatform::new(SimBackend::new(4), PlatformConfig::default());
        let results = bat.submit_batch(&jobs);
        assert_eq!(results.len(), jobs.len());
        for (i, (r, e)) in results.iter().zip(&expected).enumerate() {
            assert!(!r.cached);
            assert_eq!(r.submission_index, Some(i as u64));
            assert_eq!(&r.outcome, e, "job {i}");
        }
        assert_eq!(bat.wall_clock_s(), seq.wall_clock_s());
        assert_eq!(bat.submissions(), seq.submissions());
    }

    #[test]
    fn batch_cache_hit_is_free() {
        let mut p = EvalPlatform::new(
            SimBackend::new(2),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let g = seeds::mfma_seed();
        let first = p.submit_batch(std::slice::from_ref(&g));
        assert!(!first[0].cached);
        assert_eq!(p.submissions(), 1);
        let clock = p.wall_clock_s();
        let second = p.submit_batch(std::slice::from_ref(&g));
        assert!(second[0].cached);
        assert_eq!(second[0].outcome, first[0].outcome, "identical EvalOutcome");
        assert_eq!(second[0].submission_index, None);
        assert_eq!(p.submissions(), 1, "cache hit consumes no quota");
        assert_eq!(p.wall_clock_s(), clock, "cache hit consumes no platform time");
        assert_eq!(p.cache_stats().0, 1);
    }

    #[test]
    fn in_batch_duplicates_are_served_once() {
        let mut p = EvalPlatform::new(SimBackend::new(12), PlatformConfig::default());
        let g = seeds::mfma_seed();
        let other = seeds::human_oracle();
        let batch = vec![g.clone(), other.clone(), g.clone()];
        let results = p.submit_batch(&batch);
        assert_eq!(results.len(), 3);
        assert!(!results[0].cached && !results[1].cached);
        assert!(results[2].cached, "second occurrence aliases the first");
        assert_eq!(results[2].outcome, results[0].outcome);
        assert_eq!(results[2].submission_index, None);
        assert_eq!(p.submissions(), 2, "the duplicate consumed no quota");
        assert_eq!(p.cache_stats().0, 1, "alias counted as a cache hit");
        // with the cache disabled, in-batch duplicates evaluate twice
        let mut raw = EvalPlatform::new(
            SimBackend::new(12),
            PlatformConfig {
                cache_results: false,
                ..Default::default()
            },
        );
        let results = raw.submit_batch(&batch);
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| !r.cached));
        assert_eq!(raw.submissions(), 3);
    }

    #[test]
    fn batch_truncates_at_quota() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(4)
                .map(|(_, g)| g)
                .collect();
        let mut p = EvalPlatform::new(
            SimBackend::new(3),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let results = p.submit_batch(&jobs);
        assert_eq!(results.len(), 2);
        assert_eq!(p.submissions(), 2);
        assert!(p.quota_exhausted());
    }

    #[test]
    fn cache_disabled_reevaluates() {
        let mut p = EvalPlatform::new(
            SimBackend::new(6),
            PlatformConfig {
                cache_results: false,
                ..Default::default()
            },
        );
        let g = seeds::mfma_seed();
        let a = p.submit_batch(std::slice::from_ref(&g));
        let b = p.submit_batch(std::slice::from_ref(&g));
        assert!(!a[0].cached && !b[0].cached);
        assert_eq!(p.submissions(), 2);
        assert!(p.cached_outcome(&g).is_none());
    }

    #[test]
    fn stream_single_lane_is_bit_identical_to_sequential_submits() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
                .into_iter()
                .take(5)
                .map(|(_, g)| g)
                .collect();
        let mut seq = EvalPlatform::new(SimBackend::new(8), PlatformConfig::default());
        let expected: Vec<EvalOutcome> = jobs.iter().map(|g| seq.submit(g)).collect();
        let mut stream = EvalPlatform::new(SimBackend::new(8), PlatformConfig::default());
        let tickets: Vec<u64> = jobs.iter().map(|g| stream.submit_stream(g)).collect();
        assert_eq!(stream.in_flight(), jobs.len());
        for (i, (ticket, expected)) in tickets.iter().zip(&expected).enumerate() {
            let done = stream.poll_completed().expect("in flight");
            assert_eq!(done.ticket, *ticket, "completion order == submission order");
            assert_eq!(&done.outcome, expected, "job {i}");
            assert!(!done.cached);
            assert_eq!(done.submission_index, Some(i as u64));
        }
        assert!(stream.poll_completed().is_none());
        assert_eq!(stream.wall_clock_s(), seq.wall_clock_s());
        assert_eq!(stream.submissions(), seq.submissions());
        let seq_times: Vec<f64> = seq.log().iter().map(|r| r.completed_at_s).collect();
        let stream_times: Vec<f64> =
            stream.log().iter().map(|r| r.completed_at_s).collect();
        assert_eq!(seq_times, stream_times);
    }

    #[test]
    fn stream_multi_lane_completes_in_virtual_clock_order() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(6)
                .map(|(_, g)| g)
                .collect();
        let run_once = || {
            let mut p = EvalPlatform::new(
                SimBackend::new(14),
                PlatformConfig {
                    parallelism: 3,
                    ..Default::default()
                },
            );
            for g in &jobs {
                p.submit_stream(g);
            }
            let mut outcomes = Vec::new();
            let mut i = 0u64;
            while let Some(done) = p.poll_completed() {
                assert_eq!(done.ticket, i, "virtual-clock order breaks ties by ticket");
                assert_eq!(done.submission_index, Some(i));
                // 3 lanes, 90 s each: jobs 0..2 land at 90 s, 3..5 at 180 s
                let expected_t = 90.0 * (i / 3 + 1) as f64;
                assert!((done.completed_at_s - expected_t).abs() < 1e-9);
                outcomes.push(done.outcome);
                i += 1;
            }
            assert_eq!(i, 6);
            assert!((p.wall_clock_s() - 180.0).abs() < 1e-9);
            assert!((p.lane_occupancy() - 1.0).abs() < 1e-12, "fully packed lanes");
            outcomes
        };
        assert_eq!(run_once(), run_once(), "stream results are deterministic per seed");
    }

    #[test]
    fn stream_interleaves_submissions_with_completions() {
        // the steady-state usage pattern: drain one, refill one
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
                .into_iter()
                .take(6)
                .map(|(_, g)| g)
                .collect();
        let mut p = EvalPlatform::new(
            SimBackend::new(23),
            PlatformConfig {
                parallelism: 2,
                ..Default::default()
            },
        );
        p.submit_stream(&jobs[0]);
        p.submit_stream(&jobs[1]);
        for next in 2..jobs.len() {
            let done = p.poll_completed().expect("in flight");
            assert!(done.outcome.is_success());
            p.submit_stream(&jobs[next]);
            assert_eq!(p.in_flight(), 2, "a lane refills as soon as one frees");
        }
        while p.poll_completed().is_some() {}
        assert_eq!(p.submissions(), 6);
        // 6 uniform submissions over 2 continuously-fed lanes
        assert!((p.wall_clock_s() - 270.0).abs() < 1e-9);
        for (i, rec) in p.log().iter().enumerate() {
            assert_eq!(rec.index, i as u64, "log stays in submission order");
        }
    }

    #[test]
    fn stream_cache_hits_and_inflight_aliases_are_free() {
        let mut p = EvalPlatform::new(
            SimBackend::new(31),
            PlatformConfig {
                parallelism: 2,
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let g = seeds::mfma_seed();
        let other = seeds::human_oracle();
        // duplicate of an in-flight run aliases it (free)
        let t0 = p.submit_stream(&g);
        let t1 = p.submit_stream(&other);
        let t2 = p.submit_stream(&g);
        let first = p.poll_completed().unwrap();
        assert_eq!(first.ticket, t0);
        assert!(!first.cached);
        let second = p.poll_completed().unwrap();
        assert_eq!(second.ticket, t1, "equal completion times drain in ticket order");
        let alias = p.poll_completed().unwrap();
        assert_eq!(alias.ticket, t2, "the alias resolves after its original");
        assert!(alias.cached);
        assert_eq!(alias.outcome, first.outcome);
        assert_eq!(alias.submission_index, None);
        assert_eq!(p.submissions(), 2, "the alias consumed no quota");
        let clock = p.wall_clock_s();
        // quota is exhausted, but cached genomes are still served
        let t3 = p.submit_stream(&g);
        let hit = p.poll_completed().unwrap();
        assert_eq!(hit.ticket, t3);
        assert!(hit.cached);
        assert_eq!(hit.outcome, first.outcome);
        assert_eq!(p.submissions(), 2);
        assert_eq!(p.wall_clock_s(), clock, "cache hit consumes no platform time");
    }

    #[test]
    #[should_panic(expected = "quota exhausted")]
    fn stream_counts_inflight_toward_quota() {
        let mut p = EvalPlatform::new(
            SimBackend::new(2),
            PlatformConfig {
                submission_quota: Some(1),
                cache_results: false,
                ..Default::default()
            },
        );
        p.submit_stream(&seeds::mfma_seed());
        // still in flight, but the quota is already spoken for
        p.submit_stream(&seeds::human_oracle());
    }

    #[test]
    fn stream_batch_matches_barrier_batch_at_one_lane() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(4)
                .map(|(_, g)| g)
                .collect();
        let mut barrier = EvalPlatform::new(SimBackend::new(6), PlatformConfig::default());
        let expected = barrier.submit_batch(&jobs);
        let mut stream = EvalPlatform::new(SimBackend::new(6), PlatformConfig::default());
        let results = stream.submit_stream_batch(&jobs);
        assert_eq!(results.len(), expected.len());
        for (r, e) in results.iter().zip(&expected) {
            assert_eq!(r.outcome, e.outcome);
            assert_eq!(r.cached, e.cached);
            assert_eq!(r.submission_index, e.submission_index);
        }
        assert_eq!(stream.wall_clock_s(), barrier.wall_clock_s());
        assert_eq!(stream.cache_stats(), barrier.cache_stats());
    }

    #[test]
    fn stream_batch_truncates_at_quota() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(4)
                .map(|(_, g)| g)
                .collect();
        let mut p = EvalPlatform::new(
            SimBackend::new(3),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let results = p.submit_stream_batch(&jobs);
        assert_eq!(results.len(), 2);
        assert_eq!(p.submissions(), 2);
        assert!(p.quota_exhausted());
    }

    #[test]
    fn sync_lanes_models_the_lockstep_barrier() {
        let mut p = EvalPlatform::new(
            SimBackend::new(5),
            PlatformConfig {
                parallelism: 3,
                ..Default::default()
            },
        );
        // full round: all three lanes busy to 90 s, sync is a no-op
        let jobs = crate::test_support::distinct_genomes(5);
        p.submit_batch(&jobs[..3]);
        p.sync_lanes();
        assert!((p.wall_clock_s() - 90.0).abs() < 1e-9);
        // partial round: two lanes to 180 s, one idles at the barrier
        p.submit_batch(&jobs[3..]);
        p.sync_lanes();
        assert!((p.wall_clock_s() - 180.0).abs() < 1e-9);
        // 5 busy submissions over 3 lanes x 180 s of makespan
        assert!((p.lane_occupancy() - 5.0 * 90.0 / (3.0 * 180.0)).abs() < 1e-12);
        // the barrier means the next submission starts after 180 s on
        // every lane, not on the idle lane at 90 s
        p.submit(&jobs[0]);
        assert!((p.wall_clock_s() - 270.0).abs() < 1e-9);
    }

    /// Drive `n` stream submissions + drains on a fresh platform,
    /// checkpointing after `ckpt_at` completions, then restore a second
    /// platform from that checkpoint and check both finish the
    /// remaining jobs bit-identically.
    fn stream_checkpoint_roundtrip(lanes: u32, ckpt_at: usize) {
        let jobs = crate::test_support::distinct_genomes(8);
        let mk = || {
            let mut p = EvalPlatform::new(
                SimBackend::new(33),
                PlatformConfig {
                    parallelism: lanes,
                    ..Default::default()
                },
            );
            p.enable_state_capture();
            p
        };
        // reference: uninterrupted run
        let mut live = mk();
        let mut live_outcomes = Vec::new();
        for g in &jobs {
            live.submit_stream(g);
        }
        let mut cp = None;
        let mut resubmit_from = 0usize;
        for i in 0..jobs.len() {
            let done = live.poll_completed().unwrap();
            live_outcomes.push(done.outcome);
            if i + 1 == ckpt_at {
                cp = Some(live.checkpoint_state().unwrap());
                // everything not yet committed gets re-submitted on the
                // restored platform, as the scheduler would on resume
                resubmit_from = i + 1;
            }
        }
        let cp = cp.unwrap();
        // restored platform: rebuild the log + cache from the committed
        // prefix (what the journal would hold)
        let committed: Vec<KernelGenome> = jobs[..resubmit_from].to_vec();
        let log: Vec<SubmissionRecord> = live.log()[..resubmit_from].to_vec();
        let cache_entries: Vec<(u64, EvalOutcome)> = log
            .iter()
            .enumerate()
            .map(|(i, r)| (committed[i].fingerprint_hash(), r.outcome.clone()))
            .collect();
        let mut resumed = mk();
        resumed
            .restore_checkpoint(&cp, log, cache_entries, &committed)
            .unwrap();
        assert_eq!(resumed.submissions(), resubmit_from as u64);
        for g in &jobs[resubmit_from..] {
            resumed.submit_stream(g);
        }
        let mut resumed_outcomes = Vec::new();
        while let Some(done) = resumed.poll_completed() {
            resumed_outcomes.push(done.outcome);
        }
        assert_eq!(
            &live_outcomes[resubmit_from..],
            &resumed_outcomes[..],
            "lanes={lanes} ckpt_at={ckpt_at}: resumed tail must be bit-identical"
        );
        assert_eq!(resumed.submissions(), live.submissions());
        assert_eq!(resumed.wall_clock_s(), live.wall_clock_s());
        assert_eq!(resumed.cache_stats(), live.cache_stats());
        let live_log: Vec<(u64, f64, u32)> =
            live.log().iter().map(|r| (r.index, r.completed_at_s, r.lane)).collect();
        let resumed_log: Vec<(u64, f64, u32)> =
            resumed.log().iter().map(|r| (r.index, r.completed_at_s, r.lane)).collect();
        assert_eq!(live_log, resumed_log);
    }

    #[test]
    fn checkpoint_roundtrip_inline_stream() {
        stream_checkpoint_roundtrip(1, 3);
        stream_checkpoint_roundtrip(1, 7);
    }

    #[test]
    fn checkpoint_roundtrip_threaded_stream() {
        stream_checkpoint_roundtrip(3, 2);
        stream_checkpoint_roundtrip(3, 5);
    }

    #[test]
    fn checkpoint_unwinds_in_flight_work_exactly() {
        // checkpoint with jobs still in flight: the rolled-back clocks,
        // tickets, and cache stats equal a platform that never
        // dispatched them
        let jobs = crate::test_support::distinct_genomes(5);
        let mut p = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                parallelism: 2,
                ..Default::default()
            },
        );
        p.enable_state_capture();
        for g in &jobs {
            p.submit_stream(g);
        }
        p.poll_completed().unwrap(); // one committed, four in flight
        let cp = p.checkpoint_state().unwrap();
        assert_eq!(cp.next_ticket, 1);
        assert_eq!(cp.cache_misses, 1, "only the committed run's counted miss");
        // one committed 90 s submission on lane 0; lane 1 rolled back
        assert_eq!(cp.lane_busy_until, vec![90.0, 0.0]);
        assert_eq!(cp.busy_lane_s, 90.0);
        assert!(cp.stream_threaded);
        assert!(cp.prespawn_backend.is_some());
    }

    #[test]
    fn checkpoint_restore_rejects_lane_mismatch_and_divergence() {
        let jobs = crate::test_support::distinct_genomes(3);
        let mut p = EvalPlatform::new(SimBackend::new(4), PlatformConfig::default());
        p.enable_state_capture();
        for g in &jobs {
            p.submit_stream(g);
        }
        while p.poll_completed().is_some() {}
        let cp = p.checkpoint_state().unwrap();
        let mut wrong_lanes = EvalPlatform::new(
            SimBackend::new(4),
            PlatformConfig {
                parallelism: 2,
                ..Default::default()
            },
        );
        assert!(wrong_lanes
            .restore_checkpoint(&cp, p.log().to_vec(), vec![], &jobs)
            .unwrap_err()
            .contains("lanes"));
        let mut short = EvalPlatform::new(SimBackend::new(4), PlatformConfig::default());
        assert!(short
            .restore_checkpoint(&cp, p.log().to_vec(), vec![], &jobs[..1])
            .unwrap_err()
            .contains("log entries"));
    }

    #[test]
    fn leaderboard_score_is_geomean_over_suite() {
        let mut p = platform();
        let score = p
            .leaderboard_score(&seeds::human_oracle(), &BenchmarkSuite::leaderboard())
            .unwrap();
        assert!(score > 0.0);
        // leaderboard doesn't count against the submission log
        assert_eq!(p.submissions(), 0);
    }

    #[test]
    fn reps_take_minimum() {
        // more reps can only lower (or keep) the reported time
        let mut p1 = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                reps_per_config: 1,
                ..Default::default()
            },
        );
        let mut p5 = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                reps_per_config: 5,
                ..Default::default()
            },
        );
        let t1 = p1.submit(&seeds::mfma_seed());
        let t5 = p5.submit(&seeds::mfma_seed());
        let g1 = crate::metrics::geomean(t1.timings().unwrap());
        let g5 = crate::metrics::geomean(t5.timings().unwrap());
        // not strictly comparable (different rng draws) but both sane
        assert!(g1 > 0.0 && g5 > 0.0);
    }

    #[test]
    fn federated_stream_hit_reproduces_genuine_bookkeeping() {
        // run 1 evaluates for real; run 2 replays run 1's results out of
        // the federation store — every trajectory-visible number (clock,
        // quota, cache stats, log shape) must come out identical
        let jobs = crate::test_support::distinct_genomes(4);
        let run = |fed: Option<HashMap<u64, EvalOutcome>>| {
            let mut p = EvalPlatform::new(
                SimBackend::new(77),
                PlatformConfig {
                    parallelism: 2,
                    ..Default::default()
                },
            );
            if let Some(map) = fed {
                p.attach_federation(map);
            }
            for g in &jobs {
                p.submit_stream(g);
            }
            let mut outcomes = Vec::new();
            while let Some(done) = p.poll_completed() {
                outcomes.push((done.outcome, done.submission_index));
            }
            let log = p.log().to_vec();
            (outcomes, p.wall_clock_s(), p.submissions(), p.cache_stats(), p.federated_hits(), log)
        };
        let (outs1, clock1, subs1, stats1, hits1, log1) = run(None);
        assert_eq!(hits1, 0);
        assert!(log1.iter().all(|r| !r.federated));
        let store: HashMap<u64, EvalOutcome> = jobs
            .iter()
            .zip(&outs1)
            .map(|(g, (o, _))| (g.fingerprint_hash(), o.clone()))
            .collect();
        let (outs2, clock2, subs2, stats2, hits2, log2) = run(Some(store));
        assert_eq!(outs1, outs2, "stored results replay bit-identically");
        assert_eq!(clock1, clock2, "fed hits consume identical lane time");
        assert_eq!(subs1, subs2, "fed hits consume identical quota");
        assert_eq!(stats1, stats2, "fed hits leave the same counted-miss footprint");
        assert_eq!(hits2, jobs.len() as u64);
        assert!(log2.iter().all(|r| r.federated));
    }

    #[test]
    fn federated_batch_hit_consumes_quota_and_aliases_duplicates() {
        let g = seeds::mfma_seed();
        let mut first = EvalPlatform::new(SimBackend::new(51), PlatformConfig::default());
        let orig = first.submit(&g);
        let mut store = HashMap::new();
        store.insert(g.fingerprint_hash(), orig.clone());
        let mut p = EvalPlatform::new(
            SimBackend::new(51),
            PlatformConfig {
                submission_quota: Some(1),
                ..Default::default()
            },
        );
        p.attach_federation(store);
        let results = p.submit_batch(&[g.clone(), g.clone()]);
        assert_eq!(results.len(), 2);
        assert!(!results[0].cached, "a fed hit is a committed submission, not a cache hit");
        assert_eq!(results[0].outcome, orig);
        assert_eq!(results[0].submission_index, Some(0));
        assert!(results[1].cached, "in-batch duplicate of a fed hit aliases it for free");
        assert_eq!(results[1].outcome, orig);
        assert_eq!(p.submissions(), 1);
        assert!(p.quota_exhausted(), "a fed hit consumes quota like a genuine run");
        assert_eq!(p.federated_hits(), 1);
        assert!(p.log()[0].federated);
        assert!(p.wall_clock_s() > 0.0, "and lane time");
    }

    #[test]
    fn checkpoint_restore_skips_federated_log_entries() {
        // entry 1 comes from the store: no lane backend ever ran it, so
        // the restore replay must step over it — and post-restore
        // execution must still match the uninterrupted run exactly
        let jobs = crate::test_support::distinct_genomes(4);
        let mut prior = EvalPlatform::new(SimBackend::new(33), PlatformConfig::default());
        let stored = prior.submit(&jobs[1]);
        let mut store = HashMap::new();
        store.insert(jobs[1].fingerprint_hash(), stored);
        let mk = |fed: HashMap<u64, EvalOutcome>| {
            let mut p = EvalPlatform::new(
                SimBackend::new(33),
                PlatformConfig {
                    parallelism: 2,
                    ..Default::default()
                },
            );
            p.enable_state_capture();
            p.attach_federation(fed);
            p
        };
        let mut live = mk(store.clone());
        for g in &jobs[..3] {
            live.submit_stream(g);
        }
        while live.poll_completed().is_some() {}
        assert_eq!(live.federated_hits(), 1);
        assert!(live.log()[1].federated);
        let cp = live.checkpoint_state().unwrap();
        assert_eq!(cp.federated_hits, 1);
        let committed: Vec<KernelGenome> = jobs[..3].to_vec();
        let log = live.log().to_vec();
        let cache_entries: Vec<(u64, EvalOutcome)> = log
            .iter()
            .enumerate()
            .map(|(i, r)| (committed[i].fingerprint_hash(), r.outcome.clone()))
            .collect();
        let mut resumed = mk(store);
        resumed
            .restore_checkpoint(&cp, log, cache_entries, &committed)
            .unwrap();
        assert_eq!(resumed.federated_hits(), 1);
        live.submit_stream(&jobs[3]);
        resumed.submit_stream(&jobs[3]);
        let a = live.poll_completed().unwrap();
        let b = resumed.poll_completed().unwrap();
        assert_eq!(a.outcome, b.outcome, "post-restore evaluation stays bit-identical");
        assert_eq!(live.wall_clock_s(), resumed.wall_clock_s());
    }
}
