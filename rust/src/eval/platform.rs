//! The submission platform: sequential queue, gates, timing runs,
//! leaderboard scoring, and the simulated wall clock.

use super::{EvalBackend, EvalError};
use crate::genome::KernelGenome;
use crate::metrics::geomean;
use crate::population::EvalOutcome;
use crate::workload::BenchmarkSuite;

/// Platform policy knobs.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Timing repetitions per config (platform reports the minimum —
    /// standard benchmark practice).
    pub reps_per_config: u32,
    /// Concurrent submission lanes. The paper runs 1 ("good citizen");
    /// the §5.1 ablation raises it.
    pub parallelism: u32,
    /// Hard cap on total submissions (competition quota), if any.
    pub submission_quota: Option<u64>,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            reps_per_config: 3,
            parallelism: 1,
            submission_quota: None,
        }
    }
}

/// One line of the platform's submission log.
#[derive(Debug, Clone)]
pub struct SubmissionRecord {
    pub index: u64,
    /// Simulated wall-clock time (s) at which results became available.
    pub completed_at_s: f64,
    pub outcome: EvalOutcome,
}

/// The evaluation platform wrapping a backend.
pub struct EvalPlatform<B: EvalBackend> {
    backend: B,
    pub config: PlatformConfig,
    pub feedback_suite: BenchmarkSuite,
    log: Vec<SubmissionRecord>,
    /// Simulated wall clock, seconds. With `parallelism` lanes, each
    /// lane is a virtual worker; the clock advances to the earliest
    /// free lane at submit time.
    lane_busy_until: Vec<f64>,
}

impl<B: EvalBackend> EvalPlatform<B> {
    pub fn new(backend: B, config: PlatformConfig) -> Self {
        let lanes = config.parallelism.max(1) as usize;
        EvalPlatform {
            backend,
            config,
            feedback_suite: BenchmarkSuite::feedback(),
            log: Vec::new(),
            lane_busy_until: vec![0.0; lanes],
        }
    }

    /// Use a non-default feedback suite (the PJRT backend needs the
    /// testbed shapes).
    pub fn with_feedback_suite(mut self, suite: BenchmarkSuite) -> Self {
        self.feedback_suite = suite;
        self
    }

    pub fn backend_name(&self) -> String {
        self.backend.name().to_string()
    }

    pub fn submissions(&self) -> u64 {
        self.log.len() as u64
    }

    pub fn log(&self) -> &[SubmissionRecord] {
        &self.log
    }

    /// Simulated wall-clock seconds consumed so far (max over lanes).
    pub fn wall_clock_s(&self) -> f64 {
        self.lane_busy_until.iter().cloned().fold(0.0, f64::max)
    }

    /// Whether the quota (if any) is exhausted.
    pub fn quota_exhausted(&self) -> bool {
        self.config
            .submission_quota
            .map(|q| self.submissions() >= q)
            .unwrap_or(false)
    }

    /// Submit one kernel: gates, then `reps_per_config` timing reps on
    /// each feedback config (minimum reported). Advances the simulated
    /// clock on the earliest-free lane — the sequential default means
    /// strictly serialized submissions, as in the paper.
    pub fn submit(&mut self, genome: &KernelGenome) -> EvalOutcome {
        assert!(
            !self.quota_exhausted(),
            "platform quota exhausted ({} submissions)",
            self.submissions()
        );
        let outcome = self.run_gates_and_time(genome);
        // clock accounting
        let cost = self.backend.submission_cost_s();
        let lane = self
            .lane_busy_until
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.lane_busy_until[lane] += cost;
        let completed_at_s = self.lane_busy_until[lane];
        self.log.push(SubmissionRecord {
            index: self.log.len() as u64,
            completed_at_s,
            outcome: outcome.clone(),
        });
        outcome
    }

    fn run_gates_and_time(&mut self, genome: &KernelGenome) -> EvalOutcome {
        if let Err(e) = self.backend.check(genome) {
            return match e {
                EvalError::Compile(m) | EvalError::Unsupported(m) => {
                    EvalOutcome::CompileFailure(m)
                }
                EvalError::Incorrect(m) => EvalOutcome::IncorrectResult(m),
            };
        }
        let mut timings = Vec::with_capacity(self.feedback_suite.configs.len());
        for cfg in self.feedback_suite.configs.clone() {
            let mut best = f64::INFINITY;
            for _ in 0..self.config.reps_per_config.max(1) {
                match self.backend.measure(genome, &cfg) {
                    Ok(t) => best = best.min(t),
                    Err(e) => {
                        return match e {
                            EvalError::Incorrect(m) => EvalOutcome::IncorrectResult(m),
                            EvalError::Compile(m) | EvalError::Unsupported(m) => {
                                EvalOutcome::CompileFailure(m)
                            }
                        }
                    }
                }
            }
            timings.push(best);
        }
        EvalOutcome::Timings(timings)
    }

    /// Final leaderboard score: geomean over a (typically 18-size)
    /// suite, taken outside the submission quota (the organisers run
    /// this once at the end).
    pub fn leaderboard_score(
        &mut self,
        genome: &KernelGenome,
        suite: &BenchmarkSuite,
    ) -> Result<f64, EvalError> {
        self.backend.check(genome)?;
        let mut times = Vec::with_capacity(suite.configs.len());
        for cfg in &suite.configs {
            let mut best = f64::INFINITY;
            for _ in 0..self.config.reps_per_config.max(1) {
                best = best.min(self.backend.measure(genome, cfg)?);
            }
            times.push(best);
        }
        Ok(geomean(&times))
    }

    /// Direct backend access (reports/benches only — the scientist
    /// never touches this).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome};
    use crate::sim::SimBackend;
    use crate::workload::BenchmarkSuite;

    fn platform() -> EvalPlatform<SimBackend> {
        EvalPlatform::new(SimBackend::new(42), PlatformConfig::default())
    }

    #[test]
    fn successful_submission_returns_six_timings() {
        let mut p = platform();
        let out = p.submit(&seeds::mfma_seed());
        let t = out.timings().expect("should time");
        assert_eq!(t.len(), 6);
        assert!(t.iter().all(|&x| x > 0.0));
        assert_eq!(p.submissions(), 1);
    }

    #[test]
    fn compile_failure_logged() {
        let mut p = platform();
        let bad = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        let out = p.submit(&bad);
        assert!(matches!(out, EvalOutcome::CompileFailure(_)));
        assert!(matches!(
            p.log()[0].outcome,
            EvalOutcome::CompileFailure(_)
        ));
    }

    #[test]
    fn sequential_clock_advances_per_submission() {
        let mut p = platform();
        p.submit(&seeds::mfma_seed());
        let t1 = p.wall_clock_s();
        p.submit(&seeds::mfma_seed());
        let t2 = p.wall_clock_s();
        assert!(t2 > t1);
        assert!((t2 - 2.0 * t1).abs() < 1e-9, "strictly serialized");
    }

    #[test]
    fn parallel_lanes_share_wall_clock() {
        let mut seq = EvalPlatform::new(SimBackend::new(1), PlatformConfig::default());
        let mut par = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                parallelism: 3,
                ..Default::default()
            },
        );
        for _ in 0..6 {
            seq.submit(&seeds::mfma_seed());
            par.submit(&seeds::mfma_seed());
        }
        assert!((par.wall_clock_s() - seq.wall_clock_s() / 3.0).abs() < 1e-6);
    }

    #[test]
    fn quota_enforced() {
        let mut p = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        p.submit(&seeds::mfma_seed());
        assert!(!p.quota_exhausted());
        p.submit(&seeds::mfma_seed());
        assert!(p.quota_exhausted());
    }

    #[test]
    #[should_panic(expected = "quota exhausted")]
    fn submit_past_quota_panics() {
        let mut p = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                submission_quota: Some(1),
                ..Default::default()
            },
        );
        p.submit(&seeds::mfma_seed());
        p.submit(&seeds::mfma_seed());
    }

    #[test]
    fn leaderboard_score_is_geomean_over_suite() {
        let mut p = platform();
        let score = p
            .leaderboard_score(&seeds::human_oracle(), &BenchmarkSuite::leaderboard())
            .unwrap();
        assert!(score > 0.0);
        // leaderboard doesn't count against the submission log
        assert_eq!(p.submissions(), 0);
    }

    #[test]
    fn reps_take_minimum() {
        // more reps can only lower (or keep) the reported time
        let mut p1 = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                reps_per_config: 1,
                ..Default::default()
            },
        );
        let mut p5 = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                reps_per_config: 5,
                ..Default::default()
            },
        );
        let t1 = p1.submit(&seeds::mfma_seed());
        let t5 = p5.submit(&seeds::mfma_seed());
        let g1 = crate::metrics::geomean(t1.timings().unwrap());
        let g5 = crate::metrics::geomean(t5.timings().unwrap());
        // not strictly comparable (different rng draws) but both sane
        assert!(g1 > 0.0 && g5 > 0.0);
    }
}
