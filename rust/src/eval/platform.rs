//! The submission platform: submission queue, gates, timing runs,
//! leaderboard scoring, the simulated wall clock, and (since the
//! executor refactor, DESIGN.md §3) genuinely concurrent batch
//! submission plus the genome-fingerprint result cache.
//!
//! Two concurrent submission APIs coexist (both on top of the
//! multi-lane executor):
//!
//! * **Barrier batches** — [`EvalPlatform::submit_batch`]: one call,
//!   one result vector, the caller waits for everything.
//! * **Completion-driven stream** — [`EvalPlatform::submit_stream`] +
//!   [`EvalPlatform::poll_completed`] (DESIGN.md §8): submissions
//!   enter individually as a scheduler plans them, and completions
//!   are drained one at a time in **virtual-clock order**, so the
//!   steady-state pipeline can refill a lane the moment it frees.

use std::collections::HashMap;

use super::executor::{self, EvalCache, StreamExecutor};
use super::{EvalBackend, EvalError};
use crate::genome::KernelGenome;
use crate::metrics::geomean;
use crate::population::EvalOutcome;
use crate::workload::BenchmarkSuite;

/// Platform policy knobs.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Timing repetitions per config (platform reports the minimum —
    /// standard benchmark practice).
    pub reps_per_config: u32,
    /// Concurrent submission lanes. The paper runs 1 ("good citizen");
    /// the §5.1 ablation raises it. Batches submitted through
    /// [`EvalPlatform::submit_batch`] run on this many real worker
    /// threads when the backend supports lane forking.
    pub parallelism: u32,
    /// Hard cap on total submissions (competition quota), if any.
    pub submission_quota: Option<u64>,
    /// Serve duplicate genomes from the eval-result cache on the batch
    /// path (free: no quota, no platform time, no backend run).
    pub cache_results: bool,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            reps_per_config: 3,
            parallelism: 1,
            submission_quota: None,
            cache_results: true,
        }
    }
}

/// One line of the platform's submission log.
#[derive(Debug, Clone)]
pub struct SubmissionRecord {
    pub index: u64,
    /// Simulated wall-clock time (s) at which results became available.
    pub completed_at_s: f64,
    pub outcome: EvalOutcome,
}

/// Per-genome result of a [`EvalPlatform::submit_batch`] call, in
/// submission order.
#[derive(Debug, Clone)]
pub struct BatchResult {
    pub outcome: EvalOutcome,
    /// Served from the eval cache: no quota, no platform time consumed.
    pub cached: bool,
    /// Index in the submission log (`None` for cache hits).
    pub submission_index: Option<u64>,
    /// Simulated wall-clock time at which the result became available.
    pub completed_at_s: f64,
}

/// One completed stream submission, returned by
/// [`EvalPlatform::poll_completed`] in virtual-clock order.
#[derive(Debug, Clone)]
pub struct CompletedEval {
    /// The ticket [`EvalPlatform::submit_stream`] handed out.
    pub ticket: u64,
    pub outcome: EvalOutcome,
    /// Served from the eval cache (or aliased to an in-flight
    /// duplicate): no quota, no platform time consumed.
    pub cached: bool,
    /// Index in the submission log (`None` for cache hits).
    pub submission_index: Option<u64>,
    /// Simulated wall-clock time at which the result became available.
    pub completed_at_s: f64,
}

/// How stream submissions are evaluated (decided once, at the first
/// [`EvalPlatform::submit_stream`] call).
enum StreamState {
    /// No stream submission has happened yet.
    Idle,
    /// Evaluate inline on the platform's own backend at submit time —
    /// the single-lane / unforkable-backend path, bit-identical to
    /// sequential [`EvalPlatform::submit`] calls.
    Inline,
    /// Dispatch to the persistent lane workers.
    Threaded(StreamExecutor),
}

/// One in-flight (or already-served) stream submission.
struct PendingEval {
    ticket: u64,
    completed_at_s: f64,
    kind: PendingKind,
}

enum PendingKind {
    /// Occupies a lane. `inline_outcome` is `Some` on the inline path
    /// (evaluated at submit time), `None` while a worker runs it.
    Run {
        lane: usize,
        submission_index: u64,
        fingerprint: String,
        inline_outcome: Option<EvalOutcome>,
    },
    /// Served from the result cache at submit time (free).
    Cached { outcome: EvalOutcome },
    /// Duplicate of an in-flight run with the same fingerprint:
    /// resolves from the cache once the original completes (free).
    Alias { fingerprint: String },
}

/// The evaluation platform wrapping a backend.
pub struct EvalPlatform<B: EvalBackend> {
    backend: B,
    pub config: PlatformConfig,
    pub feedback_suite: BenchmarkSuite,
    log: Vec<SubmissionRecord>,
    /// Simulated wall clock, seconds. With `parallelism` lanes, each
    /// lane is a virtual worker; the clock advances to the earliest
    /// free lane at submit time. Batch submissions assign lanes in
    /// submission order with equal per-submission cost, which matches
    /// the executor's static round-robin thread partition.
    lane_busy_until: Vec<f64>,
    /// Total lane-seconds spent evaluating (drives
    /// [`EvalPlatform::lane_occupancy`]; idle time shows up as the gap
    /// to `lanes x wall_clock_s`).
    busy_lane_s: f64,
    /// Eval-result cache keyed by genome fingerprint (DESIGN.md §3).
    cache: EvalCache,
    /// Stream path state (submit_stream / poll_completed).
    stream: StreamState,
    pending: Vec<PendingEval>,
    next_ticket: u64,
}

impl<B: EvalBackend> EvalPlatform<B> {
    pub fn new(backend: B, config: PlatformConfig) -> Self {
        let lanes = config.parallelism.max(1) as usize;
        let cache = EvalCache::new(config.cache_results);
        EvalPlatform {
            backend,
            config,
            feedback_suite: BenchmarkSuite::feedback(),
            log: Vec::new(),
            lane_busy_until: vec![0.0; lanes],
            busy_lane_s: 0.0,
            cache,
            stream: StreamState::Idle,
            pending: Vec::new(),
            next_ticket: 0,
        }
    }

    /// Use a non-default feedback suite (the PJRT backend needs the
    /// testbed shapes).
    pub fn with_feedback_suite(mut self, suite: BenchmarkSuite) -> Self {
        self.feedback_suite = suite;
        self
    }

    pub fn backend_name(&self) -> String {
        self.backend.name().to_string()
    }

    /// The workload the backend evaluates (seed genomes, suites — see
    /// [`crate::workload::Workload`]). Tuners use this to stay
    /// workload-generic.
    pub fn workload(&self) -> std::sync::Arc<dyn crate::workload::Workload> {
        self.backend.workload()
    }

    pub fn submissions(&self) -> u64 {
        self.log.len() as u64
    }

    pub fn log(&self) -> &[SubmissionRecord] {
        &self.log
    }

    /// Simulated wall-clock seconds consumed so far (max over lanes).
    pub fn wall_clock_s(&self) -> f64 {
        self.lane_busy_until.iter().cloned().fold(0.0, f64::max)
    }

    /// Whether the quota (if any) is exhausted.
    pub fn quota_exhausted(&self) -> bool {
        self.config
            .submission_quota
            .map(|q| self.submissions() >= q)
            .unwrap_or(false)
    }

    /// Submit one kernel: gates, then `reps_per_config` timing reps on
    /// each feedback config (minimum reported). Advances the simulated
    /// clock on the earliest-free lane — the sequential default means
    /// strictly serialized submissions, as in the paper. Always runs
    /// the backend (the cache only *serves* on the batch path, but
    /// results recorded here do populate it).
    pub fn submit(&mut self, genome: &KernelGenome) -> EvalOutcome {
        debug_assert!(
            self.pending.is_empty(),
            "submit() while stream evaluations are in flight"
        );
        assert!(
            !self.quota_exhausted(),
            "platform quota exhausted ({} submissions)",
            self.submissions()
        );
        let outcome = executor::evaluate_one(
            &mut self.backend,
            &self.feedback_suite,
            self.config.reps_per_config,
            genome,
        );
        self.cache.insert(genome.fingerprint(), outcome.clone());
        self.account_submission(outcome.clone());
        outcome
    }

    /// Submit a batch of kernels. Cache hits are served for free (no
    /// quota, no platform time) — including duplicates *within* the
    /// batch, whose later occurrences alias the first occurrence's
    /// result. The misses run concurrently on `parallelism` executor
    /// lanes and are then committed to the log, quota, and lane clocks
    /// **in submission order**, exactly as if each had gone through
    /// [`EvalPlatform::submit`] in turn. If the quota runs out
    /// mid-batch, processing stops at the first entry the quota cannot
    /// cover and the rest are dropped — even entries that would have
    /// been free — so the returned vector is always a prefix-aligned
    /// result per input; callers that must not lose work pre-truncate
    /// to their remaining budget.
    pub fn submit_batch(&mut self, genomes: &[KernelGenome]) -> Vec<BatchResult>
    where
        B: Send,
    {
        debug_assert!(
            self.pending.is_empty(),
            "submit_batch() while stream evaluations are in flight"
        );
        enum Slot {
            Cached(EvalOutcome),
            Run(usize),
            /// Duplicate (within this batch) of planned job `j`.
            Alias(usize),
        }
        let remaining = match self.config.submission_quota {
            Some(q) => q.saturating_sub(self.submissions()),
            None => u64::MAX,
        };
        let mut slots: Vec<Slot> = Vec::with_capacity(genomes.len());
        let mut jobs: Vec<KernelGenome> = Vec::new();
        let mut planned_fps: HashMap<String, usize> = HashMap::new();
        for genome in genomes {
            let fp = genome.fingerprint();
            // Counted-stats invariant: every *processed* entry (one
            // that yields a result) contributes exactly one counted
            // lookup — in-batch duplicates count theirs as the hit at
            // result assembly, and the entry that triggers quota
            // truncation counts nothing — so with the cache enabled,
            // hits + misses == results returned by this path.
            if self.cache.enabled() {
                if let Some(&j) = planned_fps.get(&fp) {
                    slots.push(Slot::Alias(j));
                    continue;
                }
                if self.cache.peek(&fp).is_some() {
                    let hit = self.cache.lookup(&fp).expect("peeked entry present");
                    slots.push(Slot::Cached(hit));
                    continue;
                }
            }
            if (jobs.len() as u64) >= remaining {
                break; // quota exhausted: truncate the batch here, uncounted
            }
            if self.cache.enabled() {
                let miss = self.cache.lookup(&fp); // counted miss
                debug_assert!(miss.is_none());
            }
            slots.push(Slot::Run(jobs.len()));
            planned_fps.insert(fp, jobs.len());
            jobs.push(genome.clone());
        }
        let outcomes = executor::run_batch(
            &mut self.backend,
            &self.feedback_suite,
            self.config.reps_per_config,
            &jobs,
            self.config.parallelism,
        );
        let mut results = Vec::with_capacity(slots.len());
        for slot in slots {
            match slot {
                Slot::Cached(outcome) => results.push(BatchResult {
                    outcome,
                    cached: true,
                    submission_index: None,
                    completed_at_s: self.wall_clock_s(),
                }),
                Slot::Alias(j) => {
                    // By commit order the aliased job has already been
                    // committed and cached; the lookup also counts the
                    // hit in the cache stats.
                    let outcome = self
                        .cache
                        .lookup(&jobs[j].fingerprint())
                        .unwrap_or_else(|| outcomes[j].clone());
                    results.push(BatchResult {
                        outcome,
                        cached: true,
                        submission_index: None,
                        completed_at_s: self.wall_clock_s(),
                    });
                }
                Slot::Run(j) => {
                    let outcome = outcomes[j].clone();
                    self.cache.insert(jobs[j].fingerprint(), outcome.clone());
                    let (index, completed_at_s) = self.account_submission(outcome.clone());
                    results.push(BatchResult {
                        outcome,
                        cached: false,
                        submission_index: Some(index),
                        completed_at_s,
                    });
                }
            }
        }
        results
    }

    /// Stream submissions currently in flight (incl. cache hits not
    /// yet polled).
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// In-flight stream submissions that occupy a lane (i.e. count
    /// toward the quota once they complete).
    fn pending_runs(&self) -> u64 {
        self.pending
            .iter()
            .filter(|p| matches!(p.kind, PendingKind::Run { .. }))
            .count() as u64
    }

    /// The in-flight run (if any) evaluating this fingerprint — the
    /// aliasing target for duplicate stream submissions.
    fn pending_run_with_fp(&self, fp: &str) -> Option<&PendingEval> {
        self.pending.iter().find(|p| {
            matches!(&p.kind, PendingKind::Run { fingerprint, .. } if fingerprint == fp)
        })
    }

    /// Submit one kernel on the completion-driven stream path and
    /// return its ticket; the result arrives through
    /// [`EvalPlatform::poll_completed`]. Semantics match the batch
    /// path per entry: cache hits (and duplicates of in-flight
    /// submissions) are free — no quota, no platform time — while
    /// misses occupy the earliest-free virtual lane for
    /// `submission_cost_s` and run concurrently on that lane's
    /// persistent worker thread (`B: 'static`; backends that cannot
    /// fork evaluate inline, preserving the exact sequential call
    /// sequence). Panics if the quota cannot cover a miss, counting
    /// in-flight misses as already spent — stream callers plan
    /// against `submissions() + in_flight()`.
    pub fn submit_stream(&mut self, genome: &KernelGenome) -> u64
    where
        B: Send + 'static,
    {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        let fp = genome.fingerprint();
        if self.cache.enabled() {
            // duplicate of an in-flight run: resolves (free) when the
            // original lands in the cache. Counted as a hit at poll
            // time, mirroring the batch path's alias accounting.
            if let Some(original) = self.pending_run_with_fp(&fp) {
                let completed_at_s = original.completed_at_s;
                self.pending.push(PendingEval {
                    ticket,
                    completed_at_s,
                    kind: PendingKind::Alias { fingerprint: fp },
                });
                return ticket;
            }
            // counted lookup either way: a hit serves the entry below,
            // a miss is the run's one counted miss (batch-path parity)
            if let Some(outcome) = self.cache.lookup(&fp) {
                self.pending.push(PendingEval {
                    ticket,
                    completed_at_s: self.wall_clock_s(),
                    kind: PendingKind::Cached { outcome },
                });
                return ticket;
            }
        }
        let pending_runs = self.pending_runs();
        assert!(
            self.config
                .submission_quota
                .map(|q| self.submissions() + pending_runs < q)
                .unwrap_or(true),
            "platform quota exhausted ({} submissions, {pending_runs} in flight)",
            self.submissions()
        );
        if matches!(self.stream, StreamState::Idle) {
            self.stream = match StreamExecutor::spawn(
                &mut self.backend,
                &self.feedback_suite,
                self.config.reps_per_config,
                self.config.parallelism,
            ) {
                Some(executor) => StreamState::Threaded(executor),
                None => StreamState::Inline,
            };
        }
        let cost = self.backend.submission_cost_s();
        let lane = self.earliest_free_lane();
        self.lane_busy_until[lane] += cost;
        self.busy_lane_s += cost;
        let completed_at_s = self.lane_busy_until[lane];
        let submission_index = self.submissions() + pending_runs;
        let inline_outcome = match &self.stream {
            StreamState::Threaded(executor) => {
                executor.dispatch(lane, ticket, genome.clone());
                None
            }
            StreamState::Inline => Some(executor::evaluate_one(
                &mut self.backend,
                &self.feedback_suite,
                self.config.reps_per_config,
                genome,
            )),
            StreamState::Idle => unreachable!("stream mode decided above"),
        };
        self.pending.push(PendingEval {
            ticket,
            completed_at_s,
            kind: PendingKind::Run {
                lane,
                submission_index,
                fingerprint: fp,
                inline_outcome,
            },
        });
        ticket
    }

    /// Drain the in-flight stream submission with the **earliest
    /// virtual completion time** (ties resolve to the earliest
    /// ticket), blocking on its lane worker if it is still running.
    /// Returns `None` when nothing is in flight.
    ///
    /// Because each virtual lane's clock only moves forward and each
    /// lane worker finishes jobs in FIFO order, the completion order
    /// this returns is a pure function of the submission sequence —
    /// never of OS scheduling (DESIGN.md §8).
    pub fn poll_completed(&mut self) -> Option<CompletedEval> {
        if self.pending.is_empty() {
            return None;
        }
        // strict `<` keeps the earliest-pushed (lowest-ticket) entry on
        // ties, which also guarantees an aliased original resolves
        // before its duplicates
        let mut earliest = 0;
        for (i, p) in self.pending.iter().enumerate().skip(1) {
            if p.completed_at_s < self.pending[earliest].completed_at_s {
                earliest = i;
            }
        }
        let p = self.pending.remove(earliest);
        match p.kind {
            PendingKind::Cached { outcome } => Some(CompletedEval {
                ticket: p.ticket,
                outcome,
                cached: true,
                submission_index: None,
                completed_at_s: p.completed_at_s,
            }),
            PendingKind::Alias { fingerprint } => {
                let outcome = self
                    .cache
                    .lookup(&fingerprint) // the alias's counted hit
                    .expect("aliased submission completes before its duplicates");
                Some(CompletedEval {
                    ticket: p.ticket,
                    outcome,
                    cached: true,
                    submission_index: None,
                    completed_at_s: p.completed_at_s,
                })
            }
            PendingKind::Run {
                lane,
                submission_index,
                fingerprint,
                inline_outcome,
            } => {
                let outcome = match inline_outcome {
                    Some(outcome) => outcome,
                    None => {
                        let StreamState::Threaded(executor) = &self.stream else {
                            unreachable!("worker-dispatched job without workers")
                        };
                        let (ticket, outcome) = executor.collect(lane);
                        debug_assert_eq!(
                            ticket, p.ticket,
                            "lane workers must finish jobs in FIFO order"
                        );
                        outcome
                    }
                };
                self.cache.insert(fingerprint, outcome.clone());
                debug_assert_eq!(
                    self.log.len() as u64,
                    submission_index,
                    "stream completions commit to the log in submission order"
                );
                self.log.push(SubmissionRecord {
                    index: submission_index,
                    completed_at_s: p.completed_at_s,
                    outcome: outcome.clone(),
                });
                Some(CompletedEval {
                    ticket: p.ticket,
                    outcome,
                    cached: false,
                    submission_index: Some(submission_index),
                    completed_at_s: p.completed_at_s,
                })
            }
        }
    }

    /// Push a whole batch through the stream path and wait for all of
    /// it — the streaming equivalent of [`EvalPlatform::submit_batch`]
    /// (same quota-truncation semantics: planning stops at the first
    /// entry the remaining quota cannot cover, so the result is a
    /// prefix-aligned vector). The genetic baseline evaluates its
    /// generations through this.
    pub fn submit_stream_batch(&mut self, genomes: &[KernelGenome]) -> Vec<BatchResult>
    where
        B: Send + 'static,
    {
        // the drain below consumes every pending completion, so prior
        // stream work must already be polled (same contract as the
        // barrier paths)
        debug_assert!(
            self.pending.is_empty(),
            "submit_stream_batch() while stream evaluations are in flight"
        );
        let remaining = match self.config.submission_quota {
            Some(q) => q.saturating_sub(self.submissions() + self.pending_runs()),
            None => u64::MAX,
        };
        let mut planned = 0u64;
        let mut tickets = Vec::with_capacity(genomes.len());
        for genome in genomes {
            let fp = genome.fingerprint();
            let free = self.cache.enabled()
                && (self.cache.peek(&fp).is_some() || self.pending_run_with_fp(&fp).is_some());
            if !free {
                if planned >= remaining {
                    break;
                }
                planned += 1;
            }
            tickets.push(self.submit_stream(genome));
        }
        let mut by_ticket: HashMap<u64, BatchResult> = HashMap::with_capacity(tickets.len());
        while let Some(done) = self.poll_completed() {
            by_ticket.insert(
                done.ticket,
                BatchResult {
                    outcome: done.outcome,
                    cached: done.cached,
                    submission_index: done.submission_index,
                    completed_at_s: done.completed_at_s,
                },
            );
        }
        tickets
            .into_iter()
            .map(|t| by_ticket.remove(&t).expect("every ticket completes"))
            .collect()
    }

    /// Model a scheduling barrier: every lane waits for the slowest
    /// one (lockstep's "plan the next round only after the whole batch
    /// completes", DESIGN.md §8). A no-op with a single lane; must not
    /// be called with stream work in flight.
    pub fn sync_lanes(&mut self) {
        debug_assert!(
            self.pending.is_empty(),
            "sync_lanes() while stream evaluations are in flight"
        );
        let barrier = self.wall_clock_s();
        for lane in &mut self.lane_busy_until {
            *lane = barrier;
        }
    }

    /// Fraction of total lane-time spent evaluating: busy lane-seconds
    /// over `lanes x` simulated makespan. 1.0 = perfectly saturated
    /// lanes (also reported for an empty platform, vacuously).
    pub fn lane_occupancy(&self) -> f64 {
        let makespan = self.wall_clock_s();
        if makespan <= 0.0 {
            return 1.0;
        }
        self.busy_lane_s / (self.lane_busy_until.len() as f64 * makespan)
    }

    /// The lane-assignment rule shared by every submission path:
    /// earliest-free virtual lane, ties to the LOWEST index. With
    /// uniform submission costs this is exactly `run_batch`'s static
    /// round-robin partition (job i -> lane i mod N), which is what
    /// keeps stream and barrier evaluation agreeing on which lane
    /// backend times which job.
    fn earliest_free_lane(&self) -> usize {
        let mut lane = 0;
        for (i, &busy) in self.lane_busy_until.iter().enumerate().skip(1) {
            if busy < self.lane_busy_until[lane] {
                lane = i;
            }
        }
        lane
    }

    /// Record one completed submission: quota, earliest-free-lane wall
    /// clock, and the log line. Returns (log index, completion time).
    fn account_submission(&mut self, outcome: EvalOutcome) -> (u64, f64) {
        let cost = self.backend.submission_cost_s();
        let lane = self.earliest_free_lane();
        self.lane_busy_until[lane] += cost;
        self.busy_lane_s += cost;
        let completed_at_s = self.lane_busy_until[lane];
        let index = self.log.len() as u64;
        self.log.push(SubmissionRecord {
            index,
            completed_at_s,
            outcome,
        });
        (index, completed_at_s)
    }

    /// Read-only cache probe (planning aid for batch callers: a cached
    /// genome will not consume quota). Does not count toward stats.
    pub fn cached_outcome(&self, genome: &KernelGenome) -> Option<EvalOutcome> {
        self.cache.peek(&genome.fingerprint()).cloned()
    }

    /// (hits, misses) of counted cache lookups on the batch path.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Final leaderboard score: geomean over a (typically 18-size)
    /// suite, taken outside the submission quota (the organisers run
    /// this once at the end).
    pub fn leaderboard_score(
        &mut self,
        genome: &KernelGenome,
        suite: &BenchmarkSuite,
    ) -> Result<f64, EvalError> {
        self.backend.check(genome)?;
        let mut times = Vec::with_capacity(suite.configs.len());
        for cfg in &suite.configs {
            let mut best = f64::INFINITY;
            for _ in 0..self.config.reps_per_config.max(1) {
                best = best.min(self.backend.measure(genome, cfg)?);
            }
            times.push(best);
        }
        Ok(geomean(&times))
    }

    /// Direct backend access (reports/benches only — the scientist
    /// never touches this).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome};
    use crate::sim::SimBackend;
    use crate::workload::BenchmarkSuite;

    fn platform() -> EvalPlatform<SimBackend> {
        EvalPlatform::new(SimBackend::new(42), PlatformConfig::default())
    }

    #[test]
    fn successful_submission_returns_six_timings() {
        let mut p = platform();
        let out = p.submit(&seeds::mfma_seed());
        let t = out.timings().expect("should time");
        assert_eq!(t.len(), 6);
        assert!(t.iter().all(|&x| x > 0.0));
        assert_eq!(p.submissions(), 1);
    }

    #[test]
    fn compile_failure_logged() {
        let mut p = platform();
        let bad = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        let out = p.submit(&bad);
        assert!(matches!(out, EvalOutcome::CompileFailure(_)));
        assert!(matches!(
            p.log()[0].outcome,
            EvalOutcome::CompileFailure(_)
        ));
    }

    #[test]
    fn sequential_clock_advances_per_submission() {
        let mut p = platform();
        p.submit(&seeds::mfma_seed());
        let t1 = p.wall_clock_s();
        p.submit(&seeds::mfma_seed());
        let t2 = p.wall_clock_s();
        assert!(t2 > t1);
        assert!((t2 - 2.0 * t1).abs() < 1e-9, "strictly serialized");
    }

    #[test]
    fn parallel_lanes_share_wall_clock() {
        let mut seq = EvalPlatform::new(SimBackend::new(1), PlatformConfig::default());
        let mut par = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                parallelism: 3,
                ..Default::default()
            },
        );
        for _ in 0..6 {
            seq.submit(&seeds::mfma_seed());
            par.submit(&seeds::mfma_seed());
        }
        assert!((par.wall_clock_s() - seq.wall_clock_s() / 3.0).abs() < 1e-6);
    }

    #[test]
    fn quota_enforced() {
        let mut p = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        p.submit(&seeds::mfma_seed());
        assert!(!p.quota_exhausted());
        p.submit(&seeds::mfma_seed());
        assert!(p.quota_exhausted());
    }

    #[test]
    #[should_panic(expected = "quota exhausted")]
    fn submit_past_quota_panics() {
        let mut p = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                submission_quota: Some(1),
                ..Default::default()
            },
        );
        p.submit(&seeds::mfma_seed());
        p.submit(&seeds::mfma_seed());
    }

    #[test]
    fn batch_matches_sequential_at_one_lane() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
                .into_iter()
                .take(5)
                .map(|(_, g)| g)
                .collect();
        let mut seq = EvalPlatform::new(SimBackend::new(4), PlatformConfig::default());
        let expected: Vec<EvalOutcome> = jobs.iter().map(|g| seq.submit(g)).collect();
        let mut bat = EvalPlatform::new(SimBackend::new(4), PlatformConfig::default());
        let results = bat.submit_batch(&jobs);
        assert_eq!(results.len(), jobs.len());
        for (i, (r, e)) in results.iter().zip(&expected).enumerate() {
            assert!(!r.cached);
            assert_eq!(r.submission_index, Some(i as u64));
            assert_eq!(&r.outcome, e, "job {i}");
        }
        assert_eq!(bat.wall_clock_s(), seq.wall_clock_s());
        assert_eq!(bat.submissions(), seq.submissions());
    }

    #[test]
    fn batch_cache_hit_is_free() {
        let mut p = EvalPlatform::new(
            SimBackend::new(2),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let g = seeds::mfma_seed();
        let first = p.submit_batch(std::slice::from_ref(&g));
        assert!(!first[0].cached);
        assert_eq!(p.submissions(), 1);
        let clock = p.wall_clock_s();
        let second = p.submit_batch(std::slice::from_ref(&g));
        assert!(second[0].cached);
        assert_eq!(second[0].outcome, first[0].outcome, "identical EvalOutcome");
        assert_eq!(second[0].submission_index, None);
        assert_eq!(p.submissions(), 1, "cache hit consumes no quota");
        assert_eq!(p.wall_clock_s(), clock, "cache hit consumes no platform time");
        assert_eq!(p.cache_stats().0, 1);
    }

    #[test]
    fn in_batch_duplicates_are_served_once() {
        let mut p = EvalPlatform::new(SimBackend::new(12), PlatformConfig::default());
        let g = seeds::mfma_seed();
        let other = seeds::human_oracle();
        let batch = vec![g.clone(), other.clone(), g.clone()];
        let results = p.submit_batch(&batch);
        assert_eq!(results.len(), 3);
        assert!(!results[0].cached && !results[1].cached);
        assert!(results[2].cached, "second occurrence aliases the first");
        assert_eq!(results[2].outcome, results[0].outcome);
        assert_eq!(results[2].submission_index, None);
        assert_eq!(p.submissions(), 2, "the duplicate consumed no quota");
        assert_eq!(p.cache_stats().0, 1, "alias counted as a cache hit");
        // with the cache disabled, in-batch duplicates evaluate twice
        let mut raw = EvalPlatform::new(
            SimBackend::new(12),
            PlatformConfig {
                cache_results: false,
                ..Default::default()
            },
        );
        let results = raw.submit_batch(&batch);
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| !r.cached));
        assert_eq!(raw.submissions(), 3);
    }

    #[test]
    fn batch_truncates_at_quota() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(4)
                .map(|(_, g)| g)
                .collect();
        let mut p = EvalPlatform::new(
            SimBackend::new(3),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let results = p.submit_batch(&jobs);
        assert_eq!(results.len(), 2);
        assert_eq!(p.submissions(), 2);
        assert!(p.quota_exhausted());
    }

    #[test]
    fn cache_disabled_reevaluates() {
        let mut p = EvalPlatform::new(
            SimBackend::new(6),
            PlatformConfig {
                cache_results: false,
                ..Default::default()
            },
        );
        let g = seeds::mfma_seed();
        let a = p.submit_batch(std::slice::from_ref(&g));
        let b = p.submit_batch(std::slice::from_ref(&g));
        assert!(!a[0].cached && !b[0].cached);
        assert_eq!(p.submissions(), 2);
        assert!(p.cached_outcome(&g).is_none());
    }

    #[test]
    fn stream_single_lane_is_bit_identical_to_sequential_submits() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
                .into_iter()
                .take(5)
                .map(|(_, g)| g)
                .collect();
        let mut seq = EvalPlatform::new(SimBackend::new(8), PlatformConfig::default());
        let expected: Vec<EvalOutcome> = jobs.iter().map(|g| seq.submit(g)).collect();
        let mut stream = EvalPlatform::new(SimBackend::new(8), PlatformConfig::default());
        let tickets: Vec<u64> = jobs.iter().map(|g| stream.submit_stream(g)).collect();
        assert_eq!(stream.in_flight(), jobs.len());
        for (i, (ticket, expected)) in tickets.iter().zip(&expected).enumerate() {
            let done = stream.poll_completed().expect("in flight");
            assert_eq!(done.ticket, *ticket, "completion order == submission order");
            assert_eq!(&done.outcome, expected, "job {i}");
            assert!(!done.cached);
            assert_eq!(done.submission_index, Some(i as u64));
        }
        assert!(stream.poll_completed().is_none());
        assert_eq!(stream.wall_clock_s(), seq.wall_clock_s());
        assert_eq!(stream.submissions(), seq.submissions());
        let seq_times: Vec<f64> = seq.log().iter().map(|r| r.completed_at_s).collect();
        let stream_times: Vec<f64> =
            stream.log().iter().map(|r| r.completed_at_s).collect();
        assert_eq!(seq_times, stream_times);
    }

    #[test]
    fn stream_multi_lane_completes_in_virtual_clock_order() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(6)
                .map(|(_, g)| g)
                .collect();
        let run_once = || {
            let mut p = EvalPlatform::new(
                SimBackend::new(14),
                PlatformConfig {
                    parallelism: 3,
                    ..Default::default()
                },
            );
            for g in &jobs {
                p.submit_stream(g);
            }
            let mut outcomes = Vec::new();
            let mut i = 0u64;
            while let Some(done) = p.poll_completed() {
                assert_eq!(done.ticket, i, "virtual-clock order breaks ties by ticket");
                assert_eq!(done.submission_index, Some(i));
                // 3 lanes, 90 s each: jobs 0..2 land at 90 s, 3..5 at 180 s
                let expected_t = 90.0 * (i / 3 + 1) as f64;
                assert!((done.completed_at_s - expected_t).abs() < 1e-9);
                outcomes.push(done.outcome);
                i += 1;
            }
            assert_eq!(i, 6);
            assert!((p.wall_clock_s() - 180.0).abs() < 1e-9);
            assert!((p.lane_occupancy() - 1.0).abs() < 1e-12, "fully packed lanes");
            outcomes
        };
        assert_eq!(run_once(), run_once(), "stream results are deterministic per seed");
    }

    #[test]
    fn stream_interleaves_submissions_with_completions() {
        // the steady-state usage pattern: drain one, refill one
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
                .into_iter()
                .take(6)
                .map(|(_, g)| g)
                .collect();
        let mut p = EvalPlatform::new(
            SimBackend::new(23),
            PlatformConfig {
                parallelism: 2,
                ..Default::default()
            },
        );
        p.submit_stream(&jobs[0]);
        p.submit_stream(&jobs[1]);
        for next in 2..jobs.len() {
            let done = p.poll_completed().expect("in flight");
            assert!(done.outcome.is_success());
            p.submit_stream(&jobs[next]);
            assert_eq!(p.in_flight(), 2, "a lane refills as soon as one frees");
        }
        while p.poll_completed().is_some() {}
        assert_eq!(p.submissions(), 6);
        // 6 uniform submissions over 2 continuously-fed lanes
        assert!((p.wall_clock_s() - 270.0).abs() < 1e-9);
        for (i, rec) in p.log().iter().enumerate() {
            assert_eq!(rec.index, i as u64, "log stays in submission order");
        }
    }

    #[test]
    fn stream_cache_hits_and_inflight_aliases_are_free() {
        let mut p = EvalPlatform::new(
            SimBackend::new(31),
            PlatformConfig {
                parallelism: 2,
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let g = seeds::mfma_seed();
        let other = seeds::human_oracle();
        // duplicate of an in-flight run aliases it (free)
        let t0 = p.submit_stream(&g);
        let t1 = p.submit_stream(&other);
        let t2 = p.submit_stream(&g);
        let first = p.poll_completed().unwrap();
        assert_eq!(first.ticket, t0);
        assert!(!first.cached);
        let second = p.poll_completed().unwrap();
        assert_eq!(second.ticket, t1, "equal completion times drain in ticket order");
        let alias = p.poll_completed().unwrap();
        assert_eq!(alias.ticket, t2, "the alias resolves after its original");
        assert!(alias.cached);
        assert_eq!(alias.outcome, first.outcome);
        assert_eq!(alias.submission_index, None);
        assert_eq!(p.submissions(), 2, "the alias consumed no quota");
        let clock = p.wall_clock_s();
        // quota is exhausted, but cached genomes are still served
        let t3 = p.submit_stream(&g);
        let hit = p.poll_completed().unwrap();
        assert_eq!(hit.ticket, t3);
        assert!(hit.cached);
        assert_eq!(hit.outcome, first.outcome);
        assert_eq!(p.submissions(), 2);
        assert_eq!(p.wall_clock_s(), clock, "cache hit consumes no platform time");
    }

    #[test]
    #[should_panic(expected = "quota exhausted")]
    fn stream_counts_inflight_toward_quota() {
        let mut p = EvalPlatform::new(
            SimBackend::new(2),
            PlatformConfig {
                submission_quota: Some(1),
                cache_results: false,
                ..Default::default()
            },
        );
        p.submit_stream(&seeds::mfma_seed());
        // still in flight, but the quota is already spoken for
        p.submit_stream(&seeds::human_oracle());
    }

    #[test]
    fn stream_batch_matches_barrier_batch_at_one_lane() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(4)
                .map(|(_, g)| g)
                .collect();
        let mut barrier = EvalPlatform::new(SimBackend::new(6), PlatformConfig::default());
        let expected = barrier.submit_batch(&jobs);
        let mut stream = EvalPlatform::new(SimBackend::new(6), PlatformConfig::default());
        let results = stream.submit_stream_batch(&jobs);
        assert_eq!(results.len(), expected.len());
        for (r, e) in results.iter().zip(&expected) {
            assert_eq!(r.outcome, e.outcome);
            assert_eq!(r.cached, e.cached);
            assert_eq!(r.submission_index, e.submission_index);
        }
        assert_eq!(stream.wall_clock_s(), barrier.wall_clock_s());
        assert_eq!(stream.cache_stats(), barrier.cache_stats());
    }

    #[test]
    fn stream_batch_truncates_at_quota() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(4)
                .map(|(_, g)| g)
                .collect();
        let mut p = EvalPlatform::new(
            SimBackend::new(3),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let results = p.submit_stream_batch(&jobs);
        assert_eq!(results.len(), 2);
        assert_eq!(p.submissions(), 2);
        assert!(p.quota_exhausted());
    }

    #[test]
    fn sync_lanes_models_the_lockstep_barrier() {
        let mut p = EvalPlatform::new(
            SimBackend::new(5),
            PlatformConfig {
                parallelism: 3,
                ..Default::default()
            },
        );
        // full round: all three lanes busy to 90 s, sync is a no-op
        let jobs = crate::test_support::distinct_genomes(5);
        p.submit_batch(&jobs[..3]);
        p.sync_lanes();
        assert!((p.wall_clock_s() - 90.0).abs() < 1e-9);
        // partial round: two lanes to 180 s, one idles at the barrier
        p.submit_batch(&jobs[3..]);
        p.sync_lanes();
        assert!((p.wall_clock_s() - 180.0).abs() < 1e-9);
        // 5 busy submissions over 3 lanes x 180 s of makespan
        assert!((p.lane_occupancy() - 5.0 * 90.0 / (3.0 * 180.0)).abs() < 1e-12);
        // the barrier means the next submission starts after 180 s on
        // every lane, not on the idle lane at 90 s
        p.submit(&jobs[0]);
        assert!((p.wall_clock_s() - 270.0).abs() < 1e-9);
    }

    #[test]
    fn leaderboard_score_is_geomean_over_suite() {
        let mut p = platform();
        let score = p
            .leaderboard_score(&seeds::human_oracle(), &BenchmarkSuite::leaderboard())
            .unwrap();
        assert!(score > 0.0);
        // leaderboard doesn't count against the submission log
        assert_eq!(p.submissions(), 0);
    }

    #[test]
    fn reps_take_minimum() {
        // more reps can only lower (or keep) the reported time
        let mut p1 = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                reps_per_config: 1,
                ..Default::default()
            },
        );
        let mut p5 = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                reps_per_config: 5,
                ..Default::default()
            },
        );
        let t1 = p1.submit(&seeds::mfma_seed());
        let t5 = p5.submit(&seeds::mfma_seed());
        let g1 = crate::metrics::geomean(t1.timings().unwrap());
        let g5 = crate::metrics::geomean(t5.timings().unwrap());
        // not strictly comparable (different rng draws) but both sane
        assert!(g1 > 0.0 && g5 > 0.0);
    }
}
