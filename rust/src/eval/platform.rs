//! The submission platform: submission queue, gates, timing runs,
//! leaderboard scoring, the simulated wall clock, and (since the
//! executor refactor, DESIGN.md §3) genuinely concurrent batch
//! submission plus the genome-fingerprint result cache.

use std::collections::HashMap;

use super::executor::{self, EvalCache};
use super::{EvalBackend, EvalError};
use crate::genome::KernelGenome;
use crate::metrics::geomean;
use crate::population::EvalOutcome;
use crate::workload::BenchmarkSuite;

/// Platform policy knobs.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Timing repetitions per config (platform reports the minimum —
    /// standard benchmark practice).
    pub reps_per_config: u32,
    /// Concurrent submission lanes. The paper runs 1 ("good citizen");
    /// the §5.1 ablation raises it. Batches submitted through
    /// [`EvalPlatform::submit_batch`] run on this many real worker
    /// threads when the backend supports lane forking.
    pub parallelism: u32,
    /// Hard cap on total submissions (competition quota), if any.
    pub submission_quota: Option<u64>,
    /// Serve duplicate genomes from the eval-result cache on the batch
    /// path (free: no quota, no platform time, no backend run).
    pub cache_results: bool,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            reps_per_config: 3,
            parallelism: 1,
            submission_quota: None,
            cache_results: true,
        }
    }
}

/// One line of the platform's submission log.
#[derive(Debug, Clone)]
pub struct SubmissionRecord {
    pub index: u64,
    /// Simulated wall-clock time (s) at which results became available.
    pub completed_at_s: f64,
    pub outcome: EvalOutcome,
}

/// Per-genome result of a [`EvalPlatform::submit_batch`] call, in
/// submission order.
#[derive(Debug, Clone)]
pub struct BatchResult {
    pub outcome: EvalOutcome,
    /// Served from the eval cache: no quota, no platform time consumed.
    pub cached: bool,
    /// Index in the submission log (`None` for cache hits).
    pub submission_index: Option<u64>,
    /// Simulated wall-clock time at which the result became available.
    pub completed_at_s: f64,
}

/// The evaluation platform wrapping a backend.
pub struct EvalPlatform<B: EvalBackend> {
    backend: B,
    pub config: PlatformConfig,
    pub feedback_suite: BenchmarkSuite,
    log: Vec<SubmissionRecord>,
    /// Simulated wall clock, seconds. With `parallelism` lanes, each
    /// lane is a virtual worker; the clock advances to the earliest
    /// free lane at submit time. Batch submissions assign lanes in
    /// submission order with equal per-submission cost, which matches
    /// the executor's static round-robin thread partition.
    lane_busy_until: Vec<f64>,
    /// Eval-result cache keyed by genome fingerprint (DESIGN.md §3).
    cache: EvalCache,
}

impl<B: EvalBackend> EvalPlatform<B> {
    pub fn new(backend: B, config: PlatformConfig) -> Self {
        let lanes = config.parallelism.max(1) as usize;
        let cache = EvalCache::new(config.cache_results);
        EvalPlatform {
            backend,
            config,
            feedback_suite: BenchmarkSuite::feedback(),
            log: Vec::new(),
            lane_busy_until: vec![0.0; lanes],
            cache,
        }
    }

    /// Use a non-default feedback suite (the PJRT backend needs the
    /// testbed shapes).
    pub fn with_feedback_suite(mut self, suite: BenchmarkSuite) -> Self {
        self.feedback_suite = suite;
        self
    }

    pub fn backend_name(&self) -> String {
        self.backend.name().to_string()
    }

    /// The workload the backend evaluates (seed genomes, suites — see
    /// [`crate::workload::Workload`]). Tuners use this to stay
    /// workload-generic.
    pub fn workload(&self) -> std::sync::Arc<dyn crate::workload::Workload> {
        self.backend.workload()
    }

    pub fn submissions(&self) -> u64 {
        self.log.len() as u64
    }

    pub fn log(&self) -> &[SubmissionRecord] {
        &self.log
    }

    /// Simulated wall-clock seconds consumed so far (max over lanes).
    pub fn wall_clock_s(&self) -> f64 {
        self.lane_busy_until.iter().cloned().fold(0.0, f64::max)
    }

    /// Whether the quota (if any) is exhausted.
    pub fn quota_exhausted(&self) -> bool {
        self.config
            .submission_quota
            .map(|q| self.submissions() >= q)
            .unwrap_or(false)
    }

    /// Submit one kernel: gates, then `reps_per_config` timing reps on
    /// each feedback config (minimum reported). Advances the simulated
    /// clock on the earliest-free lane — the sequential default means
    /// strictly serialized submissions, as in the paper. Always runs
    /// the backend (the cache only *serves* on the batch path, but
    /// results recorded here do populate it).
    pub fn submit(&mut self, genome: &KernelGenome) -> EvalOutcome {
        assert!(
            !self.quota_exhausted(),
            "platform quota exhausted ({} submissions)",
            self.submissions()
        );
        let outcome = executor::evaluate_one(
            &mut self.backend,
            &self.feedback_suite,
            self.config.reps_per_config,
            genome,
        );
        self.cache.insert(genome.fingerprint(), outcome.clone());
        self.account_submission(outcome.clone());
        outcome
    }

    /// Submit a batch of kernels. Cache hits are served for free (no
    /// quota, no platform time) — including duplicates *within* the
    /// batch, whose later occurrences alias the first occurrence's
    /// result. The misses run concurrently on `parallelism` executor
    /// lanes and are then committed to the log, quota, and lane clocks
    /// **in submission order**, exactly as if each had gone through
    /// [`EvalPlatform::submit`] in turn. If the quota runs out
    /// mid-batch, processing stops at the first entry the quota cannot
    /// cover and the rest are dropped — even entries that would have
    /// been free — so the returned vector is always a prefix-aligned
    /// result per input; callers that must not lose work pre-truncate
    /// to their remaining budget.
    pub fn submit_batch(&mut self, genomes: &[KernelGenome]) -> Vec<BatchResult>
    where
        B: Send,
    {
        enum Slot {
            Cached(EvalOutcome),
            Run(usize),
            /// Duplicate (within this batch) of planned job `j`.
            Alias(usize),
        }
        let remaining = match self.config.submission_quota {
            Some(q) => q.saturating_sub(self.submissions()),
            None => u64::MAX,
        };
        let mut slots: Vec<Slot> = Vec::with_capacity(genomes.len());
        let mut jobs: Vec<KernelGenome> = Vec::new();
        let mut planned_fps: HashMap<String, usize> = HashMap::new();
        for genome in genomes {
            let fp = genome.fingerprint();
            // Counted-stats invariant: every *processed* entry (one
            // that yields a result) contributes exactly one counted
            // lookup — in-batch duplicates count theirs as the hit at
            // result assembly, and the entry that triggers quota
            // truncation counts nothing — so with the cache enabled,
            // hits + misses == results returned by this path.
            if self.cache.enabled() {
                if let Some(&j) = planned_fps.get(&fp) {
                    slots.push(Slot::Alias(j));
                    continue;
                }
                if self.cache.peek(&fp).is_some() {
                    let hit = self.cache.lookup(&fp).expect("peeked entry present");
                    slots.push(Slot::Cached(hit));
                    continue;
                }
            }
            if (jobs.len() as u64) >= remaining {
                break; // quota exhausted: truncate the batch here, uncounted
            }
            if self.cache.enabled() {
                let miss = self.cache.lookup(&fp); // counted miss
                debug_assert!(miss.is_none());
            }
            slots.push(Slot::Run(jobs.len()));
            planned_fps.insert(fp, jobs.len());
            jobs.push(genome.clone());
        }
        let outcomes = executor::run_batch(
            &mut self.backend,
            &self.feedback_suite,
            self.config.reps_per_config,
            &jobs,
            self.config.parallelism,
        );
        let mut results = Vec::with_capacity(slots.len());
        for slot in slots {
            match slot {
                Slot::Cached(outcome) => results.push(BatchResult {
                    outcome,
                    cached: true,
                    submission_index: None,
                    completed_at_s: self.wall_clock_s(),
                }),
                Slot::Alias(j) => {
                    // By commit order the aliased job has already been
                    // committed and cached; the lookup also counts the
                    // hit in the cache stats.
                    let outcome = self
                        .cache
                        .lookup(&jobs[j].fingerprint())
                        .unwrap_or_else(|| outcomes[j].clone());
                    results.push(BatchResult {
                        outcome,
                        cached: true,
                        submission_index: None,
                        completed_at_s: self.wall_clock_s(),
                    });
                }
                Slot::Run(j) => {
                    let outcome = outcomes[j].clone();
                    self.cache.insert(jobs[j].fingerprint(), outcome.clone());
                    let (index, completed_at_s) = self.account_submission(outcome.clone());
                    results.push(BatchResult {
                        outcome,
                        cached: false,
                        submission_index: Some(index),
                        completed_at_s,
                    });
                }
            }
        }
        results
    }

    /// Record one completed submission: quota, earliest-free-lane wall
    /// clock, and the log line. Returns (log index, completion time).
    fn account_submission(&mut self, outcome: EvalOutcome) -> (u64, f64) {
        let cost = self.backend.submission_cost_s();
        let lane = self
            .lane_busy_until
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.lane_busy_until[lane] += cost;
        let completed_at_s = self.lane_busy_until[lane];
        let index = self.log.len() as u64;
        self.log.push(SubmissionRecord {
            index,
            completed_at_s,
            outcome,
        });
        (index, completed_at_s)
    }

    /// Read-only cache probe (planning aid for batch callers: a cached
    /// genome will not consume quota). Does not count toward stats.
    pub fn cached_outcome(&self, genome: &KernelGenome) -> Option<EvalOutcome> {
        self.cache.peek(&genome.fingerprint()).cloned()
    }

    /// (hits, misses) of counted cache lookups on the batch path.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Final leaderboard score: geomean over a (typically 18-size)
    /// suite, taken outside the submission quota (the organisers run
    /// this once at the end).
    pub fn leaderboard_score(
        &mut self,
        genome: &KernelGenome,
        suite: &BenchmarkSuite,
    ) -> Result<f64, EvalError> {
        self.backend.check(genome)?;
        let mut times = Vec::with_capacity(suite.configs.len());
        for cfg in &suite.configs {
            let mut best = f64::INFINITY;
            for _ in 0..self.config.reps_per_config.max(1) {
                best = best.min(self.backend.measure(genome, cfg)?);
            }
            times.push(best);
        }
        Ok(geomean(&times))
    }

    /// Direct backend access (reports/benches only — the scientist
    /// never touches this).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::{seeds, KernelGenome};
    use crate::sim::SimBackend;
    use crate::workload::BenchmarkSuite;

    fn platform() -> EvalPlatform<SimBackend> {
        EvalPlatform::new(SimBackend::new(42), PlatformConfig::default())
    }

    #[test]
    fn successful_submission_returns_six_timings() {
        let mut p = platform();
        let out = p.submit(&seeds::mfma_seed());
        let t = out.timings().expect("should time");
        assert_eq!(t.len(), 6);
        assert!(t.iter().all(|&x| x > 0.0));
        assert_eq!(p.submissions(), 1);
    }

    #[test]
    fn compile_failure_logged() {
        let mut p = platform();
        let bad = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        let out = p.submit(&bad);
        assert!(matches!(out, EvalOutcome::CompileFailure(_)));
        assert!(matches!(
            p.log()[0].outcome,
            EvalOutcome::CompileFailure(_)
        ));
    }

    #[test]
    fn sequential_clock_advances_per_submission() {
        let mut p = platform();
        p.submit(&seeds::mfma_seed());
        let t1 = p.wall_clock_s();
        p.submit(&seeds::mfma_seed());
        let t2 = p.wall_clock_s();
        assert!(t2 > t1);
        assert!((t2 - 2.0 * t1).abs() < 1e-9, "strictly serialized");
    }

    #[test]
    fn parallel_lanes_share_wall_clock() {
        let mut seq = EvalPlatform::new(SimBackend::new(1), PlatformConfig::default());
        let mut par = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                parallelism: 3,
                ..Default::default()
            },
        );
        for _ in 0..6 {
            seq.submit(&seeds::mfma_seed());
            par.submit(&seeds::mfma_seed());
        }
        assert!((par.wall_clock_s() - seq.wall_clock_s() / 3.0).abs() < 1e-6);
    }

    #[test]
    fn quota_enforced() {
        let mut p = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        p.submit(&seeds::mfma_seed());
        assert!(!p.quota_exhausted());
        p.submit(&seeds::mfma_seed());
        assert!(p.quota_exhausted());
    }

    #[test]
    #[should_panic(expected = "quota exhausted")]
    fn submit_past_quota_panics() {
        let mut p = EvalPlatform::new(
            SimBackend::new(1),
            PlatformConfig {
                submission_quota: Some(1),
                ..Default::default()
            },
        );
        p.submit(&seeds::mfma_seed());
        p.submit(&seeds::mfma_seed());
    }

    #[test]
    fn batch_matches_sequential_at_one_lane() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::mfma_seed())
                .into_iter()
                .take(5)
                .map(|(_, g)| g)
                .collect();
        let mut seq = EvalPlatform::new(SimBackend::new(4), PlatformConfig::default());
        let expected: Vec<EvalOutcome> = jobs.iter().map(|g| seq.submit(g)).collect();
        let mut bat = EvalPlatform::new(SimBackend::new(4), PlatformConfig::default());
        let results = bat.submit_batch(&jobs);
        assert_eq!(results.len(), jobs.len());
        for (i, (r, e)) in results.iter().zip(&expected).enumerate() {
            assert!(!r.cached);
            assert_eq!(r.submission_index, Some(i as u64));
            assert_eq!(&r.outcome, e, "job {i}");
        }
        assert_eq!(bat.wall_clock_s(), seq.wall_clock_s());
        assert_eq!(bat.submissions(), seq.submissions());
    }

    #[test]
    fn batch_cache_hit_is_free() {
        let mut p = EvalPlatform::new(
            SimBackend::new(2),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let g = seeds::mfma_seed();
        let first = p.submit_batch(std::slice::from_ref(&g));
        assert!(!first[0].cached);
        assert_eq!(p.submissions(), 1);
        let clock = p.wall_clock_s();
        let second = p.submit_batch(std::slice::from_ref(&g));
        assert!(second[0].cached);
        assert_eq!(second[0].outcome, first[0].outcome, "identical EvalOutcome");
        assert_eq!(second[0].submission_index, None);
        assert_eq!(p.submissions(), 1, "cache hit consumes no quota");
        assert_eq!(p.wall_clock_s(), clock, "cache hit consumes no platform time");
        assert_eq!(p.cache_stats().0, 1);
    }

    #[test]
    fn in_batch_duplicates_are_served_once() {
        let mut p = EvalPlatform::new(SimBackend::new(12), PlatformConfig::default());
        let g = seeds::mfma_seed();
        let other = seeds::human_oracle();
        let batch = vec![g.clone(), other.clone(), g.clone()];
        let results = p.submit_batch(&batch);
        assert_eq!(results.len(), 3);
        assert!(!results[0].cached && !results[1].cached);
        assert!(results[2].cached, "second occurrence aliases the first");
        assert_eq!(results[2].outcome, results[0].outcome);
        assert_eq!(results[2].submission_index, None);
        assert_eq!(p.submissions(), 2, "the duplicate consumed no quota");
        assert_eq!(p.cache_stats().0, 1, "alias counted as a cache hit");
        // with the cache disabled, in-batch duplicates evaluate twice
        let mut raw = EvalPlatform::new(
            SimBackend::new(12),
            PlatformConfig {
                cache_results: false,
                ..Default::default()
            },
        );
        let results = raw.submit_batch(&batch);
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| !r.cached));
        assert_eq!(raw.submissions(), 3);
    }

    #[test]
    fn batch_truncates_at_quota() {
        let jobs: Vec<KernelGenome> =
            crate::genome::edit::valid_neighbors(&seeds::human_oracle())
                .into_iter()
                .take(4)
                .map(|(_, g)| g)
                .collect();
        let mut p = EvalPlatform::new(
            SimBackend::new(3),
            PlatformConfig {
                submission_quota: Some(2),
                ..Default::default()
            },
        );
        let results = p.submit_batch(&jobs);
        assert_eq!(results.len(), 2);
        assert_eq!(p.submissions(), 2);
        assert!(p.quota_exhausted());
    }

    #[test]
    fn cache_disabled_reevaluates() {
        let mut p = EvalPlatform::new(
            SimBackend::new(6),
            PlatformConfig {
                cache_results: false,
                ..Default::default()
            },
        );
        let g = seeds::mfma_seed();
        let a = p.submit_batch(std::slice::from_ref(&g));
        let b = p.submit_batch(std::slice::from_ref(&g));
        assert!(!a[0].cached && !b[0].cached);
        assert_eq!(p.submissions(), 2);
        assert!(p.cached_outcome(&g).is_none());
    }

    #[test]
    fn leaderboard_score_is_geomean_over_suite() {
        let mut p = platform();
        let score = p
            .leaderboard_score(&seeds::human_oracle(), &BenchmarkSuite::leaderboard())
            .unwrap();
        assert!(score > 0.0);
        // leaderboard doesn't count against the submission log
        assert_eq!(p.submissions(), 0);
    }

    #[test]
    fn reps_take_minimum() {
        // more reps can only lower (or keep) the reported time
        let mut p1 = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                reps_per_config: 1,
                ..Default::default()
            },
        );
        let mut p5 = EvalPlatform::new(
            SimBackend::new(9),
            PlatformConfig {
                reps_per_config: 5,
                ..Default::default()
            },
        );
        let t1 = p1.submit(&seeds::mfma_seed());
        let t5 = p5.submit(&seeds::mfma_seed());
        let g1 = crate::metrics::geomean(t1.timings().unwrap());
        let g5 = crate::metrics::geomean(t5.timings().unwrap());
        // not strictly comparable (different rng draws) but both sane
        assert!(g1 > 0.0 && g5 > 0.0);
    }
}
