//! Stage 1 — the LLM Evolutionary Selector (paper §3.1, App. A.1).
//!
//! Chooses a **Base** ("the basis code for the next experiment") and a
//! **Reference** ("chosen for its ability to help in analysing
//! experiments") from the population, with a written rationale. The
//! paper deliberately has *no* mechanical selection rule — it relies
//! on the LLM's judgement over the multi-objective situation. The
//! surrogate reproduces the three judgement patterns the paper's
//! App. A.1 samples exhibit:
//!
//! 1. base = consistently-best kernel, reference = a **divergent
//!    optimization path** from a common ancestor (sample 1);
//! 2. base = best, reference = its **direct parent** for one-step
//!    contrast (sample 2);
//! 3. base = best, reference = an ancestor that **uniquely wins one
//!    configuration** (sample 3 — m=6144, k=512, n=4096).

use super::llm::SurrogateLlm;
use crate::population::{Individual, Population};

/// Which reference-choice judgement the selector applied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReferencePolicy {
    DivergentPath,
    DirectParent,
    PerConfigSpecialist,
}

/// Ablation axis: how selection is performed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionPolicy {
    /// The paper's LLM-judgement selection (surrogate, multi-objective).
    PaperLlm,
    /// Uniform-random base + reference (lower bound).
    Random,
    /// Always best + second-best, no diversity reasoning (greedy).
    GreedyBest,
}

/// The selector's output (the `basis_code` / `basis_reference` /
/// `rationale` triple of App. A.1).
#[derive(Debug, Clone)]
pub struct Selection {
    pub base_id: String,
    pub reference_id: String,
    pub policy: Option<ReferencePolicy>,
    pub rationale: String,
}

/// Stage-1 agent.
#[derive(Debug, Clone)]
pub struct Selector {
    pub policy: SelectionPolicy,
}

impl Selector {
    pub fn new(policy: SelectionPolicy) -> Self {
        Selector { policy }
    }

    /// Select base + reference. Requires >= 2 successful members.
    ///
    /// Perf (§Perf, archive-scaling pass): every path reads the
    /// population's incrementally maintained indexes — the leaderboard
    /// for top-k/second-best (the old per-call `successful()` clone +
    /// full sort is gone), the per-config timing indexes for the
    /// specialist candidates, and resolved parent indices for the
    /// divergence walk — so per-round cost no longer grows with the
    /// archive. Candidate content, order, weights, and RNG call
    /// sequence are unchanged, keeping trajectories bit-identical.
    pub fn select(&self, pop: &Population, llm: &mut SurrogateLlm) -> Option<Selection> {
        let n_ok = pop.successful_count();
        if n_ok < 2 {
            return None;
        }
        match self.policy {
            SelectionPolicy::Random => {
                let base = pop.nth_successful(llm.rng().below(n_ok));
                let mut reference = pop.nth_successful(llm.rng().below(n_ok));
                while reference.id == base.id {
                    reference = pop.nth_successful(llm.rng().below(n_ok));
                }
                Some(Selection {
                    base_id: base.id.clone(),
                    reference_id: reference.id.clone(),
                    policy: None,
                    rationale: "(random-selection ablation: no judgement applied)".into(),
                })
            }
            SelectionPolicy::GreedyBest => {
                let mut top = pop.leaderboard_members();
                let best = top.next().expect(">= 2 successful members");
                let second = top.next().expect(">= 2 successful members");
                Some(Selection {
                    base_id: best.id.clone(),
                    reference_id: second.id.clone(),
                    policy: None,
                    rationale: "(greedy ablation: best and second-best by geomean)".into(),
                })
            }
            SelectionPolicy::PaperLlm => self.select_llm(pop, llm),
        }
    }

    fn select_llm(&self, pop: &Population, llm: &mut SurrogateLlm) -> Option<Selection> {
        // --- base: lowest geomean, with a temperature-weighted wobble
        // over the top few (the LLM sometimes favours a near-best with
        // interesting properties). Leaderboard order == the old stable
        // sort of successful() by score.
        let top: Vec<(&Individual, f64)> = pop
            .leaderboard_members()
            .take(3)
            .enumerate()
            .map(|(rank, m)| (m, 1.0 - rank as f64 * 0.45))
            .collect();
        let base = top[llm.sample_weighted(&top)].0;
        let base_idx = pop.index_of(&base.id).expect("base is in the population");

        // --- reference: gather one candidate per applicable policy,
        // then let the surrogate choose among them.
        let mut candidates: Vec<(ReferencePolicy, &Individual, f64)> = Vec::new();

        // (a) direct parent
        if let Some(parent_id) = base.parents.first() {
            if let Some(parent) = pop.by_id(parent_id) {
                if parent.outcome.is_success() {
                    candidates.push((ReferencePolicy::DirectParent, parent, 0.8));
                }
            }
        }
        // (b) per-config specialist: someone who beats the base on >= 1
        // feedback config despite a worse geomean. Answered from the
        // per-config timing indexes in O(result) — same candidates,
        // same first-config weights, same insertion order as the old
        // full-archive scan.
        for (i, m) in pop.config_beaters(base) {
            candidates.push((ReferencePolicy::PerConfigSpecialist, m, 0.9 + i as f64 * 1e-3));
        }
        // (c) divergent path: a member sharing a common ancestor with
        // the base but on a different branch (not an ancestor/
        // descendant). The base's ancestor set is built once; candidate
        // chains walk resolved parent *indices* (no id hashing), so the
        // scan is O(depth) per candidate and stops at the first hit.
        {
            let mut base_anc: std::collections::HashSet<usize> =
                std::collections::HashSet::new();
            let mut cur = pop.parent_of(base_idx);
            while let Some(p) = cur {
                base_anc.insert(p);
                cur = pop.parent_of(p);
            }
            'outer: for &mi in pop.successful_indices() {
                let mi = mi as usize;
                if mi == base_idx || base_anc.contains(&mi) {
                    continue;
                }
                // walk m's ancestor chain directly (indices strictly
                // descend, so cycles are impossible — the depth cap
                // stays because "divergence evidence within 64
                // generations" is observable selector behaviour)
                let mut cur = pop.parent_of(mi);
                let mut depth = 0;
                while let Some(p) = cur {
                    if p == base_idx {
                        continue 'outer; // descendant of base, not divergent
                    }
                    if base_anc.contains(&p) {
                        candidates.push((
                            ReferencePolicy::DivergentPath,
                            pop.member(mi),
                            0.85,
                        ));
                        break 'outer;
                    }
                    cur = pop.parent_of(p);
                    depth += 1;
                    if depth > 64 {
                        break;
                    }
                }
            }
        }
        // fallback: second best
        if candidates.is_empty() {
            let second = pop.leaderboard_members().find(|m| m.id != base.id)?;
            candidates.push((ReferencePolicy::DirectParent, second, 0.5));
        }
        // dedup on reference id, keep highest weight (no per-candidate
        // id clones — the seen-set borrows)
        candidates.sort_by(|a, b| b.2.total_cmp(&a.2));
        let mut seen = std::collections::HashSet::new();
        candidates.retain(|(_, m, _)| seen.insert(m.id.as_str()) && m.id != base.id);
        if candidates.is_empty() {
            return None;
        }
        let scored: Vec<((ReferencePolicy, &Individual), f64)> = candidates
            .iter()
            .map(|(p, m, w)| ((*p, *m), *w))
            .collect();
        let (policy, reference) = scored[llm.sample_weighted(&scored)].0;

        let rationale = render_rationale(pop, base, reference, policy);
        Some(Selection {
            base_id: base.id.clone(),
            reference_id: reference.id.clone(),
            policy: Some(policy),
            rationale,
        })
    }
}

/// Render the App.-A.1-style rationale for a selection.
fn render_rationale(
    pop: &Population,
    base: &Individual,
    reference: &Individual,
    policy: ReferencePolicy,
) -> String {
    let base_score = base.score().unwrap_or(f64::NAN);
    let why_ref = match policy {
        ReferencePolicy::DirectParent => format!(
            "Run {} , its direct parent, is chosen as the reference because it represents \
             the immediate previous highly optimized iteration, providing crucial context \
             for understanding the precise improvements and minor trade-offs leading to \
             the current best performance.",
            reference.id
        ),
        ReferencePolicy::DivergentPath => {
            let ancestor = pop
                .common_ancestor(&base.id, &reference.id)
                .map(|a| a.id.clone())
                .unwrap_or_else(|| "a seed".into());
            format!(
                "Run {} is chosen as the reference because it represents a divergent \
                 optimization path from a common ancestor ({ancestor}), offering specific \
                 strengths that can provide valuable comparative insights for the kernel \
                 scientist, despite its overall lower performance.",
                reference.id
            )
        }
        ReferencePolicy::PerConfigSpecialist => {
            let cfg = winning_config(pop, base, reference)
                .map(|c| format!("(m={}, k={}, n={})", c.m, c.k, c.n))
                .unwrap_or_else(|| "one specific configuration".into());
            format!(
                "Run {} is selected as the reference because, while having a higher total \
                 benchmark score, it uniquely performs better on one specific configuration \
                 {cfg}, providing valuable insight into optimization trade-offs for the \
                 kernel scientist.",
                reference.id
            )
        }
    };
    format!(
        "Run {} is selected as the basis code due to its consistently lowest average \
         benchmark scores across all input configurations (geomean {:.1} us), indicating \
         the best overall performance achieved so far. {}",
        base.id, base_score, why_ref
    )
}

fn winning_config<'a>(
    pop: &'a Population,
    base: &Individual,
    reference: &Individual,
) -> Option<&'a crate::workload::GemmConfig> {
    let bts = base.outcome.timings()?;
    let rts = reference.outcome.timings()?;
    for (i, (&r, &b)) in rts.iter().zip(bts.iter()).enumerate() {
        if r < b {
            return pop.feedback_configs.get(i);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::population::EvalOutcome;
    use crate::workload::FEEDBACK_CONFIGS;

    fn ind(id: &str, parents: &[&str], timings: Vec<f64>) -> Individual {
        Individual {
            id: id.into(),
            parents: parents.iter().map(|s| s.to_string()).collect(),
            genome: seeds::mfma_seed(),
            experiment: String::new(),
            report: String::new(),
            outcome: EvalOutcome::Timings(timings),
        }
    }

    fn llm() -> SurrogateLlm {
        SurrogateLlm::new(
            7,
            super::super::llm::LlmConfig {
                temperature: 0.0, // deterministic for golden tests
                ..Default::default()
            },
        )
    }

    #[test]
    fn needs_two_successes() {
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        pop.add(ind("00001", &[], vec![100.0; 6]));
        let sel = Selector::new(SelectionPolicy::PaperLlm);
        assert!(sel.select(&pop, &mut llm()).is_none());
    }

    #[test]
    fn base_is_best_at_zero_temperature() {
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        pop.add(ind("00001", &[], vec![1000.0; 6]));
        pop.add(ind("00002", &["00001"], vec![500.0; 6]));
        pop.add(ind("00003", &["00002"], vec![300.0; 6]));
        let sel = Selector::new(SelectionPolicy::PaperLlm);
        let s = sel.select(&pop, &mut llm()).unwrap();
        assert_eq!(s.base_id, "00003");
        assert!(s.rationale.contains("00003"));
    }

    #[test]
    fn direct_parent_policy_fires() {
        // Linear chain: the only candidate policies are DirectParent
        // (parent of best) — A.1 sample 2's shape.
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        pop.add(ind("00001", &[], vec![1000.0; 6]));
        pop.add(ind("00002", &["00001"], vec![500.0; 6]));
        let sel = Selector::new(SelectionPolicy::PaperLlm);
        let s = sel.select(&pop, &mut llm()).unwrap();
        assert_eq!(s.base_id, "00002");
        assert_eq!(s.reference_id, "00001");
        assert!(s.rationale.contains("direct parent"));
    }

    #[test]
    fn per_config_specialist_policy_fires() {
        // 00002 has worse geomean but uniquely wins config 0 —
        // A.1 sample 3's shape.
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        pop.add(ind("00001", &[], vec![100.0, 100.0, 100.0, 100.0, 100.0, 100.0]));
        pop.add(ind(
            "00002",
            &["00001"],
            vec![50.0, 400.0, 400.0, 400.0, 400.0, 400.0],
        ));
        // best individual (base)
        pop.add(ind("00003", &["00001"], vec![80.0, 80.0, 80.0, 80.0, 80.0, 80.0]));
        let sel = Selector::new(SelectionPolicy::PaperLlm);
        let s = sel.select(&pop, &mut llm()).unwrap();
        assert_eq!(s.base_id, "00003");
        assert_eq!(s.reference_id, "00002");
        assert_eq!(s.policy, Some(ReferencePolicy::PerConfigSpecialist));
        assert!(s.rationale.contains("uniquely performs better"));
        assert!(s.rationale.contains("m=6144"), "{}", s.rationale);
    }

    #[test]
    fn divergent_path_policy_fires() {
        // Two branches from 00001; no parent link from best to other
        // branch; neither beats the base anywhere.
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        pop.add(ind("00001", &[], vec![1000.0; 6]));
        pop.add(ind("00002", &["00001"], vec![400.0; 6]));
        pop.add(ind("00003", &["00001"], vec![500.0; 6]));
        pop.add(ind("00004", &["00002"], vec![300.0; 6]));
        let sel = Selector::new(SelectionPolicy::PaperLlm);
        // 00004 is base; direct parent 00002 and divergent 00003 are
        // both candidates. At T=0 the specialist/parent weighting picks
        // the parent, so force policy diversity via temperature.
        let mut hot = SurrogateLlm::new(
            11,
            super::super::llm::LlmConfig {
                temperature: 3.0,
                ..Default::default()
            },
        );
        let mut saw_divergent = false;
        for _ in 0..40 {
            let s = sel.select(&pop, &mut hot).unwrap();
            if s.policy == Some(ReferencePolicy::DivergentPath) {
                assert!(s.rationale.contains("divergent"));
                saw_divergent = true;
                break;
            }
        }
        assert!(saw_divergent, "divergent policy never sampled");
    }

    #[test]
    fn random_and_greedy_ablations() {
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        pop.add(ind("00001", &[], vec![1000.0; 6]));
        pop.add(ind("00002", &["00001"], vec![500.0; 6]));
        pop.add(ind("00003", &["00001"], vec![700.0; 6]));
        let greedy = Selector::new(SelectionPolicy::GreedyBest)
            .select(&pop, &mut llm())
            .unwrap();
        assert_eq!(greedy.base_id, "00002");
        assert_eq!(greedy.reference_id, "00003");
        let random = Selector::new(SelectionPolicy::Random)
            .select(&pop, &mut llm())
            .unwrap();
        assert_ne!(random.base_id, random.reference_id);
    }

    #[test]
    fn reference_never_equals_base() {
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        for i in 1..=6 {
            let parent = if i == 1 {
                vec![]
            } else {
                vec![format!("{:05}", i - 1)]
            };
            pop.add(Individual {
                id: format!("{i:05}"),
                parents: parent,
                genome: seeds::mfma_seed(),
                experiment: String::new(),
                report: String::new(),
                outcome: EvalOutcome::Timings(vec![1000.0 / i as f64; 6]),
            });
        }
        let sel = Selector::new(SelectionPolicy::PaperLlm);
        let mut hot = SurrogateLlm::with_seed(5);
        for _ in 0..50 {
            let s = sel.select(&pop, &mut hot).unwrap();
            assert_ne!(s.base_id, s.reference_id);
        }
    }
}
