//! The three LLM agent stages of the GPU Kernel Scientist (paper §3)
//! and the knowledge base they share.
//!
//! * [`selector`] — Stage 1, the Evolutionary Selector (§3.1)
//! * [`designer`] — Stage 2, the Experiment Designer (§3.2)
//! * [`writer`]   — Stage 3, the Kernel Writer (§3.3)
//! * [`knowledge`] — the findings doc + digested avenue library
//! * [`llm`]      — the LLM boundary and its seeded surrogate
//!
//! All three stages draw their stochasticity from one [`SurrogateLlm`]
//! instance so an entire scientist run replays from a single seed.

pub mod designer;
pub mod knowledge;
pub mod llm;
pub mod selector;
pub mod writer;

pub use designer::{DesignOutput, Designer, ExperimentPlan, ExperimentRule};
pub use knowledge::{Avenue, Finding, FindingsDoc, KnowledgeBase, KnowledgeProfile};
pub use llm::{LlmConfig, SurrogateLlm};
pub use selector::{ReferencePolicy, Selection, SelectionPolicy, Selector};
pub use writer::{KernelWrite, Writer};

/// The full agent stack with its shared surrogate LLM.
pub struct AgentSuite {
    pub llm: SurrogateLlm,
    pub selector: Selector,
    pub designer: Designer,
    pub writer: Writer,
    pub knowledge: KnowledgeBase,
}

impl AgentSuite {
    /// The paper's configuration: LLM-judgement selection, the 3-of-5
    /// experiment rule, full knowledge base.
    pub fn paper(seed: u64) -> Self {
        AgentSuite {
            llm: SurrogateLlm::with_seed(seed),
            selector: Selector::new(SelectionPolicy::PaperLlm),
            designer: Designer::default(),
            writer: Writer::new(),
            knowledge: KnowledgeBase::full(),
        }
    }

    pub fn with_llm_config(mut self, config: LlmConfig) -> Self {
        self.llm.config = config;
        self
    }

    pub fn with_selection_policy(mut self, policy: SelectionPolicy) -> Self {
        self.selector = Selector::new(policy);
        self
    }

    pub fn with_experiment_rule(mut self, rule: ExperimentRule) -> Self {
        self.designer = Designer::with_rule(rule);
        self
    }

    pub fn with_knowledge(mut self, profile: KnowledgeProfile) -> Self {
        self.knowledge = KnowledgeBase::with_profile(profile);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_suite_defaults() {
        let s = AgentSuite::paper(1);
        assert_eq!(s.selector.policy, SelectionPolicy::PaperLlm);
        assert_eq!(s.designer.rule, ExperimentRule::Paper);
        assert_eq!(s.designer.n_plans, 5);
        assert_eq!(s.designer.n_chosen, 3);
        assert_eq!(s.knowledge.profile, KnowledgeProfile::Full);
    }

    #[test]
    fn builders_override() {
        let s = AgentSuite::paper(1)
            .with_selection_policy(SelectionPolicy::Random)
            .with_experiment_rule(ExperimentRule::TopMax)
            .with_knowledge(KnowledgeProfile::Minimal);
        assert_eq!(s.selector.policy, SelectionPolicy::Random);
        assert_eq!(s.designer.rule, ExperimentRule::TopMax);
        assert_eq!(s.knowledge.profile, KnowledgeProfile::Minimal);
    }
}
