//! Stage 2 — the LLM Experiment Designer (paper §3.2, App. A.2).
//!
//! From the Base code (genome) plus the knowledge base, produce:
//!
//! 1. **10 optimization avenues** — "intentionally longer than
//!    required ... it increases the diversity of options";
//! 2. **5 experiment plans**, each with a description, a rubric, a
//!    predicted `performance: [lo, hi]` range, and an `innovation`
//!    score;
//! 3. the **3-of-5 choice** (without replacement): (i) the most
//!    innovative, (ii) the highest *maximum* predicted performance,
//!    (iii) the highest *minimum* predicted performance — "this helps
//!    to keep a broad range of alternative paths under consideration".

use super::knowledge::{Avenue, KnowledgeBase};
use super::llm::SurrogateLlm;
use crate::genome::{edit::GenomeEdit, KernelGenome};
use crate::population::Population;
use crate::sim::Bottleneck;

/// Flat prior bonus (percent-gain scale) granted to avenues that
/// attack the base kernel's classified bottleneck when the designer
/// runs profile-guided (DESIGN.md §11). Bounded: large enough to
/// reorder mid-tier avenues (whose mean priors sit tens of percent
/// apart), small enough that a dominant avenue like MFMA adoption
/// still wins regardless of classification.
pub const PROFILE_PRIOR_BONUS: f64 = 35.0;

/// Flat prior bonus (percent-gain scale) granted to avenues that
/// attack a bottleneck surfaced by the static analyzer when the
/// designer runs lint-guided (`[lint] guided`, DESIGN.md §13): the
/// base's warn diagnostics plus its lint-rejected children's error
/// diagnostics. Smaller than [`PROFILE_PRIOR_BONUS`] — static
/// prediction is weaker evidence than a measured profile, and several
/// lint attacks can stack where the profile contributes exactly one.
pub const LINT_PRIOR_BONUS: f64 = 20.0;

/// One experiment plan (the YAML blocks of App. A.2).
#[derive(Debug, Clone)]
pub struct ExperimentPlan {
    pub avenue: Avenue,
    pub description: String,
    /// The concrete rubric the writer must implement.
    pub rubric: Vec<GenomeEdit>,
    /// Rubric rendered as prose lines (for transcripts).
    pub rubric_text: Vec<String>,
    /// Predicted gain range, percent (`performance: [lo, hi]`).
    pub performance: (f64, f64),
    /// `innovation:` score, 0-100.
    pub innovation: u8,
}

/// Designer output: the avenue list + the 5 plans.
#[derive(Debug, Clone)]
pub struct DesignOutput {
    pub avenues: Vec<Avenue>,
    pub plans: Vec<ExperimentPlan>,
}

/// Ablation axis: how 3 experiments are picked from the 5 plans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExperimentRule {
    /// The paper's rule: most innovative, highest max, highest min.
    Paper,
    /// Top-3 by maximum predicted performance (pure exploitation).
    TopMax,
    /// Uniform random 3 (pure exploration).
    Random3,
}

/// Stage-2 agent.
#[derive(Debug, Clone)]
pub struct Designer {
    pub rule: ExperimentRule,
    /// How many avenues to surface (paper: 10).
    pub n_avenues: usize,
    /// How many plans to draft (paper: 5).
    pub n_plans: usize,
    /// How many plans to run (paper: 3).
    pub n_chosen: usize,
}

impl Default for Designer {
    fn default() -> Self {
        Designer {
            rule: ExperimentRule::Paper,
            n_avenues: 10,
            n_plans: 5,
            n_chosen: 3,
        }
    }
}

impl Designer {
    pub fn with_rule(rule: ExperimentRule) -> Self {
        Designer {
            rule,
            ..Default::default()
        }
    }

    /// Produce avenues + plans for a base genome.
    ///
    /// Novelty shaping: avenues already attempted along the base's
    /// lineage lose innovation points (the LLM sees the one-step
    /// experiment analyses in context and avoids re-proposing stale
    /// ideas); untried avenues gain a small bonus.
    ///
    /// `bottleneck` is the base kernel's classified profile bottleneck
    /// when the run is profile-guided (`[profile] guided`, DESIGN.md
    /// §11): avenues that attack it gain [`PROFILE_PRIOR_BONUS`] in
    /// both the avenue ranking and the plan draw. `None` — timing-only
    /// feedback — adds exactly zero and consumes no extra randomness,
    /// so unguided designs are bit-identical to the pre-profile ones.
    pub fn design(
        &self,
        base_id: &str,
        base: &KernelGenome,
        pop: &Population,
        kb: &KnowledgeBase,
        llm: &mut SurrogateLlm,
        bottleneck: Option<Bottleneck>,
    ) -> DesignOutput {
        self.design_guided(base_id, base, pop, kb, llm, bottleneck, &[])
    }

    /// [`Designer::design`] with an additional static-analysis prior
    /// (`[lint] guided`, DESIGN.md §13): every avenue attacking any
    /// bottleneck in `lint_attacks` gains [`LINT_PRIOR_BONUS`] on top
    /// of the profile bonus. An empty slice — lint guidance off or
    /// nothing diagnosed — adds exactly zero and consumes no extra
    /// randomness, so ungated designs are bit-identical to
    /// [`Designer::design`].
    #[allow(clippy::too_many_arguments)]
    pub fn design_guided(
        &self,
        base_id: &str,
        base: &KernelGenome,
        pop: &Population,
        kb: &KnowledgeBase,
        llm: &mut SurrogateLlm,
        bottleneck: Option<Bottleneck>,
        lint_attacks: &[Bottleneck],
    ) -> DesignOutput {
        let boost = |a: &Avenue| -> f64 {
            let profile = match bottleneck {
                Some(b) if a.attacks().contains(&b) => PROFILE_PRIOR_BONUS,
                _ => 0.0,
            };
            let lint = if lint_attacks.iter().any(|b| a.attacks().contains(b)) {
                LINT_PRIOR_BONUS
            } else {
                0.0
            };
            profile + lint
        };
        let mut available = kb.available_avenues(base);
        // rank by perturbed prior mean gain, keep up to n_avenues
        let mut scored: Vec<(Avenue, f64)> = available
            .drain(..)
            .map(|a| {
                let (lo, hi) = a.prior_gain();
                let wobble = llm.rng().range_f64(0.85, 1.15);
                let score = (lo + hi) * 0.5 * wobble + boost(&a);
                (a, score)
            })
            .collect();
        scored.sort_by(|a, b| b.1.total_cmp(&a.1));
        scored.truncate(self.n_avenues);
        let avenues: Vec<Avenue> = scored.iter().map(|(a, _)| *a).collect();

        // lineage history for novelty shaping (borrowed — no per-call
        // experiment-string clones, §Perf)
        let tried: std::collections::HashSet<&str> = pop
            .ancestors(base_id)
            .iter()
            .copied()
            .chain(pop.by_id(base_id))
            .map(|m| m.experiment.as_str())
            .collect();

        let mut plans = Vec::new();
        let mut used = std::collections::HashSet::new();
        // temperature-weighted draw of distinct avenues into plans
        while plans.len() < self.n_plans && used.len() < avenues.len() {
            let candidates: Vec<(Avenue, f64)> = avenues
                .iter()
                .filter(|a| !used.contains(*a))
                .map(|a| {
                    let (lo, hi) = a.prior_gain();
                    let score = (lo + hi) * 0.5 + a.innovation() as f64 * 0.3 + boost(a);
                    (*a, score)
                })
                .collect();
            if candidates.is_empty() {
                break;
            }
            let avenue = candidates[llm.sample_weighted(&candidates)].0;
            used.insert(avenue);
            let rubric = avenue.instantiate(base, llm.rng());
            if rubric.iter().all(|e| e.is_noop(base)) {
                continue;
            }
            let mut innovation = llm.perturb_innovation(avenue.innovation());
            // order-independent reduction: `any` over an unordered set
            // yields the same boolean regardless of visit order
            let tried_before = tried.iter().any(|e| e.contains(avenue.name())); // detlint: allow(DL003)
            if tried_before {
                innovation = innovation.saturating_sub(25);
            } else {
                innovation = (innovation + 5).min(100);
            }
            let performance = llm.perturb_gain(avenue.prior_gain());
            let rubric_text = rubric.iter().map(|e| e.describe()).collect();
            plans.push(ExperimentPlan {
                avenue,
                description: format!(
                    "{}: {} (expected from digested knowledge: {:?}%)",
                    avenue.name(),
                    rubric
                        .iter()
                        .map(|e| e.describe())
                        .collect::<Vec<_>>()
                        .join("; "),
                    avenue.prior_gain()
                ),
                rubric,
                rubric_text,
                performance,
                innovation,
            });
        }
        DesignOutput { avenues, plans }
    }

    /// Apply the 3-of-5 selection rule; returns indices into `plans`.
    pub fn choose(&self, plans: &[ExperimentPlan], llm: &mut SurrogateLlm) -> Vec<usize> {
        let n = self.n_chosen.min(plans.len());
        match self.rule {
            ExperimentRule::Paper => {
                let mut chosen: Vec<usize> = Vec::new();
                let pick = |chosen: &Vec<usize>, key: &dyn Fn(&ExperimentPlan) -> f64| {
                    plans
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| !chosen.contains(i))
                        .max_by(|a, b| key(a.1).total_cmp(&key(b.1)))
                        .map(|(i, _)| i)
                };
                // (i) most innovative
                if let Some(i) = pick(&chosen, &|p| p.innovation as f64) {
                    chosen.push(i);
                }
                // (ii) highest maximum performance
                if chosen.len() < n {
                    if let Some(i) = pick(&chosen, &|p| p.performance.1) {
                        chosen.push(i);
                    }
                }
                // (iii) highest minimum performance
                if chosen.len() < n {
                    if let Some(i) = pick(&chosen, &|p| p.performance.0) {
                        chosen.push(i);
                    }
                }
                chosen
            }
            ExperimentRule::TopMax => {
                let mut idx: Vec<usize> = (0..plans.len()).collect();
                idx.sort_by(|&a, &b| {
                    plans[b].performance.1.total_cmp(&plans[a].performance.1)
                });
                idx.truncate(n);
                idx
            }
            ExperimentRule::Random3 => {
                let mut idx: Vec<usize> = (0..plans.len()).collect();
                llm.rng().shuffle(&mut idx);
                idx.truncate(n);
                idx
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::knowledge::KnowledgeBase;
    use crate::agents::llm::LlmConfig;
    use crate::genome::seeds;
    use crate::workload::FEEDBACK_CONFIGS;

    fn setup() -> (Population, KnowledgeBase, SurrogateLlm) {
        (
            Population::new(FEEDBACK_CONFIGS.to_vec()),
            KnowledgeBase::full(),
            SurrogateLlm::with_seed(11),
        )
    }

    #[test]
    fn produces_five_plans_for_naive_base() {
        let (pop, kb, mut llm) = setup();
        let d = Designer::default();
        let out = d.design("00001", &seeds::naive_hip(), &pop, &kb, &mut llm, None);
        assert!(out.avenues.len() >= 5, "avenues: {:?}", out.avenues);
        assert_eq!(out.plans.len(), 5);
        for p in &out.plans {
            assert!(!p.rubric.is_empty());
            assert!(p.performance.1 > p.performance.0);
            assert!(p.innovation <= 100);
            assert!(!p.description.is_empty());
        }
    }

    #[test]
    fn plans_use_distinct_avenues() {
        let (pop, kb, mut llm) = setup();
        let out = Designer::default()
            .design("00001", &seeds::naive_hip(), &pop, &kb, &mut llm, None);
        let mut avs: Vec<Avenue> = out.plans.iter().map(|p| p.avenue).collect();
        avs.sort_by_key(|a| format!("{a:?}"));
        avs.dedup();
        assert_eq!(avs.len(), out.plans.len());
    }

    #[test]
    fn paper_rule_picks_innovative_max_min() {
        let plans = vec![
            plan(Avenue::TileSizeTuning, (1.0, 10.0), 20),
            plan(Avenue::CooperativeStore, (5.0, 15.0), 60),
            plan(Avenue::LdsConflictPadding, (15.0, 40.0), 85),
            plan(Avenue::WiderVectorLoads, (2.0, 90.0), 30),
            plan(Avenue::KLoopUnrolling, (25.0, 30.0), 10),
        ];
        let d = Designer::default();
        let mut llm = SurrogateLlm::with_seed(1);
        let chosen = d.choose(&plans, &mut llm);
        // most innovative: idx 2 (85); highest max: idx 3 (90);
        // highest min among remaining: idx 4 (25)
        assert_eq!(chosen, vec![2, 3, 4]);
    }

    #[test]
    fn paper_rule_without_replacement() {
        // one plan dominates all three criteria; rule must still pick 3
        let plans = vec![
            plan(Avenue::LdsConflictPadding, (50.0, 100.0), 95),
            plan(Avenue::TileSizeTuning, (1.0, 5.0), 10),
            plan(Avenue::KLoopUnrolling, (2.0, 6.0), 20),
            plan(Avenue::WiderVectorLoads, (3.0, 7.0), 30),
            plan(Avenue::CooperativeStore, (4.0, 8.0), 40),
        ];
        let chosen = Designer::default().choose(&plans, &mut SurrogateLlm::with_seed(2));
        assert_eq!(chosen.len(), 3);
        let mut dedup = chosen.clone();
        dedup.dedup();
        assert_eq!(chosen, dedup);
        assert_eq!(chosen[0], 0); // dominator taken once, by innovation
    }

    #[test]
    fn topmax_rule_sorts_by_max() {
        let plans = vec![
            plan(Avenue::TileSizeTuning, (1.0, 10.0), 20),
            plan(Avenue::CooperativeStore, (5.0, 95.0), 60),
            plan(Avenue::LdsConflictPadding, (15.0, 40.0), 85),
        ];
        let d = Designer::with_rule(ExperimentRule::TopMax);
        let chosen = d.choose(&plans, &mut SurrogateLlm::with_seed(3));
        assert_eq!(chosen[0], 1);
    }

    #[test]
    fn random3_is_seeded() {
        let plans: Vec<ExperimentPlan> = (0..5)
            .map(|i| plan(Avenue::TileSizeTuning, (1.0, 2.0 + i as f64), 10))
            .collect();
        let d = Designer::with_rule(ExperimentRule::Random3);
        let a = d.choose(&plans, &mut SurrogateLlm::with_seed(4));
        let b = d.choose(&plans, &mut SurrogateLlm::with_seed(4));
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn lineage_repetition_lowers_innovation() {
        let (mut pop, kb, _) = setup();
        use crate::population::{EvalOutcome, Individual};
        pop.add(Individual {
            id: "00001".into(),
            parents: vec![],
            genome: seeds::mfma_seed(),
            experiment: format!("{}: tried before", Avenue::DoubleBuffering.name()),
            report: String::new(),
            outcome: EvalOutcome::Timings(vec![100.0; 6]),
        });
        // Run many designs; plans on the tried avenue should carry
        // lower innovation than its prior on average.
        let d = Designer::default();
        let mut llm = SurrogateLlm::new(5, LlmConfig::default());
        let mut tried_scores = Vec::new();
        for _ in 0..30 {
            let out = d.design("00001", &seeds::mfma_seed(), &pop, &kb, &mut llm, None);
            for p in out.plans {
                if p.avenue == Avenue::DoubleBuffering {
                    tried_scores.push(p.innovation as f64);
                }
            }
        }
        if !tried_scores.is_empty() {
            let mean = tried_scores.iter().sum::<f64>() / tried_scores.len() as f64;
            assert!(
                mean < Avenue::DoubleBuffering.innovation() as f64 - 10.0,
                "mean={mean}"
            );
        }
    }

    #[test]
    fn unguided_design_is_bit_identical_to_the_pre_profile_path() {
        // bottleneck: None must add exactly zero and consume no extra
        // randomness — two identically seeded designers stay in
        // lockstep across repeated unguided designs
        let (pop, kb, _) = setup();
        let mut a = SurrogateLlm::with_seed(21);
        let mut b = SurrogateLlm::with_seed(21);
        let d = Designer::default();
        for _ in 0..10 {
            let oa = d.design("00001", &seeds::naive_hip(), &pop, &kb, &mut a, None);
            let ob = d.design("00001", &seeds::naive_hip(), &pop, &kb, &mut b, None);
            assert_eq!(oa.avenues, ob.avenues);
            let pa: Vec<Avenue> = oa.plans.iter().map(|p| p.avenue).collect();
            let pb: Vec<Avenue> = ob.plans.iter().map(|p| p.avenue).collect();
            assert_eq!(pa, pb);
        }
        assert_eq!(a.rng_state(), b.rng_state());
    }

    #[test]
    fn bottleneck_conditioning_steers_the_plan_draw() {
        // with only 2 plan slots, the bonus must pull a matching
        // avenue into the draft more often than timing-only feedback
        use crate::sim::Bottleneck;
        let d = Designer {
            n_plans: 2,
            ..Designer::default()
        };
        let (pop, kb, _) = setup();
        let memory_plans = |bottleneck: Option<Bottleneck>| -> usize {
            let mut llm = SurrogateLlm::with_seed(7);
            let mut hits = 0;
            for _ in 0..40 {
                let out =
                    d.design("00001", &seeds::naive_hip(), &pop, &kb, &mut llm, bottleneck);
                hits += out
                    .plans
                    .iter()
                    .filter(|p| p.avenue.attacks().contains(&Bottleneck::Memory))
                    .count();
            }
            hits
        };
        let guided = memory_plans(Some(Bottleneck::Memory));
        let unguided = memory_plans(None);
        assert!(
            guided > unguided,
            "guided {guided} memory plans vs unguided {unguided}"
        );
    }

    #[test]
    fn empty_lint_attacks_are_bit_identical_to_plain_design() {
        // design() delegates with an empty slice; an explicit empty
        // slice must stay in RNG lockstep with it
        let (pop, kb, _) = setup();
        let mut a = SurrogateLlm::with_seed(31);
        let mut b = SurrogateLlm::with_seed(31);
        let d = Designer::default();
        for _ in 0..10 {
            let oa = d.design("00001", &seeds::naive_hip(), &pop, &kb, &mut a, None);
            let ob = d.design_guided(
                "00001",
                &seeds::naive_hip(),
                &pop,
                &kb,
                &mut b,
                None,
                &[],
            );
            assert_eq!(oa.avenues, ob.avenues);
        }
        assert_eq!(a.rng_state(), b.rng_state());
    }

    #[test]
    fn lint_attacks_steer_the_plan_draw() {
        use crate::sim::Bottleneck;
        let d = Designer {
            n_plans: 2,
            ..Designer::default()
        };
        let (pop, kb, _) = setup();
        let memory_plans = |attacks: &[Bottleneck]| -> usize {
            let mut llm = SurrogateLlm::with_seed(13);
            let mut hits = 0;
            for _ in 0..40 {
                let out = d.design_guided(
                    "00001",
                    &seeds::naive_hip(),
                    &pop,
                    &kb,
                    &mut llm,
                    None,
                    attacks,
                );
                hits += out
                    .plans
                    .iter()
                    .filter(|p| p.avenue.attacks().contains(&Bottleneck::Memory))
                    .count();
            }
            hits
        };
        let guided = memory_plans(&[Bottleneck::Memory]);
        let unguided = memory_plans(&[]);
        assert!(
            guided > unguided,
            "lint-guided {guided} memory plans vs unguided {unguided}"
        );
    }

    fn plan(avenue: Avenue, performance: (f64, f64), innovation: u8) -> ExperimentPlan {
        ExperimentPlan {
            avenue,
            description: String::new(),
            rubric: vec![],
            rubric_text: vec![],
            performance,
            innovation,
        }
    }
}
