//! The knowledge base: the "findings" document and the avenue library.
//!
//! The paper bootstraps by (a) an LLM-driven hardware-probing phase
//! whose conclusions are distilled into a *findings document* (§3,
//! §4.3 — e.g. the MFMA memory-layout quirks of footnote 2), and (b)
//! digesting external documents (rocWMMA docs, the AMD matrix-
//! instruction calculator, CUDA blog posts by Boehm and Armbruster)
//! into task-relevant optimization *avenues* (§3.2, App. A.2).
//!
//! Here a [`Finding`] gates avenues that require bootstrap knowledge
//! (you cannot write an MFMA kernel before the probing phase revealed
//! the intrinsic semantics), and each [`Avenue`] carries the digested
//! prior — expected gain range + innovation score — the Experiment
//! Designer samples from. The knowledge-ablation bench strips the
//! library down to see how far the loop gets on generic GPU lore.

use crate::genome::{
    edit::GenomeEdit, ComputePath, GridMapping, KernelGenome, Precision, ScaleCache,
    Swizzle, Writeback,
};
use crate::rng::Rng;
use crate::sim::Bottleneck;

/// Bootstrap findings (the distilled hardware-probing results).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Finding {
    /// MFMA intrinsic semantics + fragment memory layout understood
    /// (the paper's extended deep-dive, §3 and footnote 2).
    MfmaSemantics,
    /// The LDS re-purposing trick for scale caching verified safe
    /// under double buffering (App. A.3).
    LdsRepurposeTrick,
    /// XOR-swizzle layouts verified against rocWMMA fragment loads.
    SwizzleLayouts,
}

/// The findings document: which probes have been run and distilled.
#[derive(Debug, Clone, Default)]
pub struct FindingsDoc {
    findings: Vec<Finding>,
    /// Free-text digest entries (kept for report rendering).
    pub digest: Vec<String>,
}

impl FindingsDoc {
    /// The paper's starting state: the bootstrap deep-dive has already
    /// produced the MFMA findings (it predates the evolutionary loop).
    pub fn bootstrap() -> Self {
        let mut doc = FindingsDoc::default();
        doc.record(
            Finding::MfmaSemantics,
            "MFMA 32x32x16 fp8 intrinsics probed: fragment rows spread \
             across wave quarters; accumulate in f32, cast bf16 on store.",
        );
        doc.record(
            Finding::LdsRepurposeTrick,
            "Consumed A/B LDS buffers may be overlaid with f32 scales \
             once the pipeline stage has retired (requires ping-pong).",
        );
        doc.record(
            Finding::SwizzleLayouts,
            "XOR-swizzled LDS columns match rocwmma::load_matrix_sync \
             expectations; do not combine with row padding.",
        );
        doc
    }

    pub fn record(&mut self, f: Finding, digest: &str) {
        if !self.has(f) {
            self.findings.push(f);
        }
        self.digest.push(digest.to_string());
    }

    pub fn has(&self, f: Finding) -> bool {
        self.findings.contains(&f)
    }

    /// Serialize for a run-store checkpoint (bootstrap-probing runs
    /// must resume with the probed findings, not re-probe).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            (
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| Json::Str(format!("{f:?}")))
                        .collect(),
                ),
            ),
            (
                "digest",
                Json::Arr(self.digest.iter().map(|d| Json::Str(d.clone())).collect()),
            ),
        ])
    }

    /// Rebuild from a [`FindingsDoc::to_json`] checkpoint entry.
    pub fn from_json(v: &crate::util::json::Json) -> Result<FindingsDoc, String> {
        let mut doc = FindingsDoc::default();
        for f in v
            .get("findings")
            .and_then(|x| x.as_arr())
            .ok_or("findings doc: missing findings")?
        {
            let name = f.as_str().ok_or("findings doc: non-string finding")?;
            doc.findings.push(match name {
                "MfmaSemantics" => Finding::MfmaSemantics,
                "LdsRepurposeTrick" => Finding::LdsRepurposeTrick,
                "SwizzleLayouts" => Finding::SwizzleLayouts,
                other => return Err(format!("findings doc: unknown finding '{other}'")),
            });
        }
        for d in v
            .get("digest")
            .and_then(|x| x.as_arr())
            .ok_or("findings doc: missing digest")?
        {
            doc.digest
                .push(d.as_str().ok_or("findings doc: non-string digest")?.to_string());
        }
        Ok(doc)
    }
}

/// One optimization avenue — a digested, directed piece of knowledge
/// the designer can turn into an experiment. Names mirror App. A.2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Avenue {
    MatrixCoreAdoption,
    PrecisionFp16Library,
    LdsStagingAdoption,
    DoubleBuffering,
    LdsConflictPadding,
    XorSwizzleLayout,
    WiderVectorLoads,
    IncreaseOccupancy,
    CooperativeStore,
    TileSizeTuning,
    ScaleCacheLds,
    AsyncScaleRepurpose,
    KLoopUnrolling,
    RegisterPressureRelief,
    GridMappingSwizzle,
    KInnermostFix,
    AccumulatorInRegs,
}

impl Avenue {
    pub const ALL: [Avenue; 17] = [
        Avenue::MatrixCoreAdoption,
        Avenue::PrecisionFp16Library,
        Avenue::LdsStagingAdoption,
        Avenue::DoubleBuffering,
        Avenue::LdsConflictPadding,
        Avenue::XorSwizzleLayout,
        Avenue::WiderVectorLoads,
        Avenue::IncreaseOccupancy,
        Avenue::CooperativeStore,
        Avenue::TileSizeTuning,
        Avenue::ScaleCacheLds,
        Avenue::AsyncScaleRepurpose,
        Avenue::KLoopUnrolling,
        Avenue::RegisterPressureRelief,
        Avenue::GridMappingSwizzle,
        Avenue::KInnermostFix,
        Avenue::AccumulatorInRegs,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Avenue::MatrixCoreAdoption => "Adopt AMD Matrix Cores (MFMA fp8 path)",
            Avenue::PrecisionFp16Library => "Move to packed fp16 vector math",
            Avenue::LdsStagingAdoption => "Stage A/B tiles through LDS",
            Avenue::DoubleBuffering => "Ping-pong LDS double buffering",
            Avenue::LdsConflictPadding => "LDS bank conflict mitigation via row padding",
            Avenue::XorSwizzleLayout => "Optimized LDS layout for rocWMMA (XOR swizzle)",
            Avenue::WiderVectorLoads => "Wider vectorized global loads",
            Avenue::IncreaseOccupancy => "Increase thread block occupancy",
            Avenue::CooperativeStore => "Cooperative store to global C",
            Avenue::TileSizeTuning => "Fine-tune tile sizes (TB_M, TB_N, TB_K)",
            Avenue::ScaleCacheLds => "Optimize scale application loop (LDS cache)",
            Avenue::AsyncScaleRepurpose => "Asynchronous scale loading via LDS re-purposing",
            Avenue::KLoopUnrolling => "Unroll the k inner loop",
            Avenue::RegisterPressureRelief => "Register pressure management",
            Avenue::GridMappingSwizzle => "L2-friendly grid tile swizzling",
            Avenue::KInnermostFix => "Restructure loop nest (k innermost)",
            Avenue::AccumulatorInRegs => "Keep the accumulator in registers",
        }
    }

    /// Digested prior: expected gain range in percent (what the LLM
    /// writes as `performance: [lo, hi]` in App. A.2).
    pub fn prior_gain(&self) -> (f64, f64) {
        match self {
            Avenue::MatrixCoreAdoption => (100.0, 400.0),
            Avenue::PrecisionFp16Library => (40.0, 120.0),
            Avenue::LdsStagingAdoption => (30.0, 120.0),
            Avenue::DoubleBuffering => (10.0, 40.0),
            Avenue::LdsConflictPadding => (15.0, 40.0), // A.2 experiment 1
            Avenue::XorSwizzleLayout => (10.0, 35.0),
            Avenue::WiderVectorLoads => (5.0, 25.0),
            Avenue::IncreaseOccupancy => (5.0, 30.0),
            Avenue::CooperativeStore => (5.0, 15.0), // A.2 experiment 2
            Avenue::TileSizeTuning => (-10.0, 35.0),
            Avenue::ScaleCacheLds => (3.0, 12.0),
            Avenue::AsyncScaleRepurpose => (5.0, 20.0),
            Avenue::KLoopUnrolling => (5.0, 20.0),
            Avenue::RegisterPressureRelief => (0.0, 15.0),
            Avenue::GridMappingSwizzle => (3.0, 18.0),
            Avenue::KInnermostFix => (20.0, 60.0),
            Avenue::AccumulatorInRegs => (30.0, 90.0),
        }
    }

    /// Innovation score prior (App. A.2's `innovation:` field).
    pub fn innovation(&self) -> u8 {
        match self {
            Avenue::MatrixCoreAdoption => 95,
            Avenue::PrecisionFp16Library => 55,
            Avenue::LdsStagingAdoption => 50,
            Avenue::DoubleBuffering => 55,
            Avenue::LdsConflictPadding => 85, // A.2 experiment 1
            Avenue::XorSwizzleLayout => 70,
            Avenue::WiderVectorLoads => 40,
            Avenue::IncreaseOccupancy => 45,
            Avenue::CooperativeStore => 60, // A.2 experiment 2
            Avenue::TileSizeTuning => 30,
            Avenue::ScaleCacheLds => 35,
            Avenue::AsyncScaleRepurpose => 80,
            Avenue::KLoopUnrolling => 25,
            Avenue::RegisterPressureRelief => 45,
            Avenue::GridMappingSwizzle => 65,
            Avenue::KInnermostFix => 35,
            Avenue::AccumulatorInRegs => 40,
        }
    }

    /// Which classified bottlenecks this avenue attacks (DESIGN.md
    /// §11). The profile-guided designer grants a bounded prior bonus
    /// to avenues matching the base kernel's classified bottleneck;
    /// the mapping is digested knowledge, same standing as
    /// [`Avenue::prior_gain`].
    pub fn attacks(&self) -> &'static [Bottleneck] {
        use Bottleneck as B;
        match self {
            // compute-pipe avenues: faster math per element
            Avenue::MatrixCoreAdoption => &[B::Compute],
            Avenue::PrecisionFp16Library => &[B::Compute],
            Avenue::KLoopUnrolling => &[B::Compute],
            // traffic avenues: fewer / wider / better-staged global
            // accesses (writeback counts as memory)
            Avenue::LdsStagingAdoption => &[B::Memory],
            Avenue::DoubleBuffering => &[B::Memory],
            Avenue::WiderVectorLoads => &[B::Memory],
            Avenue::CooperativeStore => &[B::Memory],
            Avenue::ScaleCacheLds => &[B::Memory],
            Avenue::AsyncScaleRepurpose => &[B::Memory],
            Avenue::KInnermostFix => &[B::Memory],
            Avenue::GridMappingSwizzle => &[B::Memory, B::Occupancy],
            // LDS-stall avenues: bank-conflict mitigation
            Avenue::LdsConflictPadding => &[B::Lds],
            Avenue::XorSwizzleLayout => &[B::Lds],
            Avenue::AccumulatorInRegs => &[B::Compute, B::Lds],
            // occupancy / shape avenues
            Avenue::IncreaseOccupancy => &[B::Occupancy],
            Avenue::RegisterPressureRelief => &[B::Compute, B::Occupancy],
            Avenue::TileSizeTuning => &[B::Memory, B::Occupancy, B::Launch],
        }
    }

    /// Which finding (if any) must exist before this avenue can be
    /// proposed — the bootstrap gating of §4.1/§4.3.
    pub fn requires_finding(&self) -> Option<Finding> {
        match self {
            Avenue::MatrixCoreAdoption => Some(Finding::MfmaSemantics),
            Avenue::AsyncScaleRepurpose => Some(Finding::LdsRepurposeTrick),
            Avenue::XorSwizzleLayout => Some(Finding::SwizzleLayouts),
            _ => None,
        }
    }

    /// Is the avenue applicable to (would change) this genome?
    pub fn applicable(&self, g: &KernelGenome) -> bool {
        match self {
            Avenue::MatrixCoreAdoption => g.compute != ComputePath::Mfma,
            Avenue::PrecisionFp16Library => {
                g.precision == Precision::Fp32 && g.compute != ComputePath::Mfma
            }
            Avenue::LdsStagingAdoption => !g.lds_staging,
            Avenue::DoubleBuffering => g.lds_staging && !g.double_buffer,
            Avenue::LdsConflictPadding => {
                g.lds_staging && g.lds_pad == 0 && g.swizzle == Swizzle::None
            }
            Avenue::XorSwizzleLayout => g.lds_staging && g.swizzle == Swizzle::None,
            Avenue::WiderVectorLoads => g.vector_width < 16,
            Avenue::IncreaseOccupancy => g.waves_per_block < 8,
            Avenue::CooperativeStore => {
                g.writeback == Writeback::SingleWave && g.waves_per_block > 1
            }
            Avenue::TileSizeTuning => true,
            Avenue::ScaleCacheLds => {
                g.lds_staging && g.scale_cache == ScaleCache::GlobalReload
            }
            Avenue::AsyncScaleRepurpose => {
                g.lds_staging && g.scale_cache != ScaleCache::LdsRepurposed
            }
            Avenue::KLoopUnrolling => g.unroll_k < 8,
            Avenue::RegisterPressureRelief => g.vgprs_per_lane() > 256,
            Avenue::GridMappingSwizzle => g.grid_mapping != GridMapping::TileSwizzled,
            Avenue::KInnermostFix => !g.k_innermost,
            Avenue::AccumulatorInRegs => !g.acc_in_regs,
        }
    }

    /// Instantiate the avenue as a concrete rubric (edit list) for a
    /// base genome. Randomness covers free parameters (which tile to
    /// grow, how much padding, ...).
    pub fn instantiate(&self, g: &KernelGenome, rng: &mut Rng) -> Vec<GenomeEdit> {
        match self {
            Avenue::MatrixCoreAdoption => vec![
                GenomeEdit::SetCompute(ComputePath::Mfma),
                GenomeEdit::SetPrecision(Precision::Fp8),
                GenomeEdit::SetLdsStaging(true),
            ],
            Avenue::PrecisionFp16Library => vec![
                GenomeEdit::SetPrecision(Precision::Fp16),
                GenomeEdit::SetCompute(ComputePath::Vectorized),
            ],
            Avenue::LdsStagingAdoption => vec![GenomeEdit::SetLdsStaging(true)],
            Avenue::DoubleBuffering => vec![GenomeEdit::SetDoubleBuffer(true)],
            Avenue::LdsConflictPadding => {
                let pad = *rng.choose(&[1u32, 2, 4]);
                vec![GenomeEdit::SetLdsPad(pad)]
            }
            Avenue::XorSwizzleLayout => vec![
                GenomeEdit::SetLdsPad(0),
                GenomeEdit::SetSwizzle(Swizzle::Xor),
            ],
            Avenue::WiderVectorLoads => {
                let next = match g.vector_width {
                    1 => 4,
                    2 => 8,
                    4 => 16,
                    _ => 16,
                };
                vec![GenomeEdit::SetVectorWidth(next)]
            }
            Avenue::IncreaseOccupancy => {
                let next = (g.waves_per_block * 2).min(8);
                vec![GenomeEdit::SetWavesPerBlock(next)]
            }
            Avenue::CooperativeStore => {
                vec![GenomeEdit::SetWriteback(Writeback::Cooperative)]
            }
            Avenue::TileSizeTuning => {
                let axis = rng.below(3);
                let scale_up = rng.chance(0.6);
                let next = |v: u32| -> u32 {
                    if scale_up {
                        (v * 2).min(256)
                    } else {
                        (v / 2).max(16)
                    }
                };
                match axis {
                    0 => vec![GenomeEdit::SetBlockM(next(g.block_m))],
                    1 => vec![GenomeEdit::SetBlockN(next(g.block_n))],
                    _ => vec![GenomeEdit::SetBlockK(next(g.block_k))],
                }
            }
            Avenue::ScaleCacheLds => vec![GenomeEdit::SetScaleCache(ScaleCache::Lds)],
            Avenue::AsyncScaleRepurpose => {
                vec![GenomeEdit::SetScaleCache(ScaleCache::LdsRepurposed)]
            }
            Avenue::KLoopUnrolling => {
                let next = (g.unroll_k * 2).min(8);
                vec![GenomeEdit::SetUnrollK(next)]
            }
            Avenue::RegisterPressureRelief => {
                if g.unroll_k > 1 && rng.chance(0.5) {
                    vec![GenomeEdit::SetUnrollK(g.unroll_k / 2)]
                } else if g.block_m >= g.block_n {
                    vec![GenomeEdit::SetBlockM((g.block_m / 2).max(16))]
                } else {
                    vec![GenomeEdit::SetBlockN((g.block_n / 2).max(16))]
                }
            }
            Avenue::GridMappingSwizzle => {
                vec![GenomeEdit::SetGridMapping(GridMapping::TileSwizzled)]
            }
            Avenue::KInnermostFix => vec![GenomeEdit::SetKInnermost(true)],
            Avenue::AccumulatorInRegs => vec![GenomeEdit::SetAccInRegs(true)],
        }
    }
}

/// Which slice of the avenue library the designer may draw on — the
/// knowledge ablation axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnowledgeProfile {
    /// Everything: bootstrap findings + digested external documents.
    Full,
    /// Only generic GPU lore (no MI300-specific digests: no MFMA
    /// adoption, no scale re-purposing, no rocWMMA swizzle layouts).
    GenericOnly,
    /// Tile-size tuning only (the OpenTuner-style hyper-parameter view).
    Minimal,
}

/// The knowledge base handed to the Experiment Designer.
#[derive(Debug, Clone)]
pub struct KnowledgeBase {
    pub profile: KnowledgeProfile,
    pub findings: FindingsDoc,
}

impl KnowledgeBase {
    pub fn full() -> Self {
        KnowledgeBase {
            profile: KnowledgeProfile::Full,
            findings: FindingsDoc::bootstrap(),
        }
    }

    pub fn with_profile(profile: KnowledgeProfile) -> Self {
        let findings = match profile {
            KnowledgeProfile::Full => FindingsDoc::bootstrap(),
            // generic/minimal profiles never ran the bootstrap probes
            _ => FindingsDoc::default(),
        };
        KnowledgeBase { profile, findings }
    }

    /// Avenues available to the designer for a given base genome.
    pub fn available_avenues(&self, g: &KernelGenome) -> Vec<Avenue> {
        Avenue::ALL
            .iter()
            .copied()
            .filter(|a| match self.profile {
                KnowledgeProfile::Full => true,
                KnowledgeProfile::GenericOnly => !matches!(
                    a,
                    Avenue::MatrixCoreAdoption
                        | Avenue::AsyncScaleRepurpose
                        | Avenue::XorSwizzleLayout
                ),
                KnowledgeProfile::Minimal => matches!(
                    a,
                    Avenue::TileSizeTuning
                        | Avenue::KLoopUnrolling
                        | Avenue::IncreaseOccupancy
                ),
            })
            .filter(|a| {
                a.requires_finding()
                    .map(|f| self.findings.has(f))
                    .unwrap_or(true)
            })
            .filter(|a| a.applicable(g))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;

    #[test]
    fn bootstrap_findings_present() {
        let doc = FindingsDoc::bootstrap();
        assert!(doc.has(Finding::MfmaSemantics));
        assert!(doc.has(Finding::LdsRepurposeTrick));
        assert_eq!(doc.digest.len(), 3);
    }

    #[test]
    fn findings_doc_json_roundtrip() {
        let doc = FindingsDoc::bootstrap();
        let back = FindingsDoc::from_json(
            &crate::util::json::parse(&doc.to_json().to_string()).unwrap(),
        )
        .unwrap();
        for f in [
            Finding::MfmaSemantics,
            Finding::LdsRepurposeTrick,
            Finding::SwizzleLayouts,
        ] {
            assert_eq!(back.has(f), doc.has(f));
        }
        assert_eq!(back.digest, doc.digest);
        // an empty doc (no-bootstrap run) round-trips too
        let empty = FindingsDoc::default();
        let back = FindingsDoc::from_json(
            &crate::util::json::parse(&empty.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert!(!back.has(Finding::MfmaSemantics));
        assert!(back.digest.is_empty());
    }

    #[test]
    fn paper_experiment_priors_match_a2() {
        // App. A.2: padding experiment performance [15, 40], innovation 85;
        // cooperative store [5, 15], innovation 60.
        assert_eq!(Avenue::LdsConflictPadding.prior_gain(), (15.0, 40.0));
        assert_eq!(Avenue::LdsConflictPadding.innovation(), 85);
        assert_eq!(Avenue::CooperativeStore.prior_gain(), (5.0, 15.0));
        assert_eq!(Avenue::CooperativeStore.innovation(), 60);
    }

    #[test]
    fn naive_genome_has_rich_avenue_set() {
        let kb = KnowledgeBase::full();
        let avenues = kb.available_avenues(&seeds::naive_hip());
        assert!(avenues.len() >= 6, "got {avenues:?}");
        assert!(avenues.contains(&Avenue::MatrixCoreAdoption));
        assert!(avenues.contains(&Avenue::LdsStagingAdoption));
        // staging-dependent avenues are not applicable yet
        assert!(!avenues.contains(&Avenue::DoubleBuffering));
    }

    #[test]
    fn oracle_genome_mostly_exhausted() {
        let kb = KnowledgeBase::full();
        let avenues = kb.available_avenues(&seeds::human_oracle());
        // the tuned kernel only has generic tuning left
        assert!(!avenues.contains(&Avenue::MatrixCoreAdoption));
        assert!(!avenues.contains(&Avenue::CooperativeStore));
        assert!(avenues.contains(&Avenue::TileSizeTuning));
    }

    #[test]
    fn generic_profile_blocks_mfma() {
        let kb = KnowledgeBase::with_profile(KnowledgeProfile::GenericOnly);
        let avenues = kb.available_avenues(&seeds::naive_hip());
        assert!(!avenues.contains(&Avenue::MatrixCoreAdoption));
        assert!(avenues.contains(&Avenue::LdsStagingAdoption));
    }

    #[test]
    fn minimal_profile_is_tuner_like() {
        let kb = KnowledgeBase::with_profile(KnowledgeProfile::Minimal);
        let avenues = kb.available_avenues(&seeds::mfma_seed());
        for a in &avenues {
            assert!(matches!(
                a,
                Avenue::TileSizeTuning | Avenue::KLoopUnrolling | Avenue::IncreaseOccupancy
            ));
        }
    }

    #[test]
    fn instantiations_change_the_genome() {
        let kb = KnowledgeBase::full();
        let g = seeds::mfma_seed();
        let mut rng = Rng::seed_from_u64(3);
        for a in kb.available_avenues(&g) {
            let edits = a.instantiate(&g, &mut rng);
            assert!(!edits.is_empty(), "{a:?} produced no edits");
            let child = crate::genome::edit::apply_edits(&g, &edits);
            assert_ne!(child, g, "{a:?} was a no-op");
        }
    }

    #[test]
    fn every_avenue_attacks_some_bottleneck() {
        for a in Avenue::ALL {
            let attacked = a.attacks();
            assert!(!attacked.is_empty(), "{a:?} attacks nothing");
            // no duplicates — a matching avenue gets one bonus, not N
            let mut seen = Vec::new();
            for b in attacked {
                assert!(!seen.contains(b), "{a:?} lists {b:?} twice");
                seen.push(*b);
            }
        }
        // every bottleneck class has at least one attacker, so a
        // guided designer always has somewhere to steer
        for b in Bottleneck::ALL {
            assert!(
                Avenue::ALL.iter().any(|a| a.attacks().contains(&b)),
                "no avenue attacks {b:?}"
            );
        }
    }

    #[test]
    fn finding_gate_blocks_ungated_probe() {
        let mut kb = KnowledgeBase::full();
        kb.findings = FindingsDoc::default(); // wipe the bootstrap
        let avenues = kb.available_avenues(&seeds::naive_hip());
        assert!(!avenues.contains(&Avenue::MatrixCoreAdoption));
    }
}
