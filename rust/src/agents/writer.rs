//! Stage 3 — the LLM Kernel Writer (paper §3.3, App. A.3).
//!
//! "This stage lies at the heart of the GPU Kernel Scientist process."
//! Given the Base code, the Reference code, and an experiment rubric,
//! produce a new kernel plus a short self-report of which techniques
//! were actually used — the paper notes the LLM "occasionally ...
//! decided against actually following through with the whole
//! experiment rubric", which we model as per-line infidelity.
//!
//! The surrogate writer:
//! 1. applies each rubric edit (dropping lines with probability
//!    `rubric_infidelity`, recorded in the report);
//! 2. occasionally grafts one axis from the Reference (the paper
//!    frames the LLM as a crossover operator over Base + Reference);
//! 3. runs a *compile-repair loop*: the paper's writer almost always
//!    produced code that compiles ("known-working code consistently
//!    being present by construction"), so hard-invalid children are
//!    repaired by targeted fixes, each recorded. Semantic hazards
//!    (races) are NOT repaired — the writer cannot see them, only the
//!    evaluation platform can (§3.4).

use super::designer::ExperimentPlan;
use super::llm::SurrogateLlm;
use crate::genome::{
    edit::{apply_edits, GenomeEdit},
    render, Invalid, KernelGenome,
};

/// The writer's output: a kernel plus its self-report.
#[derive(Debug, Clone)]
pub struct KernelWrite {
    pub genome: KernelGenome,
    /// Rubric lines actually implemented.
    pub applied: Vec<String>,
    /// Rubric lines the writer decided against (infidelity).
    pub skipped: Vec<String>,
    /// Compile-repair actions taken.
    pub repairs: Vec<String>,
    /// Free-text report (goes into the one-step experiment analysis).
    pub report: String,
    /// Base -> child diff of the rendered kernel sketch.
    pub diff: String,
}

/// Stage-3 agent.
#[derive(Debug, Clone, Default)]
pub struct Writer {
    /// Probability of grafting one axis from the Reference kernel.
    pub crossover_rate: f64,
}

impl Writer {
    pub fn new() -> Self {
        Writer {
            crossover_rate: 0.15,
        }
    }

    /// Produce the child kernel for one experiment.
    pub fn write(
        &self,
        base: &KernelGenome,
        reference: &KernelGenome,
        plan: &ExperimentPlan,
        llm: &mut SurrogateLlm,
    ) -> KernelWrite {
        let mut applied = Vec::new();
        let mut skipped = Vec::new();
        let mut kept_edits: Vec<GenomeEdit> = Vec::new();
        for edit in &plan.rubric {
            if llm.drops_rubric_line() {
                skipped.push(edit.describe());
            } else {
                applied.push(edit.describe());
                kept_edits.push(edit.clone());
            }
        }
        let mut child = apply_edits(base, &kept_edits);

        // occasional crossover from the in-context Reference listing
        if llm.rng().chance(self.crossover_rate) {
            let grafted = graft_axis(&mut child, reference, llm);
            if let Some(desc) = grafted {
                applied.push(format!("adopted from reference: {desc}"));
            }
        }

        // compile-repair loop
        let mut repairs = Vec::new();
        for _ in 0..8 {
            match child.validate() {
                Ok(()) => break,
                Err(inv) => {
                    let fix = repair_for(&inv, &child);
                    match fix {
                        Some((edit, why)) => {
                            edit.apply(&mut child);
                            repairs.push(why);
                        }
                        None => break,
                    }
                }
            }
        }

        let report = render_report(plan, &applied, &skipped, &repairs);
        let diff = render::diff_sketches(base, &child);
        KernelWrite {
            genome: child,
            applied,
            skipped,
            repairs,
            report,
            diff,
        }
    }
}

/// Graft one structural axis from the reference into the child
/// (crossover), returning a description if something changed.
fn graft_axis(
    child: &mut KernelGenome,
    reference: &KernelGenome,
    llm: &mut SurrogateLlm,
) -> Option<String> {
    let choices: Vec<(&str, GenomeEdit)> = vec![
        ("tile shape", GenomeEdit::SetBlockM(reference.block_m)),
        ("tile shape", GenomeEdit::SetBlockN(reference.block_n)),
        ("k depth", GenomeEdit::SetBlockK(reference.block_k)),
        ("vector width", GenomeEdit::SetVectorWidth(reference.vector_width)),
        ("wave count", GenomeEdit::SetWavesPerBlock(reference.waves_per_block)),
        ("unroll", GenomeEdit::SetUnrollK(reference.unroll_k)),
        ("grid mapping", GenomeEdit::SetGridMapping(reference.grid_mapping)),
    ];
    let idx = llm.rng().below(choices.len());
    let (what, edit) = &choices[idx];
    if edit.is_noop(child) {
        return None;
    }
    edit.apply(child);
    Some(format!("{what} ({})", edit.describe()))
}

/// Targeted fix for a hard-invalid child, mirroring what a competent
/// code-writer does when the compiler rejects a configuration.
fn repair_for(inv: &Invalid, g: &KernelGenome) -> Option<(GenomeEdit, String)> {
    match inv {
        Invalid::DoubleBufferWithoutStaging => Some((
            GenomeEdit::SetLdsStaging(true),
            "enabled LDS staging (double buffering requires it)".into(),
        )),
        Invalid::ScaleLdsWithoutStaging => Some((
            GenomeEdit::SetLdsStaging(true),
            "enabled LDS staging (LDS scale cache requires it)".into(),
        )),
        Invalid::SwizzleWithPadding => Some((
            GenomeEdit::SetLdsPad(0),
            "dropped row padding (conflicts with XOR swizzle)".into(),
        )),
        Invalid::MfmaRequiresLowPrecision => Some((
            GenomeEdit::SetPrecision(crate::genome::Precision::Fp8),
            "switched operands to fp8 (MFMA requires low precision)".into(),
        )),
        Invalid::LdsOverflow { .. } => {
            // shrink the deepest LDS consumer
            if g.block_k > 16 {
                Some((
                    GenomeEdit::SetBlockK(g.block_k / 2),
                    format!("halved TB_K to {} (LDS overflow)", g.block_k / 2),
                ))
            } else if g.double_buffer {
                Some((
                    GenomeEdit::SetDoubleBuffer(false),
                    "dropped double buffering (LDS overflow)".into(),
                ))
            } else if g.block_m >= g.block_n && g.block_m > 16 {
                Some((
                    GenomeEdit::SetBlockM(g.block_m / 2),
                    format!("halved TB_M to {} (LDS overflow)", g.block_m / 2),
                ))
            } else if g.block_n > 16 {
                Some((
                    GenomeEdit::SetBlockN(g.block_n / 2),
                    format!("halved TB_N to {} (LDS overflow)", g.block_n / 2),
                ))
            } else {
                None
            }
        }
        Invalid::RegisterOverflow { .. } => {
            if g.unroll_k > 1 {
                Some((
                    GenomeEdit::SetUnrollK(g.unroll_k / 2),
                    format!("reduced unroll to {} (VGPR pressure)", g.unroll_k / 2),
                ))
            } else if g.waves_per_block < 8 {
                Some((
                    GenomeEdit::SetWavesPerBlock(g.waves_per_block * 2),
                    "spread accumulator across more waves (VGPR pressure)".into(),
                ))
            } else if g.block_m >= g.block_n && g.block_m > 16 {
                Some((
                    GenomeEdit::SetBlockM(g.block_m / 2),
                    format!("halved TB_M to {} (VGPR pressure)", g.block_m / 2),
                ))
            } else if g.block_n > 16 {
                Some((
                    GenomeEdit::SetBlockN(g.block_n / 2),
                    format!("halved TB_N to {} (VGPR pressure)", g.block_n / 2),
                ))
            } else {
                None
            }
        }
        Invalid::NonPow2Block(dim, _) | Invalid::BlockOutOfRange(dim, _) => {
            let edit = match *dim {
                "m" => GenomeEdit::SetBlockM(64),
                "n" => GenomeEdit::SetBlockN(64),
                _ => GenomeEdit::SetBlockK(64),
            };
            Some((edit, format!("reset block_{dim} to 64 (invalid size)")))
        }
        Invalid::BadUnroll(_) => Some((
            GenomeEdit::SetUnrollK(2),
            "reset unroll to 2 (invalid factor)".into(),
        )),
        Invalid::BadVectorWidth(_) => Some((
            GenomeEdit::SetVectorWidth(4),
            "reset vector width to 4 (invalid width)".into(),
        )),
        Invalid::BadWaves(_) | Invalid::TooManyLanes(_) => Some((
            GenomeEdit::SetWavesPerBlock(4),
            "reset waves/block to 4 (invalid launch shape)".into(),
        )),
    }
}

fn render_report(
    plan: &ExperimentPlan,
    applied: &[String],
    skipped: &[String],
    repairs: &[String],
) -> String {
    let mut s = format!("Experiment: {}\nTechniques applied:\n", plan.description);
    for a in applied {
        s.push_str(&format!("  - {a}\n"));
    }
    if !skipped.is_empty() {
        s.push_str("Rubric lines NOT implemented (writer judgement):\n");
        for k in skipped {
            s.push_str(&format!("  - {k}\n"));
        }
    }
    if !repairs.is_empty() {
        s.push_str("Compile repairs:\n");
        for r in repairs {
            s.push_str(&format!("  - {r}\n"));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::designer::ExperimentPlan;
    use crate::agents::knowledge::Avenue;
    use crate::agents::llm::{LlmConfig, SurrogateLlm};
    use crate::genome::{seeds, ComputePath, Precision};

    fn plan(rubric: Vec<GenomeEdit>) -> ExperimentPlan {
        ExperimentPlan {
            avenue: Avenue::TileSizeTuning,
            description: "test experiment".into(),
            rubric_text: rubric.iter().map(|e| e.describe()).collect(),
            rubric,
            performance: (5.0, 15.0),
            innovation: 50,
        }
    }

    fn faithful_llm() -> SurrogateLlm {
        SurrogateLlm::new(
            1,
            LlmConfig {
                rubric_infidelity: 0.0,
                temperature: 0.0,
                ..Default::default()
            },
        )
    }

    #[test]
    fn applies_rubric_faithfully_at_zero_infidelity() {
        let w = Writer {
            crossover_rate: 0.0,
        };
        let base = seeds::mfma_seed();
        let p = plan(vec![GenomeEdit::SetBlockM(64), GenomeEdit::SetUnrollK(4)]);
        let out = w.write(&base, &seeds::naive_hip(), &p, &mut faithful_llm());
        assert_eq!(out.genome.block_m, 64);
        assert_eq!(out.genome.unroll_k, 4);
        assert_eq!(out.applied.len(), 2);
        assert!(out.skipped.is_empty());
        assert!(out.report.contains("Techniques applied"));
        assert!(out.diff.contains("TB_M"));
    }

    #[test]
    fn infidelity_skips_lines_and_reports_them() {
        let w = Writer {
            crossover_rate: 0.0,
        };
        let base = seeds::mfma_seed();
        let p = plan(vec![GenomeEdit::SetBlockM(64)]);
        let mut llm = SurrogateLlm::new(
            3,
            LlmConfig {
                rubric_infidelity: 1.0,
                ..Default::default()
            },
        );
        let out = w.write(&base, &seeds::naive_hip(), &p, &mut llm);
        assert_eq!(out.genome, base, "nothing applied");
        assert_eq!(out.skipped.len(), 1);
        assert!(out.report.contains("NOT implemented"));
    }

    #[test]
    fn repairs_double_buffer_without_staging() {
        let w = Writer {
            crossover_rate: 0.0,
        };
        let base = seeds::naive_hip(); // no staging
        let p = plan(vec![GenomeEdit::SetDoubleBuffer(true)]);
        let out = w.write(&base, &seeds::naive_hip(), &p, &mut faithful_llm());
        assert!(out.genome.validate().is_ok());
        assert!(out.genome.lds_staging, "repair enabled staging");
        assert!(!out.repairs.is_empty());
        assert!(out.report.contains("Compile repairs"));
    }

    #[test]
    fn repairs_lds_overflow_by_shrinking() {
        let w = Writer {
            crossover_rate: 0.0,
        };
        let base = seeds::human_oracle();
        // grow k to 256: oracle 256x128 tiles fp8 double-buffered would
        // need (256*256 + 256*128)*2 = 160 KiB LDS -> overflow
        let p = plan(vec![GenomeEdit::SetBlockK(256)]);
        let out = w.write(&base, &base, &p, &mut faithful_llm());
        assert!(out.genome.validate().is_ok(), "{:?}", out.genome.validate());
        assert!(!out.repairs.is_empty());
    }

    #[test]
    fn repairs_mfma_precision() {
        let w = Writer {
            crossover_rate: 0.0,
        };
        let base = seeds::naive_hip();
        let p = plan(vec![GenomeEdit::SetCompute(ComputePath::Mfma)]);
        let out = w.write(&base, &base, &p, &mut faithful_llm());
        assert!(out.genome.validate().is_ok());
        assert_eq!(out.genome.precision, Precision::Fp8);
    }

    #[test]
    fn hazards_are_not_repaired() {
        // writer happily produces a racy kernel; only the platform
        // will catch it (the paper's black-box constraint)
        let w = Writer {
            crossover_rate: 0.0,
        };
        let mut base = seeds::mfma_seed();
        base.waves_per_block = 4;
        base.acc_in_regs = false;
        let p = plan(vec![GenomeEdit::SetWriteback(
            crate::genome::Writeback::Cooperative,
        )]);
        let out = w.write(&base, &base, &p, &mut faithful_llm());
        assert!(out.genome.validate().is_ok());
        assert!(out.genome.correctness_hazard().is_some());
    }

    #[test]
    fn crossover_grafts_reference_axis() {
        let w = Writer {
            crossover_rate: 1.0,
        };
        let base = seeds::mfma_seed();
        let reference = seeds::human_oracle();
        let mut llm = faithful_llm();
        let mut grafted_any = false;
        for _ in 0..20 {
            let out = w.write(&base, &reference, &plan(vec![]), &mut llm);
            if out.applied.iter().any(|a| a.contains("adopted from reference")) {
                grafted_any = true;
                assert_ne!(out.genome, base);
                break;
            }
        }
        assert!(grafted_any);
    }

    #[test]
    fn writes_are_deterministic_per_seed() {
        let w = Writer::new();
        let base = seeds::mfma_seed();
        let p = plan(vec![GenomeEdit::SetBlockN(64)]);
        let a = w.write(&base, &seeds::human_oracle(), &p, &mut SurrogateLlm::with_seed(42));
        let b = w.write(&base, &seeds::human_oracle(), &p, &mut SurrogateLlm::with_seed(42));
        assert_eq!(a.genome, b.genome);
        assert_eq!(a.report, b.report);
    }
}
