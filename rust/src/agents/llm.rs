//! The LLM boundary: what a real frontier-model integration would
//! implement, and the seeded surrogate that implements it here.
//!
//! The paper drives all three stages with Gemini 2.5 Pro/Flash. No LLM
//! API is available in this reproduction environment, so the agents
//! are *surrogates*: knowledge-driven stochastic models that produce
//! the same structured artifacts (selection rationales, avenue lists,
//! experiment plans with `performance: [lo, hi]` / `innovation:`
//! estimates, kernel diffs, self-reports) through the same interfaces.
//! The substitution argument is in DESIGN.md §2; the knobs below model
//! the LLM-ness that matters to the *loop*:
//!
//! * `temperature` — decision stochasticity (sampling instead of
//!   argmax in the selector/designer).
//! * `estimate_sigma` — how noisy the designer's gain predictions are
//!   relative to the avenue priors (LLMs "believe they can estimate
//!   likely performance gains", App. A.2 — imperfectly).
//! * `rubric_infidelity` — probability the writer quietly drops a
//!   rubric line ("it was occasionally observed that the LLM decided
//!   against actually following through with the whole experiment
//!   rubric", §3.3).

use crate::rng::Rng;

/// Generation knobs for the surrogate (see module docs).
#[derive(Debug, Clone)]
pub struct LlmConfig {
    pub temperature: f64,
    pub estimate_sigma: f64,
    pub rubric_infidelity: f64,
}

impl Default for LlmConfig {
    fn default() -> Self {
        LlmConfig {
            temperature: 0.7,
            estimate_sigma: 0.25,
            rubric_infidelity: 0.08,
        }
    }
}

/// The surrogate "model": a seeded sampler shared by the three agents.
/// A real integration would swap this for API calls while keeping the
/// agent interfaces identical.
#[derive(Debug, Clone)]
pub struct SurrogateLlm {
    pub config: LlmConfig,
    rng: Rng,
}

impl SurrogateLlm {
    pub fn new(seed: u64, config: LlmConfig) -> Self {
        SurrogateLlm {
            config,
            rng: Rng::seed_from_u64(seed ^ 0x11a_facade),
        }
    }

    pub fn with_seed(seed: u64) -> Self {
        SurrogateLlm::new(seed, LlmConfig::default())
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Snapshot the sampler stream for a run-store checkpoint.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the sampler stream from a checkpoint snapshot, so the
    /// resumed agents continue the exact decision sequence.
    pub fn restore_rng(&mut self, state: [u64; 4]) {
        self.rng = Rng::from_state(state);
    }

    /// Temperature-weighted choice over scored items (higher score =
    /// more likely). At temperature 0 this is argmax.
    ///
    /// Non-finite scores (a NaN ratio, an infinite prior) never poison
    /// the draw: they take zero weight in the softmax and lose every
    /// argmax comparison. If *no* score is finite the choice degrades
    /// deterministically to the first item — the sampled path still
    /// consumes its one RNG draw so the decision stream stays aligned
    /// with a finite-score call sequence.
    pub fn sample_weighted<T>(&mut self, items: &[(T, f64)]) -> usize
    where
        T: Clone,
    {
        assert!(!items.is_empty());
        if self.config.temperature <= 1e-9 {
            return items
                .iter()
                .enumerate()
                .filter(|(_, (_, s))| s.is_finite())
                .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
                .map(|(i, _)| i)
                .unwrap_or(0);
        }
        // softmax over score / temperature, max-folded over the finite
        // scores only (folding past a NaN would NaN the whole fold)
        let t = self.config.temperature;
        let max = items
            .iter()
            .map(|(_, s)| *s)
            .filter(|s| s.is_finite())
            .fold(f64::NEG_INFINITY, f64::max);
        if !max.is_finite() {
            let _ = self.rng.f64();
            return 0;
        }
        let weights: Vec<f64> = items
            .iter()
            .map(|(_, s)| {
                if s.is_finite() {
                    ((s - max) / t).exp()
                } else {
                    0.0
                }
            })
            .collect();
        let total: f64 = weights.iter().sum();
        let mut draw = self.rng.f64() * total;
        let mut last_weighted = 0;
        for (i, w) in weights.iter().enumerate() {
            if *w <= 0.0 {
                continue;
            }
            last_weighted = i;
            draw -= w;
            if draw <= 0.0 {
                return i;
            }
        }
        // explicit fallthrough: the draw outran the re-summed total by
        // rounding — the last item that held any weight takes it
        last_weighted
    }

    /// Perturb a prior gain estimate the way an LLM's stated range
    /// wobbles around its prior knowledge.
    pub fn perturb_gain(&mut self, (lo, hi): (f64, f64)) -> (f64, f64) {
        let s = self.config.estimate_sigma;
        let f_lo = self.rng.lognormal_factor(s);
        let f_hi = self.rng.lognormal_factor(s);
        let a = lo * f_lo;
        let b = (hi * f_hi).max(a + 1.0);
        // round to integers — the paper's outputs are integer percents
        (a.round(), b.round())
    }

    /// Perturb an innovation score by a few points.
    pub fn perturb_innovation(&mut self, base: u8) -> u8 {
        let delta = (self.rng.normal() * 5.0).round() as i32;
        (base as i32 + delta).clamp(0, 100) as u8
    }

    /// Whether the writer drops this rubric line (infidelity event).
    pub fn drops_rubric_line(&mut self) -> bool {
        self.rng.chance(self.config.rubric_infidelity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_temperature_is_argmax() {
        let mut llm = SurrogateLlm::new(
            1,
            LlmConfig {
                temperature: 0.0,
                ..Default::default()
            },
        );
        let items = vec![("a", 0.1), ("b", 0.9), ("c", 0.5)];
        for _ in 0..10 {
            assert_eq!(llm.sample_weighted(&items), 1);
        }
    }

    #[test]
    fn high_temperature_explores() {
        let mut llm = SurrogateLlm::new(
            2,
            LlmConfig {
                temperature: 5.0,
                ..Default::default()
            },
        );
        let items = vec![("a", 0.1), ("b", 0.9), ("c", 0.5)];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[llm.sample_weighted(&items)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all options sampled at high T");
    }

    #[test]
    fn perturbed_gain_stays_ordered() {
        let mut llm = SurrogateLlm::with_seed(3);
        for _ in 0..100 {
            let (lo, hi) = llm.perturb_gain((15.0, 40.0));
            assert!(hi > lo, "({lo}, {hi})");
        }
    }

    #[test]
    fn innovation_clamped() {
        let mut llm = SurrogateLlm::with_seed(4);
        for _ in 0..100 {
            let i = llm.perturb_innovation(98);
            assert!(i <= 100);
        }
    }

    #[test]
    fn infidelity_rate_roughly_matches() {
        let mut llm = SurrogateLlm::new(
            5,
            LlmConfig {
                rubric_infidelity: 0.2,
                ..Default::default()
            },
        );
        let drops = (0..10_000).filter(|_| llm.drops_rubric_line()).count();
        let rate = drops as f64 / 10_000.0;
        assert!((rate - 0.2).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn seeded_determinism() {
        let mut a = SurrogateLlm::with_seed(9);
        let mut b = SurrogateLlm::with_seed(9);
        let items = vec![("x", 1.0), ("y", 2.0)];
        for _ in 0..50 {
            assert_eq!(a.sample_weighted(&items), b.sample_weighted(&items));
        }
    }

    #[test]
    fn sample_weighted_survives_nan_scores() {
        let mut llm = SurrogateLlm::with_seed(10);
        let items = vec![
            ("nan", f64::NAN),
            ("ok", 1.0),
            ("inf", f64::INFINITY),
            ("also_ok", 1.2),
            ("neg_inf", f64::NEG_INFINITY),
        ];
        for _ in 0..50 {
            let i = llm.sample_weighted(&items);
            assert!(
                i == 1 || i == 3,
                "non-finite item {i} drawn — poisoned softmax"
            );
        }
    }

    #[test]
    fn sample_weighted_all_nan_degrades_deterministically() {
        let mut a = SurrogateLlm::with_seed(11);
        let mut b = SurrogateLlm::with_seed(11);
        let poisoned = vec![("x", f64::NAN), ("y", f64::NAN)];
        let clean = vec![("x", 1.0), ("y", 2.0)];
        assert_eq!(a.sample_weighted(&poisoned), 0, "all-NaN falls to item 0");
        // stream parity: the degraded call burned exactly one draw,
        // same as a healthy sampled call would have
        let _ = b.sample_weighted(&clean);
        assert_eq!(a.rng_state(), b.rng_state(), "degraded call desynced the stream");
    }

    #[test]
    fn sample_weighted_single_item_and_all_equal() {
        let mut llm = SurrogateLlm::with_seed(12);
        let one = vec![("only", 7.0)];
        for _ in 0..10 {
            assert_eq!(llm.sample_weighted(&one), 0);
        }
        let equal = vec![("a", 3.0), ("b", 3.0), ("c", 3.0)];
        let mut counts = [0usize; 3];
        for _ in 0..300 {
            counts[llm.sample_weighted(&equal)] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            assert!(*c > 50, "item {i} drawn only {c}/300 under equal scores");
        }
    }

    #[test]
    fn zero_temperature_argmax_ignores_nan() {
        let mut llm = SurrogateLlm::new(
            13,
            LlmConfig {
                temperature: 0.0,
                ..Default::default()
            },
        );
        let items = vec![("nan", f64::NAN), ("best", 0.9), ("inf", f64::INFINITY)];
        assert_eq!(llm.sample_weighted(&items), 1);
        let hopeless = vec![("nan", f64::NAN), ("also_nan", f64::NAN)];
        assert_eq!(llm.sample_weighted(&hopeless), 0);
    }
}
