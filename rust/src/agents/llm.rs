//! The LLM boundary: what a real frontier-model integration would
//! implement, and the seeded surrogate that implements it here.
//!
//! The paper drives all three stages with Gemini 2.5 Pro/Flash. No LLM
//! API is available in this reproduction environment, so the agents
//! are *surrogates*: knowledge-driven stochastic models that produce
//! the same structured artifacts (selection rationales, avenue lists,
//! experiment plans with `performance: [lo, hi]` / `innovation:`
//! estimates, kernel diffs, self-reports) through the same interfaces.
//! The substitution argument is in DESIGN.md §2; the knobs below model
//! the LLM-ness that matters to the *loop*:
//!
//! * `temperature` — decision stochasticity (sampling instead of
//!   argmax in the selector/designer).
//! * `estimate_sigma` — how noisy the designer's gain predictions are
//!   relative to the avenue priors (LLMs "believe they can estimate
//!   likely performance gains", App. A.2 — imperfectly).
//! * `rubric_infidelity` — probability the writer quietly drops a
//!   rubric line ("it was occasionally observed that the LLM decided
//!   against actually following through with the whole experiment
//!   rubric", §3.3).

use crate::rng::Rng;

/// Generation knobs for the surrogate (see module docs).
#[derive(Debug, Clone)]
pub struct LlmConfig {
    pub temperature: f64,
    pub estimate_sigma: f64,
    pub rubric_infidelity: f64,
}

impl Default for LlmConfig {
    fn default() -> Self {
        LlmConfig {
            temperature: 0.7,
            estimate_sigma: 0.25,
            rubric_infidelity: 0.08,
        }
    }
}

/// The surrogate "model": a seeded sampler shared by the three agents.
/// A real integration would swap this for API calls while keeping the
/// agent interfaces identical.
#[derive(Debug, Clone)]
pub struct SurrogateLlm {
    pub config: LlmConfig,
    rng: Rng,
}

impl SurrogateLlm {
    pub fn new(seed: u64, config: LlmConfig) -> Self {
        SurrogateLlm {
            config,
            rng: Rng::seed_from_u64(seed ^ 0x11a_facade),
        }
    }

    pub fn with_seed(seed: u64) -> Self {
        SurrogateLlm::new(seed, LlmConfig::default())
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Snapshot the sampler stream for a run-store checkpoint.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the sampler stream from a checkpoint snapshot, so the
    /// resumed agents continue the exact decision sequence.
    pub fn restore_rng(&mut self, state: [u64; 4]) {
        self.rng = Rng::from_state(state);
    }

    /// Temperature-weighted choice over scored items (higher score =
    /// more likely). At temperature 0 this is argmax.
    pub fn sample_weighted<T>(&mut self, items: &[(T, f64)]) -> usize
    where
        T: Clone,
    {
        assert!(!items.is_empty());
        if self.config.temperature <= 1e-9 {
            return items
                .iter()
                .enumerate()
                .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
                .map(|(i, _)| i)
                .unwrap();
        }
        // softmax over score / temperature
        let t = self.config.temperature;
        let max = items.iter().map(|(_, s)| *s).fold(f64::MIN, f64::max);
        let weights: Vec<f64> = items.iter().map(|(_, s)| ((s - max) / t).exp()).collect();
        let total: f64 = weights.iter().sum();
        let mut draw = self.rng.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            draw -= w;
            if draw <= 0.0 {
                return i;
            }
        }
        items.len() - 1
    }

    /// Perturb a prior gain estimate the way an LLM's stated range
    /// wobbles around its prior knowledge.
    pub fn perturb_gain(&mut self, (lo, hi): (f64, f64)) -> (f64, f64) {
        let s = self.config.estimate_sigma;
        let f_lo = self.rng.lognormal_factor(s);
        let f_hi = self.rng.lognormal_factor(s);
        let a = lo * f_lo;
        let b = (hi * f_hi).max(a + 1.0);
        // round to integers — the paper's outputs are integer percents
        (a.round(), b.round())
    }

    /// Perturb an innovation score by a few points.
    pub fn perturb_innovation(&mut self, base: u8) -> u8 {
        let delta = (self.rng.normal() * 5.0).round() as i32;
        (base as i32 + delta).clamp(0, 100) as u8
    }

    /// Whether the writer drops this rubric line (infidelity event).
    pub fn drops_rubric_line(&mut self) -> bool {
        self.rng.chance(self.config.rubric_infidelity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_temperature_is_argmax() {
        let mut llm = SurrogateLlm::new(
            1,
            LlmConfig {
                temperature: 0.0,
                ..Default::default()
            },
        );
        let items = vec![("a", 0.1), ("b", 0.9), ("c", 0.5)];
        for _ in 0..10 {
            assert_eq!(llm.sample_weighted(&items), 1);
        }
    }

    #[test]
    fn high_temperature_explores() {
        let mut llm = SurrogateLlm::new(
            2,
            LlmConfig {
                temperature: 5.0,
                ..Default::default()
            },
        );
        let items = vec![("a", 0.1), ("b", 0.9), ("c", 0.5)];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[llm.sample_weighted(&items)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all options sampled at high T");
    }

    #[test]
    fn perturbed_gain_stays_ordered() {
        let mut llm = SurrogateLlm::with_seed(3);
        for _ in 0..100 {
            let (lo, hi) = llm.perturb_gain((15.0, 40.0));
            assert!(hi > lo, "({lo}, {hi})");
        }
    }

    #[test]
    fn innovation_clamped() {
        let mut llm = SurrogateLlm::with_seed(4);
        for _ in 0..100 {
            let i = llm.perturb_innovation(98);
            assert!(i <= 100);
        }
    }

    #[test]
    fn infidelity_rate_roughly_matches() {
        let mut llm = SurrogateLlm::new(
            5,
            LlmConfig {
                rubric_infidelity: 0.2,
                ..Default::default()
            },
        );
        let drops = (0..10_000).filter(|_| llm.drops_rubric_line()).count();
        let rate = drops as f64 / 10_000.0;
        assert!((rate - 0.2).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn seeded_determinism() {
        let mut a = SurrogateLlm::with_seed(9);
        let mut b = SurrogateLlm::with_seed(9);
        let items = vec![("x", 1.0), ("y", 2.0)];
        for _ in 0..50 {
            assert_eq!(a.sample_weighted(&items), b.sample_weighted(&items));
        }
    }
}
