//! The paper's workload: the AMD Developer Challenge 2025 fp8
//! block-scaled GEMM (MI300 target).
//!
//! This is the original single-benchmark reproduction moved behind the
//! [`Workload`] trait. Everything delegates to the pre-registry code
//! paths — `sim::estimate`, `genome::seeds`, `BenchmarkSuite::feedback/
//! leaderboard`, `TolerancePolicy::default` — so timings, verifier
//! verdicts, and therefore whole scientist trajectories are
//! bit-identical to the pre-refactor system (locked in by
//! `tests/determinism.rs` and the unit tests below).

use super::{BenchmarkSuite, GemmConfig, Workload};
use crate::eval::verifier::TolerancePolicy;
use crate::genome::{seeds, Invalid, KernelGenome};
use crate::gpu::GpuArch;
use crate::sim::KernelTiming;

/// The fp8 block-scaled GEMM competition task.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fp8Gemm;

impl Workload for Fp8Gemm {
    fn name(&self) -> &'static str {
        "fp8-gemm"
    }

    fn description(&self) -> &'static str {
        "AMD-competition fp8 block-scaled GEMM (the paper's task): 6-config feedback, 18-size leaderboard"
    }

    fn feedback_suite(&self) -> BenchmarkSuite {
        BenchmarkSuite::feedback()
    }

    fn leaderboard_suite(&self) -> BenchmarkSuite {
        BenchmarkSuite::leaderboard()
    }

    fn starting_population(&self) -> Vec<(&'static str, KernelGenome)> {
        seeds::starting_population()
    }

    fn reference_genome(&self) -> KernelGenome {
        seeds::pytorch_reference()
    }

    fn tolerance(&self) -> TolerancePolicy {
        TolerancePolicy::default()
    }

    fn estimate(
        &self,
        arch: &GpuArch,
        g: &KernelGenome,
        cfg: &GemmConfig,
    ) -> Result<KernelTiming, Invalid> {
        crate::sim::estimate(arch, g, cfg)
    }

    fn flops(&self, cfg: &GemmConfig) -> f64 {
        cfg.flops()
    }

    fn min_hbm_bytes(&self, cfg: &GemmConfig) -> f64 {
        // fp8 operands (1 B) + per-row/col f32 scales + bf16 output
        cfg.operand_bytes(1) + (cfg.m + cfg.n) as f64 * 4.0 + cfg.output_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::MI300;
    use crate::workload::{FEEDBACK_CONFIGS, LEADERBOARD_SIZES};

    #[test]
    fn suites_are_the_paper_constants() {
        let w = Fp8Gemm;
        assert_eq!(w.feedback_suite().configs, FEEDBACK_CONFIGS.to_vec());
        assert_eq!(w.leaderboard_suite().configs, LEADERBOARD_SIZES.to_vec());
    }

    #[test]
    fn estimate_is_bit_identical_to_the_legacy_path() {
        // the bit-identity anchor: the trait hook must be the exact
        // same function the pre-registry simulator called
        let w = Fp8Gemm;
        for (_, g) in seeds::all_seeds() {
            for cfg in FEEDBACK_CONFIGS {
                assert_eq!(
                    w.estimate(&MI300, &g, &cfg),
                    crate::sim::estimate(&MI300, &g, &cfg)
                );
            }
        }
    }

    #[test]
    fn tolerance_matches_the_default_policy() {
        let w = Fp8Gemm;
        let d = TolerancePolicy::default();
        for cfg in FEEDBACK_CONFIGS {
            assert_eq!(w.tolerance().rtol(&cfg), d.rtol(&cfg));
        }
    }

    #[test]
    fn admits_every_valid_genome() {
        // the competition accepts any compiling HIP kernel; the family
        // gate must not reject anything validate() admits
        for (_, g) in seeds::all_seeds() {
            assert!(Fp8Gemm.admits(&g).is_ok());
        }
    }

    #[test]
    fn roofline_hooks_positive() {
        let cfg = GemmConfig::new(6144, 512, 4096);
        assert!(Fp8Gemm.flops(&cfg) > 0.0);
        assert!(Fp8Gemm.min_hbm_bytes(&cfg) > 0.0);
    }
}
