//! The fused row-softmax/reduction family: numerically-stable softmax
//! over the rows of a large bf16 matrix (attention logits, LM heads).
//!
//! This family exercises the bandwidth-bound side of the MI300 model in
//! `gpu/`: at ~5 flops and ~4 bytes per element the arithmetic
//! intensity sits far below the machine balance, so the winning moves
//! are the memory ones — fusing the three passes (max, sum, normalize)
//! into one online-softmax pass via LDS row staging, widening global
//! loads, and keeping enough waves resident to hide HBM latency. The
//! compute pipes, tile alignment, and scale-cache axes that dominate
//! the GEMM families are deliberately near-neutral here.
//!
//! **Shape convention:** a problem is (rows, cols); [`GemmConfig`]
//! carries it as `m = rows`, `k = n = cols`. Mirroring the column count
//! into `k` keeps reduction-depth semantics (verifier tolerances grow
//! with `k`) meaningful for this family.
//!
//! **Genome interpretation:** `block_m` = rows per workgroup, `block_n`
//! = column chunk per workgroup (chunks of one row are combined through
//! an online-softmax partial pass, costed below), `lds_staging` = the
//! fused single-pass kernel vs. the 3-pass naive structure,
//! `vector_width`/`waves_per_block`/`block_k` keep their hardware
//! meanings (coalescing, latency hiding, LDS row pitch).

use super::{BenchmarkSuite, GemmConfig, Workload};
use crate::eval::verifier::TolerancePolicy;
use crate::genome::{
    seeds, ComputePath, GridMapping, Invalid, KernelGenome, Precision, ScaleCache, Swizzle,
    Writeback,
};
use crate::gpu::{lds, memory, occupancy, GpuArch};
use crate::sim::KernelTiming;

/// The 10 leaderboard shapes (rows × cols geomean basis).
pub const LEADERBOARD_SIZES: [GemmConfig; 10] = [
    GemmConfig::new(1024, 4096, 4096),
    GemmConfig::new(2048, 4096, 4096),
    GemmConfig::new(4096, 4096, 4096),
    GemmConfig::new(8192, 4096, 4096),
    GemmConfig::new(4096, 8192, 8192),
    GemmConfig::new(8192, 8192, 8192),
    GemmConfig::new(4096, 16384, 16384),
    GemmConfig::new(8192, 16384, 16384),
    GemmConfig::new(16384, 8192, 8192),
    GemmConfig::new(8192, 32768, 32768),
];

/// The 6 per-submission feedback shapes (a leaderboard subset spanning
/// the row count and reduction depth).
pub const FEEDBACK_CONFIGS: [GemmConfig; 6] = [
    GemmConfig::new(2048, 4096, 4096),
    GemmConfig::new(8192, 4096, 4096),
    GemmConfig::new(4096, 8192, 8192),
    GemmConfig::new(8192, 16384, 16384),
    GemmConfig::new(16384, 8192, 8192),
    GemmConfig::new(8192, 32768, 32768),
];

/// The library baseline: a competent vectorized fused softmax (what a
/// `torch.softmax` dispatch reaches).
pub fn library_seed() -> KernelGenome {
    KernelGenome {
        block_m: 64,
        block_n: 64,
        block_k: 32,
        compute: ComputePath::Vectorized,
        precision: Precision::Fp16,
        unroll_k: 2,
        lds_staging: true,
        double_buffer: false,
        lds_pad: 4,
        swizzle: Swizzle::None,
        vector_width: 8,
        waves_per_block: 4,
        writeback: Writeback::Cooperative,
        scale_cache: ScaleCache::GlobalReload,
        grid_mapping: GridMapping::RowMajor,
        acc_in_regs: true,
        k_innermost: true,
        isa_scheduling: false,
    }
}

/// The naive translation: scalar f32 math, three separate passes over
/// the matrix (row max, exp-sum, normalize), element-wise loads — the
/// canonical naive-HIP genome, narrowed to 1-byte-per-lane loads.
pub fn naive_seed() -> KernelGenome {
    KernelGenome {
        vector_width: 1,
        ..seeds::naive_hip()
    }
}

/// The first working fused kernel: online softmax with LDS row staging
/// but narrow loads and low occupancy — the loop's starting point.
pub fn fused_seed() -> KernelGenome {
    KernelGenome {
        block_m: 32,
        block_n: 64,
        block_k: 32,
        vector_width: 4,
        waves_per_block: 2,
        unroll_k: 1,
        lds_pad: 0,
        ..library_seed()
    }
}

/// The fused row-softmax workload.
#[derive(Debug, Clone, Copy, Default)]
pub struct RowSoftmax;

impl Workload for RowSoftmax {
    fn name(&self) -> &'static str {
        "row-softmax"
    }

    fn description(&self) -> &'static str {
        "fused row-softmax/reduction family (bandwidth-bound, bf16 in/out): 6-config feedback, 10-size leaderboard"
    }

    fn feedback_suite(&self) -> BenchmarkSuite {
        BenchmarkSuite {
            name: "softmax-feedback-6".into(),
            configs: FEEDBACK_CONFIGS.to_vec(),
        }
    }

    fn leaderboard_suite(&self) -> BenchmarkSuite {
        BenchmarkSuite {
            name: "softmax-leaderboard-10".into(),
            configs: LEADERBOARD_SIZES.to_vec(),
        }
    }

    fn starting_population(&self) -> Vec<(&'static str, KernelGenome)> {
        vec![
            ("torch-softmax", library_seed()),
            ("naive-softmax", naive_seed()),
            ("fused-softmax-seed", fused_seed()),
        ]
    }

    fn reference_genome(&self) -> KernelGenome {
        library_seed()
    }

    fn tolerance(&self) -> TolerancePolicy {
        // exp-sum accumulation is well conditioned (all terms positive);
        // the bf16 output quantum dominates
        TolerancePolicy {
            base_rtol: 1.0 / 256.0,
            accum_rtol_per_sqrt_k: 5e-5,
        }
    }

    fn admits(&self, g: &KernelGenome) -> Result<(), String> {
        if g.precision == Precision::Fp8 {
            return Err(
                "task operands are bf16 logits; kernel declares fp8 inputs that do not exist"
                    .into(),
            );
        }
        Ok(())
    }

    fn estimate(
        &self,
        arch: &GpuArch,
        g: &KernelGenome,
        cfg: &GemmConfig,
    ) -> Result<KernelTiming, Invalid> {
        estimate(arch, g, cfg)
    }

    fn flops(&self, cfg: &GemmConfig) -> f64 {
        // max-reduce + subtract + exp + sum-reduce + divide, per element
        5.0 * cfg.m as f64 * cfg.n as f64
    }

    fn min_hbm_bytes(&self, cfg: &GemmConfig) -> f64 {
        // one bf16 read + one bf16 write per element
        cfg.m as f64 * cfg.n as f64 * 4.0
    }
}

/// Deterministic noiseless estimate for a softmax genome on a
/// (rows, cols) config. Structure mirrors `sim::estimate_gemm` but with
/// the memory system as the first-class term:
///
/// ```text
/// t_compute = 5·m·n / (vector-pipe peak × issue_eff(occupancy))
/// t_exec    = t_compute × (1 + lds_pressure)
/// t_mem     = (cold read + re-read passes + partial-combine traffic)
///             / bandwidth / (coalesce × hide)
/// t_main    = overlap(t_exec, t_mem)   (staging decides the fusion)
/// total     = (t_main + t_writeback) / grid_util + launch
/// ```
pub fn estimate(
    arch: &GpuArch,
    g: &KernelGenome,
    cfg: &GemmConfig,
) -> Result<KernelTiming, Invalid> {
    g.validate()?;
    let occ = occupancy::occupancy(arch, g);
    let issue = occupancy::compute_issue_efficiency(&occ);
    let hide = occupancy::memory_latency_efficiency(&occ);
    let (m, n) = (cfg.m as f64, cfg.n as f64);
    let elems = m * n;

    // --- compute (vector/scalar pipes only: exp, max, sum) ---
    let vector_peak = match g.precision {
        Precision::Fp32 => arch.vector_fp32_tflops,
        _ => arch.vector_fp32_tflops * 1.3,
    };
    let raw_peak = match g.compute {
        ComputePath::Scalar => arch.scalar_tflops,
        ComputePath::Vectorized => vector_peak,
        // the matrix pipe has no matmul to run here: MFMA genomes fall
        // back to the vector units and pay fragment-layout shuffles to
        // get row data in and out of the matrix-core register tiling
        ComputePath::Mfma => vector_peak * 0.6,
    };
    let flops = 5.0 * elems;
    let t_compute = flops / (raw_peak * issue * 1e6);
    let lds_pressure = lds::pressure(g);
    let t_exec = t_compute * (1.0 + lds_pressure);

    // --- memory system ---
    let elt = GpuArch::operand_elt_bytes(g) as f64;
    let cold = elems * elt;
    // fused single pass with LDS row staging (online softmax); the
    // naive structure re-reads the matrix for the exp-sum and the
    // normalize passes
    let passes = if g.lds_staging { 1.0 } else { 3.0 };
    let reread = cold * (passes - 1.0);
    // re-read passes hit the infinity cache only if the matrix fits
    let matrix_mib = cold / (1024.0 * 1024.0);
    let (hbm_reread, l2_reread) = if matrix_mib <= arch.l2_mib {
        (0.0, reread)
    } else {
        (reread, 0.0)
    };
    // column-chunked rows publish one (max, sum) partial per chunk,
    // combined in a second tiny pass
    let chunks_per_row = (cfg.n / g.block_n).max(1) as f64;
    let combine = if chunks_per_row > 1.0 { m * chunks_per_row * 16.0 } else { 0.0 };
    let coal = memory::coalescing_efficiency(g.vector_width);
    let t_hbm = (cold + hbm_reread + combine) / (arch.hbm_tbps * 1e6);
    let t_l2 = l2_reread / (arch.l2_tbps * 1e6);
    let t_mem = (t_hbm + t_l2) / (coal * hide);

    // --- overlap ---
    let t_main = if g.double_buffer {
        // ping-pong row tiles: loads fully hidden behind the math
        t_exec.max(t_mem)
    } else if g.lds_staging {
        // per-tile barrier between load and reduce phases
        t_exec.max(t_mem) + 0.15 * t_exec.min(t_mem)
    } else {
        t_exec.max(t_mem)
    };

    let t_write = memory::writeback_us(g, cfg, arch);

    // --- grid ---
    let wgs = (cfg.m as u64 / g.block_m as u64).max(1)
        * (cfg.n as u64 / g.block_n as u64).max(1);
    let util = occupancy::grid_utilization(arch, &occ, wgs);
    let t_launch = arch.launch_overhead_us + wgs as f64 / arch.dispatch_rate_per_us / 1e3;

    let total = (t_main + t_write) / util + t_launch;
    // ideal: the best vector-pipe rate the machine offers this task
    let ideal = flops / (arch.vector_fp32_tflops * 1.3 * 1e6);
    Ok(KernelTiming {
        compute_us: t_compute,
        lds_pressure,
        mem_us: t_mem,
        writeback_us: t_write,
        launch_us: t_launch,
        total_us: total,
        compute_efficiency: (ideal / total).min(1.0),
        occupancy_waves: occ.waves_per_cu,
        grid_utilization: util,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::MI300;

    const CFG: GemmConfig = GemmConfig::new(8192, 16384, 16384);

    #[test]
    fn shape_convention_mirrors_cols_into_k() {
        for c in LEADERBOARD_SIZES {
            assert_eq!(c.k, c.n, "{c}: k must mirror the column count");
        }
        for c in FEEDBACK_CONFIGS {
            assert!(LEADERBOARD_SIZES.contains(&c), "{c} not on leaderboard");
        }
    }

    #[test]
    fn family_is_memory_bound() {
        // the whole point of the family: the memory term dominates the
        // compute term for every seed on every feedback shape
        for (name, g) in RowSoftmax.starting_population() {
            for cfg in FEEDBACK_CONFIGS {
                let t = estimate(&MI300, &g, &cfg).unwrap();
                assert!(
                    t.mem_us > t.compute_us,
                    "{name} on {cfg}: mem {} <= compute {}",
                    t.mem_us,
                    t.compute_us
                );
            }
        }
    }

    #[test]
    fn fusion_beats_three_passes() {
        let fused = library_seed();
        let three_pass = KernelGenome {
            lds_staging: false,
            double_buffer: false,
            ..fused.clone()
        };
        let t_fused = estimate(&MI300, &fused, &CFG).unwrap().total_us;
        let t_three = estimate(&MI300, &three_pass, &CFG).unwrap().total_us;
        assert!(t_fused < t_three, "fused {t_fused} >= 3-pass {t_three}");
    }

    #[test]
    fn wider_loads_help() {
        let narrow = KernelGenome {
            vector_width: 1,
            ..library_seed()
        };
        let wide = KernelGenome {
            vector_width: 16,
            ..library_seed()
        };
        let t_narrow = estimate(&MI300, &narrow, &CFG).unwrap().total_us;
        let t_wide = estimate(&MI300, &wide, &CFG).unwrap().total_us;
        assert!(t_wide < t_narrow);
    }

    #[test]
    fn mfma_gains_nothing_over_vectorized() {
        // no matmul to feed the matrix pipe: the Mfma path must not be
        // modeled faster than the plain vector path
        let vec = library_seed();
        let mfma = KernelGenome {
            compute: ComputePath::Mfma,
            ..vec.clone()
        };
        let t_vec = estimate(&MI300, &vec, &CFG).unwrap().total_us;
        let t_mfma = estimate(&MI300, &mfma, &CFG).unwrap().total_us;
        assert!(t_mfma >= t_vec * 0.999);
    }

    #[test]
    fn family_gate_rejects_fp8() {
        assert!(RowSoftmax.admits(&library_seed()).is_ok());
        let fp8 = KernelGenome {
            precision: Precision::Fp8,
            ..library_seed()
        };
        assert!(RowSoftmax.admits(&fp8).is_err());
    }

    #[test]
    fn estimate_is_pure_and_positive() {
        for (_, g) in RowSoftmax.starting_population() {
            for cfg in LEADERBOARD_SIZES {
                let a = estimate(&MI300, &g, &cfg).unwrap();
                assert_eq!(a, estimate(&MI300, &g, &cfg).unwrap());
                assert!(a.total_us > 0.0 && a.total_us.is_finite());
                assert!(a.grid_utilization > 0.0 && a.grid_utilization <= 1.0);
            }
        }
    }

    #[test]
    fn seed_has_headroom_toward_the_roofline() {
        // the evolution target: the fused seed must sit above the
        // family's bandwidth bound with realistic room to close
        let t = estimate(&MI300, &fused_seed(), &CFG).unwrap().total_us;
        let bound = RowSoftmax.min_hbm_bytes(&CFG) / (MI300.hbm_tbps * 1e6);
        assert!(t > bound, "seed {t} us at/below the roofline {bound} us");
        assert!(t < bound * 10.0, "seed implausibly far from the roofline");
    }
}
