//! The bf16 inference GEMM family: plain (unscaled) bf16 GEMMs at
//! LLM-serving shapes.
//!
//! Same tiled-GEMM physics as the paper's competition task — the
//! workload shares `sim::estimate_gemm` — but with the fp8 task's
//! per-row/col dequant-scale traffic switched off (a bf16 GEMM has no
//! block scales) and a family gate rejecting fp8 genomes (the task's
//! operands are bf16 tensors; there are no fp8 inputs to load, so an
//! fp8 kernel cannot compile against the task's signature).
//!
//! Shapes are decode/prefill GEMMs of a ~7B-parameter transformer:
//! m = tokens in flight, k/n = hidden / FFN dims (4096, 8192, 14336).

use super::{BenchmarkSuite, GemmConfig, Workload};
use crate::eval::verifier::TolerancePolicy;
use crate::genome::{seeds, Invalid, KernelGenome, Precision, ScaleCache};
use crate::gpu::GpuArch;
use crate::sim::KernelTiming;

/// The 12 leaderboard shapes (geomean basis).
pub const LEADERBOARD_SIZES: [GemmConfig; 12] = [
    GemmConfig::new(512, 4096, 4096),
    GemmConfig::new(512, 4096, 14336),
    GemmConfig::new(512, 14336, 4096),
    GemmConfig::new(1024, 4096, 4096),
    GemmConfig::new(1024, 4096, 14336),
    GemmConfig::new(1024, 14336, 4096),
    GemmConfig::new(2048, 4096, 4096),
    GemmConfig::new(2048, 4096, 14336),
    GemmConfig::new(2048, 14336, 4096),
    GemmConfig::new(4096, 4096, 4096),
    GemmConfig::new(8192, 4096, 4096),
    GemmConfig::new(2048, 8192, 8192),
];

/// The 6 per-submission feedback shapes (a leaderboard subset spanning
/// the m range and both FFN directions).
pub const FEEDBACK_CONFIGS: [GemmConfig; 6] = [
    GemmConfig::new(512, 4096, 4096),
    GemmConfig::new(512, 4096, 14336),
    GemmConfig::new(1024, 14336, 4096),
    GemmConfig::new(2048, 4096, 14336),
    GemmConfig::new(4096, 4096, 4096),
    GemmConfig::new(2048, 8192, 8192),
];

/// The library baseline: a tuned vectorized bf16 GEMM (what a
/// `torch.matmul` dispatch reaches on MI300-class hardware) — the
/// canonical PyTorch-reference genome minus the fp8 task's dequant
/// scale caching (a plain bf16 GEMM has no scales to cache).
pub fn library_seed() -> KernelGenome {
    KernelGenome {
        scale_cache: ScaleCache::GlobalReload,
        ..seeds::pytorch_reference()
    }
}

/// The first working Matrix-Core kernel for the family: fp16 MFMA with
/// small tiles — functional, far from tuned (the loop's fast-path
/// starting point, mirroring the paper's bootstrap seed).
pub fn mfma_bf16_seed() -> KernelGenome {
    KernelGenome {
        precision: Precision::Fp16,
        scale_cache: ScaleCache::GlobalReload,
        ..seeds::mfma_seed()
    }
}

impl Bf16Gemm {
    fn naive_seed() -> KernelGenome {
        // the same line-by-line scalar translation the paper starts
        // from — upcast-to-f32 math, no staging
        seeds::naive_hip()
    }
}

/// The bf16 inference GEMM workload.
#[derive(Debug, Clone, Copy, Default)]
pub struct Bf16Gemm;

impl Workload for Bf16Gemm {
    fn name(&self) -> &'static str {
        "bf16-gemm"
    }

    fn description(&self) -> &'static str {
        "bf16 inference GEMM family (decode/prefill shapes, no block scales): 6-config feedback, 12-size leaderboard"
    }

    fn feedback_suite(&self) -> BenchmarkSuite {
        BenchmarkSuite {
            name: "bf16-feedback-6".into(),
            configs: FEEDBACK_CONFIGS.to_vec(),
        }
    }

    fn leaderboard_suite(&self) -> BenchmarkSuite {
        BenchmarkSuite {
            name: "bf16-leaderboard-12".into(),
            configs: LEADERBOARD_SIZES.to_vec(),
        }
    }

    fn starting_population(&self) -> Vec<(&'static str, KernelGenome)> {
        vec![
            ("bf16-library", library_seed()),
            ("naive-bf16", Self::naive_seed()),
            ("mfma-bf16-seed", mfma_bf16_seed()),
        ]
    }

    fn reference_genome(&self) -> KernelGenome {
        library_seed()
    }

    fn tolerance(&self) -> TolerancePolicy {
        // no fp8 input quantum: only the bf16 output quantum plus f32
        // reassociation over the reduction depth
        TolerancePolicy {
            base_rtol: 1.0 / 256.0,
            accum_rtol_per_sqrt_k: 1e-4,
        }
    }

    fn admits(&self, g: &KernelGenome) -> Result<(), String> {
        if g.precision == Precision::Fp8 {
            return Err(
                "task operands are bf16 tensors; kernel declares fp8 inputs that do not exist"
                    .into(),
            );
        }
        Ok(())
    }

    fn estimate(
        &self,
        arch: &GpuArch,
        g: &KernelGenome,
        cfg: &GemmConfig,
    ) -> Result<KernelTiming, Invalid> {
        crate::sim::estimate_gemm(arch, g, cfg, false)
    }

    fn flops(&self, cfg: &GemmConfig) -> f64 {
        cfg.flops()
    }

    fn min_hbm_bytes(&self, cfg: &GemmConfig) -> f64 {
        cfg.operand_bytes(2) + cfg.output_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::MI300;

    #[test]
    fn feedback_is_subset_of_leaderboard() {
        for c in FEEDBACK_CONFIGS {
            assert!(LEADERBOARD_SIZES.contains(&c), "{c} not on leaderboard");
        }
    }

    #[test]
    fn family_gate_rejects_fp8_admits_bf16() {
        let w = Bf16Gemm;
        assert!(w.admits(&library_seed()).is_ok());
        assert!(w.admits(&mfma_bf16_seed()).is_ok());
        let fp8 = seeds::mfma_seed(); // fp8 MFMA from the paper task
        assert!(w.admits(&fp8).is_err());
    }

    #[test]
    fn scales_off_never_slower_than_the_fp8_model() {
        // dropping scale traffic can only help, all else equal
        let w = Bf16Gemm;
        for cfg in FEEDBACK_CONFIGS {
            let g = library_seed();
            let ours = w.estimate(&MI300, &g, &cfg).unwrap().total_us;
            let with_scales = crate::sim::estimate(&MI300, &g, &cfg).unwrap().total_us;
            assert!(ours <= with_scales, "{cfg}");
        }
    }

    #[test]
    fn mfma_seed_has_headroom_over_naive() {
        let w = Bf16Gemm;
        for cfg in FEEDBACK_CONFIGS {
            let mfma = w.estimate(&MI300, &mfma_bf16_seed(), &cfg).unwrap().total_us;
            let naive = w.estimate(&MI300, &Bf16Gemm::naive_seed(), &cfg).unwrap().total_us;
            assert!(mfma < naive, "{cfg}: mfma {mfma} >= naive {naive}");
        }
    }

    #[test]
    fn tolerance_admits_benign_error_at_max_depth() {
        let w = Bf16Gemm;
        let p = w.tolerance();
        for cfg in FEEDBACK_CONFIGS {
            let benign =
                crate::eval::verifier::predicted_rel_error(&library_seed(), &cfg);
            assert!(benign < p.rtol(&cfg), "{cfg}");
        }
    }
}
