//! The workload registry: every optimization scenario the scientist
//! loop can run, behind one [`Workload`] trait.
//!
//! The paper's methodology is workload-agnostic — the agents see only
//! code, timings, and assimilated GPU knowledge (§3). This module makes
//! the reproduction match: a workload bundles its benchmark suites
//! (per-submission feedback + final leaderboard geomean basis), its
//! seed genomes, its verifier tolerance policy, and its analytic
//! cost-model hook, and [`registry`] exposes every registered family:
//!
//! * [`fp8_gemm`] — the paper's AMD-competition fp8 block-scaled GEMM
//!   (the original single-benchmark reproduction, timings bit-identical
//!   to the pre-registry code);
//! * [`bf16_gemm`] — a bf16 inference GEMM family (decode/prefill
//!   shapes, no block scales);
//! * [`softmax`] — a fused row-softmax/reduction family exercising the
//!   bandwidth-bound side of the MI300 model in `gpu/`.
//!
//! Problem sizes are carried by [`GemmConfig`] for every family; each
//! workload documents how it interprets the (m, k, n) fields (the
//! softmax family uses m = rows and k = n = columns, so reduction-depth
//! tolerances keep their meaning).
//!
//! The constants below are the fp8 competition's: the platform returns
//! timings for **6 specified MxKxN input configurations** per
//! submission (§3.1), while the leaderboard is the **geometric average
//! over 18 specific matrix sizes** (§4.5). The exact size list is not
//! published; we use an LLM-inference-shaped spread that includes the
//! one size the paper does name, m=6144 k=512 n=4096 (App. A.1).

pub mod bf16_gemm;
pub mod fp8_gemm;
pub mod softmax;

use std::sync::Arc;

use crate::eval::verifier::TolerancePolicy;
use crate::genome::{Invalid, KernelGenome};
use crate::gpu::GpuArch;
use crate::sim::KernelTiming;

/// One optimization scenario: benchmark suites, seed genomes, verifier
/// tolerance, and the analytic roofline/cost-model hook the simulated
/// platform times genomes with. Implementations must be cheap to
/// construct and stateless — the registry hands out fresh `Arc`s and
/// backends clone them per submission lane.
pub trait Workload: Send + Sync + std::fmt::Debug {
    /// Registry key (also the `workload = "..."` config value).
    fn name(&self) -> &'static str;

    /// One-line human description (CLI listing, reports).
    fn description(&self) -> &'static str;

    /// The per-submission feedback suite (what the platform times and
    /// the population ledger records).
    fn feedback_suite(&self) -> BenchmarkSuite;

    /// The final leaderboard suite — the geomean basis scored once,
    /// outside the submission quota.
    fn leaderboard_suite(&self) -> BenchmarkSuite;

    /// Seed genomes submitted before the loop starts, in order.
    ///
    /// **Ordering contract** (relied on by `submit_seeds`'s
    /// no-bootstrap counterfactual, the annealer/GA fallbacks, and
    /// `inspect`'s default): the library/reference baseline — the same
    /// genome [`Workload::reference_genome`] returns — is listed
    /// *first* (enforced by the registry tests), a "naive" translation
    /// seed is present, and the family's fast-path bootstrap seed
    /// (fp8's mfma-seed) is listed *last*.
    fn starting_population(&self) -> Vec<(&'static str, KernelGenome)>;

    /// The library/reference baseline genome (comparison rows).
    fn reference_genome(&self) -> KernelGenome;

    /// Verifier tolerance policy for this task's numerics.
    fn tolerance(&self) -> TolerancePolicy;

    /// Workload-specific compile gate on top of
    /// [`KernelGenome::validate`] — e.g. the bf16 family has no fp8
    /// operands to load. `Err` reads as a compile failure.
    fn admits(&self, _g: &KernelGenome) -> Result<(), String> {
        Ok(())
    }

    /// Version of this workload's analytic cost model. Part of the
    /// federation config digest (DESIGN.md §12): bump it whenever
    /// [`Workload::estimate`] changes behavior, so stale cross-run
    /// cache entries recorded under the old model stop matching instead
    /// of silently serving wrong timings.
    fn cost_model_version(&self) -> u32 {
        1
    }

    /// Noiseless analytic cost model: the simulator calls this per
    /// (genome, config) measurement.
    fn estimate(
        &self,
        arch: &GpuArch,
        g: &KernelGenome,
        cfg: &GemmConfig,
    ) -> Result<KernelTiming, Invalid>;

    /// Arithmetic work of one run (roofline accounting).
    fn flops(&self, cfg: &GemmConfig) -> f64;

    /// Minimum HBM bytes one run must move (roofline accounting).
    fn min_hbm_bytes(&self, cfg: &GemmConfig) -> f64;
}

/// Registry key of the paper's workload — the default everywhere.
pub const DEFAULT_WORKLOAD: &str = "fp8-gemm";

/// Every registered workload, in registry order (the paper's fp8 GEMM
/// first).
pub fn registry() -> Vec<Arc<dyn Workload>> {
    vec![
        Arc::new(fp8_gemm::Fp8Gemm),
        Arc::new(bf16_gemm::Bf16Gemm),
        Arc::new(softmax::RowSoftmax),
    ]
}

/// Look a workload up by registry key.
pub fn lookup(name: &str) -> Option<Arc<dyn Workload>> {
    registry().into_iter().find(|w| w.name() == name)
}

/// The default (paper fp8 GEMM) workload.
pub fn default_workload() -> Arc<dyn Workload> {
    Arc::new(fp8_gemm::Fp8Gemm)
}

/// One GEMM problem size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmConfig {
    pub m: u32,
    pub k: u32,
    pub n: u32,
}

impl GemmConfig {
    pub const fn new(m: u32, k: u32, n: u32) -> Self {
        GemmConfig { m, k, n }
    }

    /// Multiply-add count x2.
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }

    /// Operand bytes at a given element size (A + B), one pass.
    pub fn operand_bytes(&self, elt: u32) -> f64 {
        (self.m as f64 * self.k as f64 + self.k as f64 * self.n as f64) * elt as f64
    }

    /// Output bytes (bf16 C).
    pub fn output_bytes(&self) -> f64 {
        self.m as f64 * self.n as f64 * 2.0
    }
}

impl std::fmt::Display for GemmConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "m={} k={} n={}", self.m, self.k, self.n)
    }
}

/// The 18 leaderboard sizes (geomean basis, Table 1).
pub const LEADERBOARD_SIZES: [GemmConfig; 18] = [
    GemmConfig::new(4096, 512, 4096),
    GemmConfig::new(4096, 1024, 4096),
    GemmConfig::new(4096, 2048, 4096),
    GemmConfig::new(4096, 4096, 4096),
    GemmConfig::new(6144, 512, 4096), // named in paper App. A.1
    GemmConfig::new(6144, 1024, 4096),
    GemmConfig::new(6144, 2048, 6144),
    GemmConfig::new(6144, 512, 6144),
    GemmConfig::new(8192, 512, 8192),
    GemmConfig::new(8192, 1024, 8192),
    GemmConfig::new(8192, 2048, 8192),
    GemmConfig::new(8192, 4096, 8192),
    GemmConfig::new(4096, 7168, 4096),
    GemmConfig::new(6144, 7168, 6144),
    GemmConfig::new(8192, 7168, 8192),
    GemmConfig::new(4096, 512, 8192),
    GemmConfig::new(8192, 512, 4096),
    GemmConfig::new(6144, 1024, 8192),
];

/// The 6 per-submission feedback configs (a subset of the leaderboard,
/// spanning the k range and the named paper size).
pub const FEEDBACK_CONFIGS: [GemmConfig; 6] = [
    GemmConfig::new(6144, 512, 4096),
    GemmConfig::new(4096, 1024, 4096),
    GemmConfig::new(4096, 4096, 4096),
    GemmConfig::new(8192, 512, 8192),
    GemmConfig::new(8192, 1024, 8192),
    GemmConfig::new(6144, 2048, 6144),
];

/// A named set of configs — the unit the evaluation platform runs.
#[derive(Debug, Clone)]
pub struct BenchmarkSuite {
    pub name: String,
    pub configs: Vec<GemmConfig>,
}

impl BenchmarkSuite {
    /// The per-submission feedback suite (6 configs).
    pub fn feedback() -> Self {
        BenchmarkSuite {
            name: "feedback-6".into(),
            configs: FEEDBACK_CONFIGS.to_vec(),
        }
    }

    /// The final leaderboard suite (18 sizes).
    pub fn leaderboard() -> Self {
        BenchmarkSuite {
            name: "leaderboard-18".into(),
            configs: LEADERBOARD_SIZES.to_vec(),
        }
    }

    /// Small CPU-testbed suite matching the PJRT artifact catalog
    /// shapes (see `python/compile/aot.py`).
    pub fn testbed() -> Self {
        BenchmarkSuite {
            name: "testbed-pjrt".into(),
            configs: vec![
                GemmConfig::new(256, 256, 256),
                GemmConfig::new(512, 256, 256),
                GemmConfig::new(256, 512, 512),
            ],
        }
    }

    /// Synthetic sweep for ablations: a grid over (m, k, n) decades.
    pub fn synthetic_sweep(points: usize, seed: u64) -> Self {
        let mut rng = crate::rng::Rng::seed_from_u64(seed);
        let dims = [512u32, 1024, 2048, 4096, 6144, 8192];
        let configs = (0..points)
            .map(|_| {
                GemmConfig::new(
                    *rng.choose(&dims),
                    *rng.choose(&dims[..4]),
                    *rng.choose(&dims),
                )
            })
            .collect();
        BenchmarkSuite {
            name: format!("synthetic-{points}"),
            configs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaderboard_has_18_unique_sizes() {
        let mut set = std::collections::HashSet::new();
        for c in LEADERBOARD_SIZES {
            set.insert(c);
        }
        assert_eq!(set.len(), 18);
    }

    #[test]
    fn feedback_is_subset_of_leaderboard() {
        for c in FEEDBACK_CONFIGS {
            assert!(LEADERBOARD_SIZES.contains(&c), "{c} not on leaderboard");
        }
    }

    #[test]
    fn paper_named_size_present() {
        let named = GemmConfig::new(6144, 512, 4096);
        assert!(FEEDBACK_CONFIGS.contains(&named));
        assert!(LEADERBOARD_SIZES.contains(&named));
    }

    #[test]
    fn flops_math() {
        let c = GemmConfig::new(2, 3, 4);
        assert_eq!(c.flops(), 48.0);
        assert_eq!(c.operand_bytes(1), 18.0);
        assert_eq!(c.output_bytes(), 16.0);
    }

    #[test]
    fn synthetic_sweep_deterministic() {
        let a = BenchmarkSuite::synthetic_sweep(10, 7);
        let b = BenchmarkSuite::synthetic_sweep(10, 7);
        assert_eq!(a.configs, b.configs);
    }

    #[test]
    fn registry_has_at_least_three_workloads() {
        let names: Vec<&str> = registry().iter().map(|w| w.name()).collect();
        assert!(names.len() >= 3, "{names:?}");
        assert_eq!(names[0], DEFAULT_WORKLOAD, "paper workload registers first");
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate registry keys");
    }

    #[test]
    fn lookup_resolves_every_registered_name() {
        for w in registry() {
            let found = lookup(w.name()).expect("registered name must resolve");
            assert_eq!(found.name(), w.name());
        }
        assert!(lookup("no-such-workload").is_none());
        assert_eq!(default_workload().name(), DEFAULT_WORKLOAD);
    }

    #[test]
    fn every_workload_is_internally_consistent() {
        for w in registry() {
            let fb = w.feedback_suite();
            let lb = w.leaderboard_suite();
            assert!(!fb.configs.is_empty(), "{}", w.name());
            assert!(lb.configs.len() >= fb.configs.len(), "{}", w.name());
            assert!(!w.description().is_empty());
            let seeds = w.starting_population();
            assert!(seeds.len() >= 2, "{}: need seeds to evolve from", w.name());
            // the starting_population ordering contract: the library/
            // reference baseline leads, a naive translation exists
            // (the bootstrap-fast-path-last half of the contract is
            // positional and exercised by the scientist's tests)
            assert_eq!(
                seeds[0].1,
                w.reference_genome(),
                "{}: the reference baseline must be the first seed",
                w.name()
            );
            assert!(
                seeds.iter().any(|(n, _)| n.contains("naive")),
                "{}: no naive translation seed",
                w.name()
            );
            for (name, g) in &seeds {
                assert!(g.validate().is_ok(), "{}/{name}", w.name());
                assert!(w.admits(g).is_ok(), "{}/{name}", w.name());
                assert!(
                    g.correctness_hazard().is_none(),
                    "{}/{name} has a hazard",
                    w.name()
                );
            }
            assert!(w.admits(&w.reference_genome()).is_ok(), "{}", w.name());
            for cfg in &fb.configs {
                assert!(w.flops(cfg) > 0.0);
                assert!(w.min_hbm_bytes(cfg) > 0.0);
            }
        }
    }

    #[test]
    fn every_workload_times_its_seeds() {
        use crate::gpu::MI300;
        for w in registry() {
            for cfg in &w.feedback_suite().configs {
                for (name, g) in w.starting_population() {
                    let t = w
                        .estimate(&MI300, &g, cfg)
                        .unwrap_or_else(|e| panic!("{}/{name} on {cfg}: {e}", w.name()));
                    assert!(
                        t.total_us.is_finite() && t.total_us > 0.0,
                        "{}/{name} on {cfg}",
                        w.name()
                    );
                }
            }
        }
    }

    #[test]
    fn seed_orderings_favor_the_library_over_naive() {
        // every family's naive translation must be slower than its
        // library reference on every feedback config, so Table-1-style
        // orderings carry over to the new workloads
        use crate::gpu::MI300;
        for w in registry() {
            let lib = w.reference_genome();
            let naive = w
                .starting_population()
                .into_iter()
                .find(|(n, _)| n.contains("naive"))
                .map(|(_, g)| g)
                .expect("every family seeds a naive translation");
            for cfg in &w.feedback_suite().configs {
                let t_lib = w.estimate(&MI300, &lib, cfg).unwrap().total_us;
                let t_naive = w.estimate(&MI300, &naive, cfg).unwrap().total_us;
                assert!(
                    t_naive > t_lib,
                    "{} on {cfg}: naive {t_naive} <= library {t_lib}",
                    w.name()
                );
            }
        }
    }
}
