//! Lineage-tree rendering and population-diversity metrics.
//!
//! The paper's population is a growing phylogeny of kernels (Fig. 1);
//! its App.-A.1 rationales reason about divergent branches and common
//! ancestors. This module renders that phylogeny as an ASCII tree for
//! run reports and computes the diversity statistics the ablation
//! benches report (how much of the genome space a strategy actually
//! explored).

use std::collections::HashMap;

use crate::genome::{edit::Param, KernelGenome};
use crate::population::Population;

/// Render the population as an ASCII forest (seeds are roots). Members
/// are annotated with their feedback geomean (or failure kind).
pub fn render_tree(pop: &Population) -> String {
    // children indexed by base parent
    let mut children: HashMap<&str, Vec<&str>> = HashMap::new();
    let mut roots: Vec<&str> = Vec::new();
    for m in pop.members() {
        match m.parents.first() {
            Some(p) => children.entry(p.as_str()).or_default().push(&m.id),
            None => roots.push(&m.id),
        }
    }
    let mut out = String::new();
    for root in roots {
        render_node(pop, &children, root, "", true, true, &mut out);
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn render_node(
    pop: &Population,
    children: &HashMap<&str, Vec<&str>>,
    id: &str,
    prefix: &str,
    last: bool,
    is_root: bool,
    out: &mut String,
) {
    let m = match pop.by_id(id) {
        Some(m) => m,
        None => return,
    };
    let connector = if is_root {
        ""
    } else if last {
        "└── "
    } else {
        "├── "
    };
    let score = match m.score() {
        Some(s) => format!("{s:9.1} us"),
        None => match &m.outcome {
            crate::population::EvalOutcome::CompileFailure(_) => "  (compile)".into(),
            crate::population::EvalOutcome::IncorrectResult(_) => "(incorrect)".into(),
            _ => "        ?".into(),
        },
    };
    let label: String = m.experiment.chars().take(48).collect();
    out.push_str(&format!("{prefix}{connector}{id} {score}  {label}\n"));
    if let Some(kids) = children.get(id) {
        let child_prefix = if is_root {
            String::new()
        } else if last {
            format!("{prefix}    ")
        } else {
            format!("{prefix}│   ")
        };
        let n = kids.len();
        for (i, kid) in kids.iter().enumerate() {
            render_node(pop, children, kid, &child_prefix, i + 1 == n, false, out);
        }
    }
}

/// Diversity statistics over the successful members' genomes.
#[derive(Debug, Clone, PartialEq)]
pub struct DiversityStats {
    /// Distinct genome fingerprints / successful members.
    pub unique_fraction: f64,
    /// Mean pairwise Hamming distance over the 17 genome axes.
    pub mean_hamming: f64,
    /// Number of axes on which at least two distinct values appear.
    pub axes_explored: usize,
    /// Maximum root-to-leaf depth of the lineage forest.
    pub max_depth: usize,
}

fn axis_values(g: &KernelGenome) -> [String; 17] {
    [
        g.block_m.to_string(),
        g.block_n.to_string(),
        g.block_k.to_string(),
        format!("{:?}", g.compute),
        format!("{:?}", g.precision),
        g.unroll_k.to_string(),
        g.lds_staging.to_string(),
        g.double_buffer.to_string(),
        g.lds_pad.to_string(),
        format!("{:?}", g.swizzle),
        g.vector_width.to_string(),
        g.waves_per_block.to_string(),
        format!("{:?}", g.writeback),
        format!("{:?}", g.scale_cache),
        format!("{:?}", g.grid_mapping),
        g.acc_in_regs.to_string(),
        g.k_innermost.to_string(),
    ]
}

/// Compute diversity statistics.
pub fn diversity(pop: &Population) -> DiversityStats {
    let ok = pop.successful();
    if ok.is_empty() {
        return DiversityStats {
            unique_fraction: 0.0,
            mean_hamming: 0.0,
            axes_explored: 0,
            max_depth: 0,
        };
    }
    let genomes: Vec<[String; 17]> = ok.iter().map(|m| axis_values(&m.genome)).collect();
    // unique fraction (content hashes — no per-member fingerprint
    // rendering, §Perf)
    let mut fps: Vec<u64> = ok.iter().map(|m| m.genome.fingerprint_hash()).collect();
    fps.sort_unstable();
    fps.dedup();
    let unique_fraction = fps.len() as f64 / ok.len() as f64;
    // mean pairwise hamming (sampled cap to stay O(n^2) small)
    let mut total = 0.0;
    let mut pairs = 0.0;
    for i in 0..genomes.len() {
        for j in (i + 1)..genomes.len() {
            let d = genomes[i]
                .iter()
                .zip(genomes[j].iter())
                .filter(|(a, b)| a != b)
                .count();
            total += d as f64;
            pairs += 1.0;
        }
    }
    let mean_hamming = if pairs > 0.0 { total / pairs } else { 0.0 };
    // axes explored
    let mut axes_explored = 0;
    for axis in 0..Param::ALL.len() {
        let mut vals: Vec<&String> = genomes.iter().map(|g| &g[axis]).collect();
        vals.sort();
        vals.dedup();
        if vals.len() > 1 {
            axes_explored += 1;
        }
    }
    // max lineage depth
    let max_depth = pop
        .members()
        .iter()
        .map(|m| pop.ancestors(&m.id).len())
        .max()
        .unwrap_or(0);
    DiversityStats {
        unique_fraction,
        mean_hamming,
        axes_explored,
        max_depth,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::population::{EvalOutcome, Individual};
    use crate::workload::FEEDBACK_CONFIGS;

    fn ind(id: &str, parents: &[&str], g: KernelGenome, t: f64) -> Individual {
        Individual {
            id: id.into(),
            parents: parents.iter().map(|s| s.to_string()).collect(),
            genome: g,
            experiment: format!("exp {id}"),
            report: String::new(),
            outcome: EvalOutcome::Timings(vec![t; 6]),
        }
    }

    fn pop() -> Population {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        p.add(ind("00001", &[], seeds::naive_hip(), 5000.0));
        p.add(ind("00002", &["00001"], seeds::mfma_seed(), 400.0));
        p.add(ind("00003", &["00001"], seeds::pytorch_reference(), 850.0));
        p.add(ind("00004", &["00002", "00003"], seeds::paper_evolved(), 300.0));
        p
    }

    #[test]
    fn tree_renders_forest() {
        let t = render_tree(&pop());
        assert!(t.contains("00001"));
        assert!(t.contains("├── 00002") || t.contains("└── 00002"));
        assert!(t.contains("└── 00004") || t.contains("├── 00004"));
        assert!(t.contains("5000.0 us"));
    }

    #[test]
    fn tree_marks_failures() {
        let mut p = pop();
        let mut bad = ind("00005", &["00004"], seeds::mfma_seed(), 1.0);
        bad.outcome = EvalOutcome::IncorrectResult("race".into());
        p.add(bad);
        let t = render_tree(&p);
        assert!(t.contains("(incorrect)"));
    }

    #[test]
    fn diversity_on_distinct_population() {
        let d = diversity(&pop());
        assert_eq!(d.unique_fraction, 1.0);
        assert!(d.mean_hamming > 3.0, "{d:?}");
        assert!(d.axes_explored >= 6);
        assert_eq!(d.max_depth, 2); // 00004 -> 00002 -> 00001
    }

    #[test]
    fn diversity_on_clones_is_zero_hamming() {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        p.add(ind("00001", &[], seeds::mfma_seed(), 100.0));
        p.add(ind("00002", &["00001"], seeds::mfma_seed(), 100.0));
        let d = diversity(&p);
        assert_eq!(d.mean_hamming, 0.0);
        assert_eq!(d.axes_explored, 0);
        assert!(d.unique_fraction < 1.0);
    }

    #[test]
    fn empty_population_safe() {
        let p = Population::new(FEEDBACK_CONFIGS.to_vec());
        assert_eq!(diversity(&p).axes_explored, 0);
        assert_eq!(render_tree(&p), "");
    }
}
