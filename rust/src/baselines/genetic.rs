//! A classic genetic algorithm over the genome space — the "GPU Kernel
//! **Evolver**" the paper deliberately is *not* (§2: "we have a GPU
//! Kernel Scientist, rather than a GPU Kernel Evolver").
//!
//! Standard GA machinery: tournament selection, uniform crossover,
//! per-axis mutation, elitism — no knowledge base, no experiment
//! design, no rationales. Comparing it against the scientist at equal
//! submission budget quantifies what the paper's "science" layer adds
//! over plain evolution with the same operators.
//!
//! Each generation is evaluated through the platform's multi-lane
//! executor on its **completion-driven stream path**
//! ([`EvalPlatform::submit_stream_batch`]) — the same machinery the
//! scientist's pipeline scheduler uses (DESIGN.md §8) — so the GA
//! benefits from real submission lanes, persistent lane workers
//! across generations, and the eval-result cache (re-derived
//! duplicate children are free, including duplicates still in
//! flight).

use super::{workload_starts, Tuner, TunerOutcome};
use crate::eval::{BatchResult, EvalBackend, EvalPlatform};
use crate::genome::{
    edit::{crossover, GenomeEdit},
    KernelGenome,
};
use crate::metrics::{geomean, ConvergenceCurve};
use crate::rng::Rng;

/// GA hyper-parameters.
#[derive(Debug, Clone)]
pub struct GeneticAlgorithm {
    pub seed: u64,
    pub population_size: usize,
    pub tournament_k: usize,
    pub mutation_rate: f64,
    pub elitism: usize,
}

impl Default for GeneticAlgorithm {
    fn default() -> Self {
        GeneticAlgorithm {
            seed: 0,
            population_size: 12,
            tournament_k: 3,
            mutation_rate: 0.25,
            elitism: 2,
        }
    }
}

#[derive(Clone)]
struct Scored {
    genome: KernelGenome,
    /// Lower is better; failures get +inf.
    score: f64,
}

/// Fold one batch of executor results into (curve, best) and return
/// the scored generation members, preserving the per-submission curve
/// semantics via each result's log index (cache hits update `best`
/// but, having consumed no submission, add no curve point).
fn fold_batch(
    genomes: &[KernelGenome],
    results: &[BatchResult],
    curve: &mut ConvergenceCurve,
    best: &mut Option<(f64, KernelGenome)>,
) -> Vec<Scored> {
    let mut scored = Vec::with_capacity(results.len());
    for (g, r) in genomes.iter().zip(results) {
        let s = match r.outcome.timings() {
            Some(ts) => geomean(ts),
            None => f64::INFINITY,
        };
        if let Some(index) = r.submission_index {
            let at = (index + 1) as usize;
            if s.is_finite() {
                curve.record(at, s);
            } else if let Some(b) = curve.best() {
                curve.record(at, b);
            }
        }
        if s.is_finite() && best.as_ref().map(|(b, _)| s < *b).unwrap_or(true) {
            *best = Some((s, g.clone()));
        }
        scored.push(Scored {
            genome: g.clone(),
            score: s,
        });
    }
    scored
}

/// Plan-time budget guard: cached children are free, uncached ones
/// reserve one submission each. Returns whether the child fits.
fn plan_room<B: EvalBackend>(
    platform: &EvalPlatform<B>,
    budget: u64,
    planned: &mut u64,
    g: &KernelGenome,
) -> bool {
    if platform.cached_outcome(g).is_some() {
        return true;
    }
    let remaining = budget.saturating_sub(platform.submissions());
    if *planned >= remaining {
        return false;
    }
    *planned += 1;
    true
}

impl GeneticAlgorithm {
    fn tournament<'a>(&self, pop: &'a [Scored], rng: &mut Rng) -> &'a Scored {
        let mut best: Option<&Scored> = None;
        for _ in 0..self.tournament_k {
            let c = &pop[rng.below(pop.len())];
            if best.map(|b| c.score < b.score).unwrap_or(true) {
                best = Some(c);
            }
        }
        best.unwrap()
    }

    fn mutate(&self, g: &mut KernelGenome, rng: &mut Rng) {
        while rng.chance(self.mutation_rate) {
            GenomeEdit::random(rng).apply(g);
        }
    }
}

impl Tuner for GeneticAlgorithm {
    fn name(&self) -> &'static str {
        "genetic-algorithm"
    }

    fn run<B: EvalBackend + Send + 'static>(
        &mut self,
        platform: &mut EvalPlatform<B>,
        budget: u64,
    ) -> TunerOutcome {
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut curve = ConvergenceCurve::default();
        let mut best: Option<(f64, KernelGenome)> = None;

        // generation 0: the workload's seeds + mutated copies, one batch
        let starts = workload_starts(platform);
        let mut gen0: Vec<KernelGenome> = Vec::new();
        let mut planned = 0u64;
        let mut attempts = 0;
        while gen0.len() < self.population_size && attempts < self.population_size * 50 {
            attempts += 1;
            let mut g = starts[gen0.len() % starts.len()].clone();
            if gen0.len() >= starts.len() {
                self.mutate(&mut g, &mut rng);
                if g.validate().is_err() {
                    continue;
                }
            }
            if !plan_room(platform, budget, &mut planned, &g) {
                break;
            }
            gen0.push(g);
        }
        let results = platform.submit_stream_batch(&gen0);
        gen0.truncate(results.len());
        let mut population = fold_batch(&gen0, &results, &mut curve, &mut best);

        // generations: plan children, evaluate each generation as a batch
        let mut stagnant = 0u32;
        while platform.submissions() < budget && !population.is_empty() && stagnant < 16 {
            let before = platform.submissions();
            let mut next: Vec<Scored> = Vec::new();
            // elitism: carry over the best without re-evaluation
            let mut sorted = population.clone();
            sorted.sort_by(|a, b| a.score.total_cmp(&b.score));
            for e in sorted.iter().take(self.elitism) {
                next.push(e.clone());
            }
            let mut children: Vec<KernelGenome> = Vec::new();
            let mut planned = 0u64;
            let mut attempts = 0;
            while next.len() + children.len() < self.population_size
                && attempts < self.population_size * 20
            {
                attempts += 1;
                let a = self.tournament(&population, &mut rng);
                let b = self.tournament(&population, &mut rng);
                let mut child = crossover(&a.genome, &b.genome, &mut rng);
                self.mutate(&mut child, &mut rng);
                if child.validate().is_err() {
                    continue;
                }
                if !plan_room(platform, budget, &mut planned, &child) {
                    break;
                }
                children.push(child);
            }
            let results = platform.submit_stream_batch(&children);
            children.truncate(results.len());
            next.extend(fold_batch(&children, &results, &mut curve, &mut best));
            population = next;
            // a fully-cached generation consumes no budget; bail out if
            // the search keeps treading water instead of spinning
            if platform.submissions() == before {
                stagnant += 1;
            } else {
                stagnant = 0;
            }
        }

        // all-failures fallback: the family's bootstrap fast-path seed
        // (listed last — fp8's mfma-seed, exactly as before the registry)
        let (score, genome) = best
            .unwrap_or_else(|| (f64::INFINITY, starts.last().expect("workload has seeds").clone()));
        TunerOutcome {
            name: self.name(),
            best_geomean_us: score,
            best_genome: genome,
            submissions: platform.submissions(),
            curve,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::PlatformConfig;
    use crate::sim::SimBackend;

    fn platform(seed: u64) -> EvalPlatform<SimBackend> {
        EvalPlatform::new(SimBackend::new(seed), PlatformConfig::default())
    }

    #[test]
    fn ga_respects_budget() {
        let mut p = platform(1);
        let out = GeneticAlgorithm {
            seed: 1,
            ..Default::default()
        }
        .run(&mut p, 40);
        assert!(out.submissions <= 40);
        assert!(out.best_geomean_us.is_finite());
        assert!(out.best_genome.validate().is_ok());
    }

    #[test]
    fn ga_improves_over_generation_zero() {
        let mut p = platform(2);
        let out = GeneticAlgorithm {
            seed: 2,
            ..Default::default()
        }
        .run(&mut p, 100);
        // gen-0 includes the naive seed (~6000 us); GA must do better
        assert!(out.best_geomean_us < 1000.0, "{}", out.best_geomean_us);
    }

    #[test]
    fn ga_is_workload_generic() {
        // the GA pulls its generation-0 seeds from the platform's
        // workload, so it tunes any registered family
        let w = crate::workload::lookup("row-softmax").unwrap();
        let mut p = EvalPlatform::new(
            SimBackend::new(4).with_workload(w.clone()),
            PlatformConfig::default(),
        )
        .with_feedback_suite(w.feedback_suite());
        let out = GeneticAlgorithm {
            seed: 4,
            ..Default::default()
        }
        .run(&mut p, 30);
        assert!(out.submissions <= 30);
        assert!(out.best_geomean_us.is_finite());
        assert!(out.best_genome.validate().is_ok());
    }

    #[test]
    fn ga_is_reproducible() {
        let a = GeneticAlgorithm {
            seed: 3,
            ..Default::default()
        }
        .run(&mut platform(7), 30);
        let b = GeneticAlgorithm {
            seed: 3,
            ..Default::default()
        }
        .run(&mut platform(7), 30);
        assert_eq!(a.best_geomean_us, b.best_geomean_us);
    }
}
