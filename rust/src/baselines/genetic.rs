//! A classic genetic algorithm over the genome space — the "GPU Kernel
//! **Evolver**" the paper deliberately is *not* (§2: "we have a GPU
//! Kernel Scientist, rather than a GPU Kernel Evolver").
//!
//! Standard GA machinery: tournament selection, uniform crossover,
//! per-axis mutation, elitism — no knowledge base, no experiment
//! design, no rationales. Comparing it against the scientist at equal
//! submission budget quantifies what the paper's "science" layer adds
//! over plain evolution with the same operators.

use super::{submit_scored, Tuner, TunerOutcome};
use crate::eval::{EvalBackend, EvalPlatform};
use crate::genome::{
    edit::{crossover, GenomeEdit},
    seeds, KernelGenome,
};
use crate::metrics::ConvergenceCurve;
use crate::rng::Rng;

/// GA hyper-parameters.
#[derive(Debug, Clone)]
pub struct GeneticAlgorithm {
    pub seed: u64,
    pub population_size: usize,
    pub tournament_k: usize,
    pub mutation_rate: f64,
    pub elitism: usize,
}

impl Default for GeneticAlgorithm {
    fn default() -> Self {
        GeneticAlgorithm {
            seed: 0,
            population_size: 12,
            tournament_k: 3,
            mutation_rate: 0.25,
            elitism: 2,
        }
    }
}

#[derive(Clone)]
struct Scored {
    genome: KernelGenome,
    /// Lower is better; failures get +inf.
    score: f64,
}

impl GeneticAlgorithm {
    fn tournament<'a>(&self, pop: &'a [Scored], rng: &mut Rng) -> &'a Scored {
        let mut best: Option<&Scored> = None;
        for _ in 0..self.tournament_k {
            let c = &pop[rng.below(pop.len())];
            if best.map(|b| c.score < b.score).unwrap_or(true) {
                best = Some(c);
            }
        }
        best.unwrap()
    }

    fn mutate(&self, g: &mut KernelGenome, rng: &mut Rng) {
        while rng.chance(self.mutation_rate) {
            GenomeEdit::random(rng).apply(g);
        }
    }
}

impl Tuner for GeneticAlgorithm {
    fn name(&self) -> &'static str {
        "genetic-algorithm"
    }

    fn run<B: EvalBackend>(
        &mut self,
        platform: &mut EvalPlatform<B>,
        budget: u64,
    ) -> TunerOutcome {
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut curve = ConvergenceCurve::default();
        let mut best: Option<(f64, KernelGenome)> = None;

        let score_and_track =
            |g: &KernelGenome,
             platform: &mut EvalPlatform<B>,
             curve: &mut ConvergenceCurve,
             best: &mut Option<(f64, KernelGenome)>| {
                let s = submit_scored(platform, g, curve).unwrap_or(f64::INFINITY);
                if s.is_finite() && best.as_ref().map(|(b, _)| s < *b).unwrap_or(true) {
                    *best = Some((s, g.clone()));
                }
                s
            };

        // generation 0: seeds + mutated copies
        let starts: Vec<KernelGenome> =
            seeds::starting_population().into_iter().map(|(_, g)| g).collect();
        let mut population: Vec<Scored> = Vec::new();
        while population.len() < self.population_size && platform.submissions() < budget {
            let mut g = starts[population.len() % starts.len()].clone();
            if population.len() >= starts.len() {
                self.mutate(&mut g, &mut rng);
                if g.validate().is_err() {
                    continue;
                }
            }
            let score = score_and_track(&g, platform, &mut curve, &mut best);
            population.push(Scored { genome: g, score });
        }

        // generations
        while platform.submissions() < budget && !population.is_empty() {
            let mut next: Vec<Scored> = Vec::new();
            // elitism: carry over the best without re-evaluation
            let mut sorted = population.clone();
            sorted.sort_by(|a, b| a.score.partial_cmp(&b.score).unwrap());
            for e in sorted.iter().take(self.elitism) {
                next.push(e.clone());
            }
            let mut attempts = 0;
            while next.len() < self.population_size
                && platform.submissions() < budget
                && attempts < self.population_size * 20
            {
                attempts += 1;
                let a = self.tournament(&population, &mut rng);
                let b = self.tournament(&population, &mut rng);
                let mut child = crossover(&a.genome, &b.genome, &mut rng);
                self.mutate(&mut child, &mut rng);
                if child.validate().is_err() {
                    continue;
                }
                let score = score_and_track(&child, platform, &mut curve, &mut best);
                next.push(Scored {
                    genome: child,
                    score,
                });
            }
            population = next;
        }

        let (score, genome) =
            best.unwrap_or_else(|| (f64::INFINITY, seeds::mfma_seed()));
        TunerOutcome {
            name: self.name(),
            best_geomean_us: score,
            best_genome: genome,
            submissions: platform.submissions(),
            curve,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::PlatformConfig;
    use crate::sim::SimBackend;

    fn platform(seed: u64) -> EvalPlatform<SimBackend> {
        EvalPlatform::new(SimBackend::new(seed), PlatformConfig::default())
    }

    #[test]
    fn ga_respects_budget() {
        let mut p = platform(1);
        let out = GeneticAlgorithm {
            seed: 1,
            ..Default::default()
        }
        .run(&mut p, 40);
        assert!(out.submissions <= 40);
        assert!(out.best_geomean_us.is_finite());
        assert!(out.best_genome.validate().is_ok());
    }

    #[test]
    fn ga_improves_over_generation_zero() {
        let mut p = platform(2);
        let out = GeneticAlgorithm {
            seed: 2,
            ..Default::default()
        }
        .run(&mut p, 100);
        // gen-0 includes the naive seed (~6000 us); GA must do better
        assert!(out.best_geomean_us < 1000.0, "{}", out.best_geomean_us);
    }

    #[test]
    fn ga_is_reproducible() {
        let a = GeneticAlgorithm {
            seed: 3,
            ..Default::default()
        }
        .run(&mut platform(7), 30);
        let b = GeneticAlgorithm {
            seed: 3,
            ..Default::default()
        }
        .run(&mut platform(7), 30);
        assert_eq!(a.best_geomean_us, b.best_geomean_us);
    }
}
