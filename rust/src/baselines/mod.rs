//! Baseline tuners: the comparison points the paper's related work
//! implies (OpenTuner / Kernel-Tuner style search, §2) plus simple
//! evolutionary controls. All operate on the *same* genome space,
//! through the *same* evaluation platform, under the *same* submission
//! budget — so the scientist-vs-tuner benches are apples-to-apples.

pub mod genetic;

pub use genetic::GeneticAlgorithm;

use crate::eval::{EvalBackend, EvalPlatform};
use crate::genome::{
    edit::{self, GenomeEdit},
    seeds, KernelGenome,
};
use crate::metrics::{geomean, ConvergenceCurve};
use crate::population::EvalOutcome;
use crate::rng::Rng;
use crate::workload::Workload;

/// The seed genomes a tuner starts from: the platform workload's
/// starting population (tuners are workload-generic, like the
/// scientist).
pub(crate) fn workload_starts<B: EvalBackend>(
    platform: &EvalPlatform<B>,
) -> Vec<KernelGenome> {
    platform
        .workload()
        .starting_population()
        .into_iter()
        .map(|(_, g)| g)
        .collect()
}

/// Outcome of a tuner run (mirrors `scientist::RunOutcome`).
#[derive(Debug, Clone)]
pub struct TunerOutcome {
    pub name: &'static str,
    pub best_geomean_us: f64,
    pub best_genome: KernelGenome,
    pub submissions: u64,
    pub curve: ConvergenceCurve,
}

/// A search strategy over the genome space. `B: Send + 'static`
/// because tuners may evaluate candidate generations through the
/// platform's multi-lane executor, whose completion-driven stream
/// path keeps per-lane worker threads alive (the genetic baseline
/// does — see [`crate::eval::EvalPlatform::submit_stream_batch`]).
pub trait Tuner {
    fn name(&self) -> &'static str;

    /// Run until `budget` submissions are spent on `platform`.
    fn run<B: EvalBackend + Send + 'static>(
        &mut self,
        platform: &mut EvalPlatform<B>,
        budget: u64,
    ) -> TunerOutcome
    where
        Self: Sized;
}

pub(crate) fn submit_scored<B: EvalBackend>(
    platform: &mut EvalPlatform<B>,
    g: &KernelGenome,
    curve: &mut ConvergenceCurve,
) -> Option<f64> {
    let out = platform.submit(g);
    let score = match &out {
        EvalOutcome::Timings(ts) => Some(geomean(ts)),
        _ => None,
    };
    if let Some(s) = score {
        curve.record(platform.submissions() as usize, s);
    } else if let Some(best) = curve.best() {
        curve.record(platform.submissions() as usize, best);
    }
    score
}

/// Pure random search over valid genomes (mutation chains from seeds).
pub struct RandomSearch {
    pub seed: u64,
}

impl Tuner for RandomSearch {
    fn name(&self) -> &'static str {
        "random-search"
    }

    fn run<B: EvalBackend + Send + 'static>(
        &mut self,
        platform: &mut EvalPlatform<B>,
        budget: u64,
    ) -> TunerOutcome {
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut curve = ConvergenceCurve::default();
        let starts = workload_starts(platform);
        let mut best: Option<(f64, KernelGenome)> = None;
        while platform.submissions() < budget {
            // random walk of 1-4 edits from a random seed
            let mut g = starts[rng.below(starts.len())].clone();
            let steps = 1 + rng.below(4);
            for _ in 0..steps {
                let e = GenomeEdit::random(&mut rng);
                e.apply(&mut g);
            }
            if g.validate().is_err() {
                continue; // don't waste a submission on known-invalid
            }
            if let Some(score) = submit_scored(platform, &g, &mut curve) {
                if best.as_ref().map(|(b, _)| score < *b).unwrap_or(true) {
                    best = Some((score, g));
                }
            }
        }
        let (score, genome) = best.unwrap_or_else(|| (f64::INFINITY, starts[0].clone()));
        TunerOutcome {
            name: self.name(),
            best_geomean_us: score,
            best_genome: genome,
            submissions: platform.submissions(),
            curve,
        }
    }
}

/// Greedy hill climber with random restarts on stall.
pub struct HillClimber {
    pub seed: u64,
    /// Consecutive non-improving submissions before a restart.
    pub patience: u32,
}

impl Default for HillClimber {
    fn default() -> Self {
        HillClimber { seed: 0, patience: 8 }
    }
}

impl Tuner for HillClimber {
    fn name(&self) -> &'static str {
        "hill-climber"
    }

    fn run<B: EvalBackend + Send + 'static>(
        &mut self,
        platform: &mut EvalPlatform<B>,
        budget: u64,
    ) -> TunerOutcome {
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut curve = ConvergenceCurve::default();
        let starts = workload_starts(platform);
        let mut current = starts[rng.below(starts.len())].clone();
        let mut current_score = f64::INFINITY;
        let mut global_best: Option<(f64, KernelGenome)> = None;
        let mut stall = 0;
        while platform.submissions() < budget {
            let neighbors = edit::valid_neighbors(&current);
            if neighbors.is_empty() {
                break;
            }
            let (_, candidate) = neighbors[rng.below(neighbors.len())].clone();
            if let Some(score) = submit_scored(platform, &candidate, &mut curve) {
                if score < current_score {
                    current = candidate.clone();
                    current_score = score;
                    stall = 0;
                } else {
                    stall += 1;
                }
                if global_best.as_ref().map(|(b, _)| score < *b).unwrap_or(true) {
                    global_best = Some((score, candidate));
                }
            } else {
                stall += 1;
            }
            if stall >= self.patience {
                current = starts[rng.below(starts.len())].clone();
                current_score = f64::INFINITY;
                stall = 0;
            }
        }
        let (score, genome) =
            global_best.unwrap_or_else(|| (f64::INFINITY, starts[0].clone()));
        TunerOutcome {
            name: self.name(),
            best_geomean_us: score,
            best_genome: genome,
            submissions: platform.submissions(),
            curve,
        }
    }
}

/// Simulated annealing (the OpenTuner-flavoured baseline).
pub struct Annealer {
    pub seed: u64,
    pub t0: f64,
    pub cooling: f64,
}

impl Default for Annealer {
    fn default() -> Self {
        Annealer {
            seed: 0,
            t0: 0.5,
            cooling: 0.96,
        }
    }
}

impl Tuner for Annealer {
    fn name(&self) -> &'static str {
        "simulated-annealing"
    }

    fn run<B: EvalBackend + Send + 'static>(
        &mut self,
        platform: &mut EvalPlatform<B>,
        budget: u64,
    ) -> TunerOutcome {
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut curve = ConvergenceCurve::default();
        // the workload's fast-path bootstrap seed (listed last; the fp8
        // family's mfma-seed, exactly as before the registry)
        let mut current = workload_starts(platform)
            .pop()
            .expect("workload has seeds");
        let mut current_score = f64::INFINITY;
        let mut best: Option<(f64, KernelGenome)> = None;
        let mut temp = self.t0;
        while platform.submissions() < budget {
            let neighbors = edit::valid_neighbors(&current);
            if neighbors.is_empty() {
                break;
            }
            let (_, candidate) = neighbors[rng.below(neighbors.len())].clone();
            if let Some(score) = submit_scored(platform, &candidate, &mut curve) {
                // accept better always; worse with exp(-delta / T) on
                // relative (log) score
                let accept = if score < current_score {
                    true
                } else {
                    let delta = (score / current_score).ln();
                    rng.f64() < (-delta / temp.max(1e-6)).exp()
                };
                if accept {
                    current = candidate.clone();
                    current_score = score;
                }
                if best.as_ref().map(|(b, _)| score < *b).unwrap_or(true) {
                    best = Some((score, candidate));
                }
            }
            temp *= self.cooling;
        }
        let (score, genome) = best.unwrap_or((f64::INFINITY, current));
        TunerOutcome {
            name: self.name(),
            best_geomean_us: score,
            best_genome: genome,
            submissions: platform.submissions(),
            curve,
        }
    }
}

/// Exhaustive directed search for the *oracle* bound — models the
/// human expert with hardware access and unlimited local iteration.
/// Uses the simulator's noiseless estimates directly (not platform
/// submissions): the expert profiles locally.
pub fn oracle_search(
    arch: &crate::gpu::GpuArch,
    configs: &[crate::workload::GemmConfig],
    iterations: u32,
    seed: u64,
) -> (f64, KernelGenome) {
    let mut rng = Rng::seed_from_u64(seed);
    let score = |g: &KernelGenome| -> Option<f64> {
        if g.correctness_hazard().is_some() {
            return None;
        }
        let ts: Option<Vec<f64>> = configs
            .iter()
            .map(|c| crate::sim::estimate(arch, g, c).ok().map(|t| t.total_us))
            .collect();
        ts.map(|v| geomean(&v))
    };
    let mut best = seeds::human_oracle();
    let mut best_score = score(&best).expect("oracle seed scores");
    for _ in 0..iterations {
        let neighbors = edit::valid_neighbors(&best);
        let mut improved = false;
        for (_, cand) in &neighbors {
            if let Some(s) = score(cand) {
                if s < best_score {
                    best = cand.clone();
                    best_score = s;
                    improved = true;
                }
            }
        }
        if !improved {
            // random kick to escape local optimum
            let (_, cand) = neighbors[rng.below(neighbors.len())].clone();
            if let Some(s) = score(&cand) {
                if s < best_score * 1.02 {
                    best = cand;
                    best_score = s.min(best_score);
                }
            }
        }
    }
    (best_score, best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::PlatformConfig;
    use crate::gpu::MI300;
    use crate::sim::SimBackend;
    use crate::workload::LEADERBOARD_SIZES;

    fn platform(seed: u64) -> EvalPlatform<SimBackend> {
        EvalPlatform::new(SimBackend::new(seed), PlatformConfig::default())
    }

    #[test]
    fn random_search_respects_budget_and_improves() {
        let mut p = platform(1);
        let out = RandomSearch { seed: 1 }.run(&mut p, 40);
        assert!(out.submissions <= 40);
        assert!(out.best_geomean_us.is_finite());
        assert!(out.best_genome.validate().is_ok());
    }

    #[test]
    fn hill_climber_runs() {
        let mut p = platform(2);
        let out = HillClimber::default().run(&mut p, 40);
        assert!(out.submissions <= 40);
        assert!(out.best_geomean_us.is_finite());
        assert!(!out.curve.points.is_empty());
    }

    #[test]
    fn annealer_runs() {
        let mut p = platform(3);
        let out = Annealer::default().run(&mut p, 40);
        assert!(out.submissions <= 40);
        assert!(out.best_geomean_us.is_finite());
    }

    #[test]
    fn tuners_are_reproducible() {
        let a = RandomSearch { seed: 7 }.run(&mut platform(9), 25);
        let b = RandomSearch { seed: 7 }.run(&mut platform(9), 25);
        assert_eq!(a.best_geomean_us, b.best_geomean_us);
        assert_eq!(a.best_genome, b.best_genome);
    }

    #[test]
    fn oracle_search_at_least_matches_seed() {
        let seed_score = {
            let ts: Vec<f64> = LEADERBOARD_SIZES
                .iter()
                .map(|c| {
                    crate::sim::estimate(&MI300, &seeds::human_oracle(), c)
                        .unwrap()
                        .total_us
                })
                .collect();
            geomean(&ts)
        };
        let (score, genome) = oracle_search(&MI300, &LEADERBOARD_SIZES, 5, 1);
        assert!(score <= seed_score * 1.0001);
        assert!(genome.validate().is_ok());
        assert!(genome.correctness_hazard().is_none());
    }
}
