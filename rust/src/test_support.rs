//! In-tree test fixtures shared by the integration tests (and usable
//! from examples/benches): tiny fast-run [`RunConfig`]s, seeded-RNG
//! helpers, genome generators, and trajectory extraction.
//!
//! Deliberately a library module rather than a `tests/common/mod.rs`:
//! the fixtures are part of the crate's supported surface (benches and
//! examples reuse them, doc links resolve, and `cargo test` exercises
//! the module's own unit tests). It contains no production logic —
//! only deterministic constructors over public APIs — and the scientist
//! loop never calls into it.

use crate::config::RunConfig;
use crate::eval::FaultyBackend;
use crate::genome::{edit, seeds, KernelGenome};
use crate::rng::Rng;
use crate::scientist::{RunOutcome, ScientistRun};
use crate::sim::SimBackend;

/// Tests honoring a CI-matrix parallelism read it from this variable.
pub const PARALLELISM_ENV: &str = "GKS_TEST_PARALLELISM";

/// Executor lanes requested by the CI matrix (defaults to 1 — the
/// paper's sequential mode — when the variable is unset or malformed).
pub fn env_parallelism() -> u32 {
    std::env::var(PARALLELISM_ENV)
        .ok()
        .and_then(|v| v.parse::<u32>().ok())
        .filter(|&p| p >= 1)
        .unwrap_or(1)
}

/// A small, fast scientist-run config: paper defaults with the given
/// seed and submission budget. Deliberately ignores the CI parallelism
/// matrix — tests that assert sequential-clock properties rely on it.
pub fn tiny_run_config(seed: u64, budget: u64) -> RunConfig {
    RunConfig::default().with_seed(seed).with_budget(budget)
}

/// A noiseless config for determinism tests: with `noise_sigma = 0`
/// measurements are exact, so trajectories are invariant under the
/// executor's lane partitioning and lane-noise forking.
pub fn noiseless_config(workload: &str, seed: u64, budget: u64) -> RunConfig {
    let mut cfg = tiny_run_config(seed, budget).with_workload(workload);
    cfg.noise_sigma = 0.0;
    cfg
}

/// A steady-state-pipeline variant of [`tiny_run_config`]: same paper
/// defaults (noise included), with the scheduler switched to the
/// pipeline (DESIGN.md §8) over `lanes` evaluation lanes.
pub fn pipeline_config(workload: &str, seed: u64, budget: u64, lanes: u32) -> RunConfig {
    tiny_run_config(seed, budget)
        .with_workload(workload)
        .with_parallelism(lanes)
        .with_pipeline(true)
}

/// A [`pipeline_config`] with the analytic screen tier enabled
/// (DESIGN.md §10): rung of 4, keep half — small enough that tiny test
/// budgets still fill rungs and exercise promotion.
pub fn screened_pipeline_config(workload: &str, seed: u64, budget: u64, lanes: u32) -> RunConfig {
    pipeline_config(workload, seed, budget, lanes).with_screen(4, 0.5)
}

/// Construct + run a simulated scientist loop to completion. The
/// backend is [`ScientistRun::new`]'s always-wrapped
/// `FaultyBackend<SimBackend>` — pure delegation (and zero fault RNG
/// draws) unless the config enables `[faults]`.
pub fn run_scientist(cfg: RunConfig) -> (ScientistRun<FaultyBackend<SimBackend>>, RunOutcome) {
    let mut run = ScientistRun::new(cfg).expect("scientist setup");
    let outcome = run.run_to_completion().expect("scientist run");
    (run, outcome)
}

/// The run's full population trajectory as (fingerprint, outcome)
/// pairs — the bit-identity witness used by the determinism tests.
pub fn trajectory(run: &ScientistRun<FaultyBackend<SimBackend>>) -> Vec<(String, String)> {
    run.population
        .members()
        .iter()
        .map(|m| (m.genome.fingerprint(), format!("{:?}", m.outcome)))
        .collect()
}

/// A deterministic RNG for test-local randomness.
pub fn test_rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}

/// A unique, freshly created scratch directory under the system temp
/// dir (for run-store tests). Uniqueness comes from the process id plus
/// a process-wide counter, so concurrent test threads never collide.
pub fn scratch_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("gks-{tag}-{}-{n}", std::process::id()));
    // fresh: a previous run's leftovers must not leak into this test
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

/// `n` distinct valid genomes (single-edit neighbors of the fp8
/// canonical seeds). Panics if the space can't supply `n`.
pub fn distinct_genomes(n: usize) -> Vec<KernelGenome> {
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for base in [
        seeds::mfma_seed(),
        seeds::human_oracle(),
        seeds::pytorch_reference(),
    ] {
        for (_, g) in edit::valid_neighbors(&base) {
            if seen.insert(g.fingerprint()) {
                out.push(g);
            }
            if out.len() == n {
                return out;
            }
        }
    }
    panic!("not enough distinct genomes for the test (wanted {n})");
}

/// A random (possibly invalid) genome via an edit walk from a random
/// canonical seed — the generator behind the property tests.
pub fn random_genome(rng: &mut Rng) -> KernelGenome {
    let starts = seeds::all_seeds();
    let mut g = starts[rng.below(starts.len())].1.clone();
    for _ in 0..rng.below(8) {
        edit::GenomeEdit::random(rng).apply(&mut g);
    }
    g
}

/// A random *valid* genome (rejection-sampled [`random_genome`]).
pub fn random_valid_genome(rng: &mut Rng) -> KernelGenome {
    loop {
        let g = random_genome(rng);
        if g.validate().is_ok() {
            return g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_genomes_are_distinct_and_valid() {
        let gs = distinct_genomes(12);
        assert_eq!(gs.len(), 12);
        let fps: std::collections::HashSet<String> =
            gs.iter().map(|g| g.fingerprint()).collect();
        assert_eq!(fps.len(), 12);
        for g in &gs {
            assert!(g.validate().is_ok());
        }
    }

    #[test]
    fn noiseless_config_zeroes_noise_only() {
        let cfg = noiseless_config("row-softmax", 7, 20);
        assert_eq!(cfg.noise_sigma, 0.0);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.max_submissions, 20);
        assert_eq!(cfg.workload, "row-softmax");
        assert_eq!(cfg.eval_parallelism, 1);
    }

    #[test]
    fn pipeline_config_switches_scheduler_only() {
        let cfg = pipeline_config("bf16-gemm", 3, 18, 4);
        assert!(cfg.pipeline);
        assert_eq!(cfg.eval_parallelism, 4);
        assert_eq!(cfg.workload, "bf16-gemm");
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.max_submissions, 18);
        assert_eq!(cfg.noise_sigma, RunConfig::default().noise_sigma);
    }

    #[test]
    fn screened_pipeline_config_enables_the_screen_knobs_only() {
        let cfg = screened_pipeline_config("fp8-gemm", 11, 40, 2);
        assert!(cfg.screen_enabled);
        assert_eq!(cfg.screen_rung, 4);
        assert_eq!(cfg.screen_keep, 0.5);
        let base = pipeline_config("fp8-gemm", 11, 40, 2);
        assert!(cfg.pipeline && cfg.eval_parallelism == base.eval_parallelism);
        assert_eq!(cfg.seed, base.seed);
        assert_eq!(cfg.max_submissions, base.max_submissions);
    }

    #[test]
    fn random_valid_genome_terminates_and_validates() {
        let mut rng = test_rng(5);
        for _ in 0..50 {
            assert!(random_valid_genome(&mut rng).validate().is_ok());
        }
    }

    #[test]
    fn env_parallelism_defaults_to_one() {
        // (the variable is not set under plain `cargo test`)
        if std::env::var(PARALLELISM_ENV).is_err() {
            assert_eq!(env_parallelism(), 1);
        }
    }
}
